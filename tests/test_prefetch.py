"""Prefetcher mechanics + the paper's central overlap claim (Fig. 6)."""
import time

import pytest

from repro.core.dataset import Dataset
from repro.core.prefetcher import PrefetchIterator


class TestPrefetchIterator:
    def test_order_and_completeness(self):
        assert list(PrefetchIterator(iter(range(50)), 4)) == list(range(50))

    def test_buffer_bounded(self):
        produced = []

        def gen():
            for i in range(100):
                produced.append(i)
                yield i

        it = PrefetchIterator(gen(), buffer_size=2)
        time.sleep(0.1)
        # producer must stall at buffer_size ahead (first next not called yet)
        assert len(produced) <= 4
        next(it)
        it.close()

    def test_empty_upstream(self):
        assert list(PrefetchIterator(iter([]), 1)) == []

    def test_bad_buffer_size(self):
        with pytest.raises(ValueError):
            PrefetchIterator(iter([1]), 0)


class TestOverlap:
    """The paper's key result: prefetch(1) fully hides I/O behind compute
    when compute >= I/O per batch (Fig. 6: runtime becomes independent of
    the input pipeline)."""

    N, IO_T, COMPUTE_T = 10, 0.03, 0.05

    def _pipeline(self, prefetch):
        def slow_io(x):
            time.sleep(self.IO_T)
            return x

        ds = Dataset.range(self.N).map(slow_io)
        if prefetch:
            ds = ds.prefetch(1)
        return ds

    def _consume(self, ds):
        t0 = time.monotonic()
        for _ in ds:
            time.sleep(self.COMPUTE_T)  # the "GPU step"
        return time.monotonic() - t0

    def test_no_prefetch_is_sum(self):
        t = self._consume(self._pipeline(False))
        expect = self.N * (self.IO_T + self.COMPUTE_T)
        assert t > expect * 0.85

    def test_prefetch_hides_io(self):
        t = self._consume(self._pipeline(True))
        serial = self.N * (self.IO_T + self.COMPUTE_T)
        overlapped = self.N * self.COMPUTE_T + self.IO_T
        assert t < (serial + overlapped) / 2, (
            f"prefetch failed to overlap: {t:.3f}s vs serial {serial:.3f}s"
        )
