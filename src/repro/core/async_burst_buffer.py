"""Async burst-buffer checkpointing: snapshot-only blocking + tiered drain.

The paper's burst buffer (§III-C, Fig. 9/10 — the 2.6x result) hides the
*slow-tier* cost of a checkpoint behind a fast tier, but training still
blocks for the full fast-tier write.  Its prefetcher result (§IV: complete
compute/input overlap) points at overlapping the write path entirely; this
module fuses the two engines so even the fast-tier stage leaves the
training thread:

1. **Snapshot** (blocking, :func:`repro.core.checkpoint.flatten_pytree`
   with ``copy=True``): the pytree is materialized in host memory —
   memory-bandwidth bound (GB/s), so training resumes after milliseconds.
2. **Stage** (background writer thread, in submission order): the normal
   sharded, atomic :meth:`CheckpointSaver.save_flat` to the *fast* tier
   (Optane in the paper), traced as ``STAGE_STAGE``.
3. **Drain** (background drain thread, inherited from
   :class:`BurstBufferCheckpointer`): every file of the staged step splits
   into ``drain_chunk`` ranges that stream to the *slow* tier on
   ``drain_streams`` threads (``read_range`` → pwrite-style
   ``write_range``), then the slow-tier commit marker is published durably
   (sync barrier + tmp/rename).

``save()`` returns an :class:`AsyncSaveHandle`; its ``result()`` settles
when the **fast tier** has committed (the step is then durable and
restorable — the contract a preemption save needs), while :meth:`wait`
additionally drains the slow tier.  ``max_pending`` bounds host memory the
same way :class:`AsyncCheckpointer` does.

Crash consistency is the same marker protocol at both tiers, proven in
``tests/test_faults.py`` under clean, torn-write and reordered-fsync fault
models at every injection point of the save/drain path.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, List, Optional

from .. import metrics, trace
from .async_checkpoint import AsyncSaveHandle, _any_error_delivered, \
    _cancel_and_promote
from .burst_buffer import BurstBufferCheckpointer
from .checkpoint import PreemptionReport, SaveResult, flatten_pytree


class AsyncBurstBufferCheckpointer(BurstBufferCheckpointer):
    """Burst-buffer checkpointer whose ``save()`` blocks only for the host
    snapshot.

    Same construction surface as :class:`BurstBufferCheckpointer` plus
    ``max_pending`` (host-memory backpressure: a ``save()`` issued while
    that many snapshots are still staging blocks until a slot frees; the
    blocked time is honestly recorded in ``blocked_s``).
    """

    def __init__(self, fast_storage, slow_storage,
                 prefix: str = "ckpt/model", *, max_pending: int = 2,
                 **kwargs):
        kwargs.pop("drain_async", None)  # the drain thread is mandatory here
        super().__init__(fast_storage, slow_storage, prefix,
                         drain_async=True, **kwargs)
        self._sema = threading.BoundedSemaphore(max(1, max_pending))
        self._stage_handles: List[AsyncSaveHandle] = []
        # One stager: steps stage (and therefore enqueue drains) in
        # submission order, so both tiers' markers advance monotonically.
        self._stager: Optional[ThreadPoolExecutor] = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="bb-stage"
        )

    # -- producer (training thread) -----------------------------------------
    def save(self, step: int, tree: Any,
             extra_meta: Optional[dict] = None) -> AsyncSaveHandle:
        if self._stager is None:
            raise RuntimeError("AsyncBurstBufferCheckpointer is closed")
        if self._preempted:
            raise RuntimeError(
                "save() on a preempted AsyncBurstBufferCheckpointer")
        m = metrics.enabled()
        t0 = time.monotonic()
        self._sema.acquire()  # backpressure: at most max_pending snapshots
        try:
            t_snap = time.monotonic()
            with trace.span(trace.STAGE_CKPT_SNAPSHOT,
                            f"snapshot:{self.prefix}-{step}") as sp:
                flat, treedef = flatten_pytree(tree, copy=True)
                sp.set_bytes(sum(a.nbytes for a in flat.values()))
            if m:
                metrics.observe("ckpt.snapshot_s",
                                time.monotonic() - t_snap, ckpt=self.prefix)
            fut = self._stager.submit(self._stage, step, flat, extra_meta,
                                      treedef, m)
            if m:
                metrics.add_gauge("ckpt.pending_saves", 1, ckpt=self.prefix)
        except BaseException:
            self._sema.release()
            raise
        blocked = time.monotonic() - t0
        self.blocked_s.append(blocked)
        if m:
            metrics.observe("ckpt.blocked_s", blocked, ckpt=self.prefix)
        handle = AsyncSaveHandle(step, fut, blocked, metrics_flag=m)
        self._stage_handles = [
            h for h in self._stage_handles
            if not h.done()
            or (not h._future.cancelled() and not h._reported
                and h._future.exception() is not None)
        ]
        self._stage_handles.append(handle)
        return handle

    # -- stager thread -------------------------------------------------------
    def _stage(self, step: int, flat, extra_meta, treedef,
               m: bool) -> SaveResult:
        """Fast-tier sharded save, then hand the files to the drain
        pipeline.  Runs on the single stager thread."""
        try:
            t0 = time.monotonic()
            with trace.span(trace.STAGE_STAGE,
                            f"stage:{self.prefix}-{step}") as sp:
                r = self.fast_saver.save_flat(step, flat, extra_meta,
                                              treedef=treedef)
                sp.set_bytes(r.n_bytes)
            if m:
                metrics.observe("ckpt.staged_s", time.monotonic() - t0,
                                ckpt=self.prefix)
                metrics.add_gauge("ckpt.drain_backlog_bytes", r.n_bytes,
                                  ckpt=self.prefix)
            if self.on_staged is not None:
                # fast-tier commit: the step is now preemption-durable
                self.on_staged(step)
            self._enqueue_drain(step, r, m)
            return r
        finally:
            self._sema.release()
            if m:  # symmetric with the save-time increment
                metrics.add_gauge("ckpt.pending_saves", -1, ckpt=self.prefix)

    # -- consumer-side API ---------------------------------------------------
    def pending(self) -> int:
        """Snapshots not yet committed to the fast tier."""
        return sum(1 for h in self._stage_handles if not h.done())

    def wait(self) -> None:
        """Block until every issued save has staged *and* drained; raise
        the first background error (stage or drain), report-once."""
        handles, self._stage_handles = self._stage_handles, []
        errors = []
        for h in handles:
            e = h._drain_error()  # blocks until this stage settles
            if e is not None:
                errors.append(e)
        # only now is the drain queue fully fed (stages enqueue drains)
        self._q.join()
        errors.extend(self._take_errors())
        if errors:
            raise errors[0]

    def preempt(self, deadline_s: Optional[float] = None) -> PreemptionReport:
        """Graceful shutdown within a budget: stop accepting saves, cancel
        queued-but-unstarted stages except the newest, and wait up to
        ``deadline_s`` for that newest snapshot to commit on the **fast
        tier** (the preemption-durability point — slow-tier drains of
        already-staged steps keep running in the background and are never
        abandoned)."""
        t0 = time.monotonic()
        self._preempted = True
        abandoned, met = _cancel_and_promote(
            list(self._stage_handles), self._sema, self.prefix, deadline_s,
            t0)
        return PreemptionReport(self.latest_step(), abandoned, deadline_s,
                                time.monotonic() - t0, met)

    def close(self) -> None:
        """Drain the stager, stop the drain thread, surface the first
        never-delivered background error from either phase (quiet if a
        failure already reached the caller — same contract as
        :meth:`AsyncCheckpointer.close`)."""
        errors: List[BaseException] = []
        if self._stager is not None:
            self._stager.shutdown(wait=True)
            self._stager = None
        handles, self._stage_handles = self._stage_handles, []
        if not _any_error_delivered(handles):
            errors.extend(e for e in (h._unreported_error() for h in handles)
                          if e is not None)
        try:
            super().close()  # joins the drain thread, raises drain errors
        except BaseException as e:
            errors.append(e)
        if errors:
            raise errors[0]
