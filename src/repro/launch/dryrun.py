import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the real step function (train / prefill / decode),
give every input a ShapeDtypeStruct stand-in (weak-type-correct, shardable,
zero allocation), lower under the production mesh, compile, and record:

* ``memory_analysis()``  — proves the cell fits per-device HBM,
* ``cost_analysis()``    — per-device FLOPs/bytes for §Roofline,
* collective schedule    — parsed from the partitioned HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                    # all cells, both meshes
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only --out reports/dryrun.json
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, get_config, runnable_cells
from ..models.registry import model_fns
from ..roofline.analysis import analyze_compiled, model_flops_for
from ..sharding.rules import ShardingCtx
from ..train import steps as steps_lib
from ..train.optimizer import OptConfig
from .mesh import devices_per_pod, make_production_mesh


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg, shape, kind: str) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S + 1), jnp.int32)
    elif kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        T = cfg.modality_seq or 1024
        specs["frames"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16)
    return specs


def _opt_cfg_for(cfg) -> OptConfig:
    # int8 optimizer states for the >=300B arch so one pod fits (DESIGN.md §3)
    if cfg.param_count() > 3e11:
        return OptConfig(state_dtype="int8")
    return OptConfig(state_dtype="float32")


def _microbatch_for(cfg) -> int:
    # gradient accumulation for the big train cells (activation memory /M)
    n = cfg.param_count()
    if n > 1e11:
        return 8
    if n > 1e10 or cfg.is_moe:   # MoE dispatch buffers scale with tokens
        return 4
    if cfg.padded_vocab >= 150_000 or cfg.family == "encdec":
        return 4                  # giant-vocab logits / enc+dec double stacks
    if n > 3e9:
        return 2
    return 1


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------
def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    rules_overrides: Optional[Dict] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    donate: bool = True,
    head_dim_fallback: bool = True,
    microbatch: Optional[int] = None,
):
    """Lower + compile one cell. Returns (lowered, compiled, meta dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    kind = shape.kind
    fns = model_fns(cfg)
    ctx = ShardingCtx(mesh=mesh, head_dim_fallback=head_dim_fallback)
    if kind == "decode":
        ctx = ctx.with_rules(kv_seq="model")
    if kind == "train":
        # sequence-parallel residual stream: per-layer activations saved for
        # the backward pass shard 16-way over 'model' — without this the
        # >=32-layer archs cannot hold remat residuals in 16 GiB HBM.
        ctx = ctx.with_rules(res_seq="model")
    if rules_overrides:
        ctx = ctx.with_rules(**rules_overrides)

    specs = input_specs(cfg, shape, kind)
    batch_sh = steps_lib.batch_shardings(cfg, ctx, specs)
    rng = jax.random.PRNGKey(0)

    if kind == "train":
        opt_cfg = _opt_cfg_for(cfg)
        state_shapes = jax.eval_shape(
            lambda: steps_lib.init_train_state(rng, cfg, opt_cfg))
        st_sh = steps_lib.state_shardings(cfg, ctx, state_shapes)
        step = steps_lib.make_train_step(cfg, opt_cfg, ctx,
                                         q_chunk=q_chunk, kv_chunk=kv_chunk,
                                         microbatch=microbatch
                                         if microbatch is not None
                                         else _microbatch_for(cfg))
        jitted = jax.jit(
            step, in_shardings=(st_sh, batch_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,) if donate else (),
        )
        with mesh:
            lowered = jitted.lower(state_shapes, specs)
    else:
        params_shapes = jax.eval_shape(lambda: fns.init_params(rng, cfg))
        p_sh = steps_lib.params_shardings(cfg, ctx, params_shapes)
        B, S = shape.global_batch, shape.seq_len
        if cfg.family == "encdec":
            enc_len = cfg.modality_seq or 1024
            cache_shapes = jax.eval_shape(
                lambda: fns.init_cache(cfg, B, S, enc_len))
        else:
            cache_shapes = jax.eval_shape(lambda: fns.init_cache(cfg, B, S))
        c_sh = steps_lib.cache_shardings(cfg, ctx, cache_shapes)
        if kind == "prefill":
            step = steps_lib.make_prefill_step(cfg, ctx, q_chunk=q_chunk,
                                               kv_chunk=kv_chunk)
            jitted = jax.jit(
                step, in_shardings=(p_sh, batch_sh, c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,) if donate else (),
            )
            with mesh:
                lowered = jitted.lower(params_shapes, specs, cache_shapes)
        else:
            step = steps_lib.make_decode_step(cfg, ctx)
            jitted = jax.jit(
                step, in_shardings=(p_sh, batch_sh["tokens"], c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,) if donate else (),
            )
            with mesh:
                lowered = jitted.lower(params_shapes, specs["tokens"],
                                       cache_shapes)

    compiled = lowered.compile()
    return lowered, compiled, dict(cfg=cfg, shape=shape, kind=kind)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rules_overrides: Optional[Dict] = None,
             q_chunk: int = 1024, kv_chunk: int = 1024,
             head_dim_fallback: bool = True,
             microbatch: Optional[int] = None) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.monotonic()
    lowered, compiled, meta = build_cell(
        arch, shape_name, mesh, rules_overrides=rules_overrides,
        q_chunk=q_chunk, kv_chunk=kv_chunk,
        head_dim_fallback=head_dim_fallback, microbatch=microbatch)
    compile_s = time.monotonic() - t0
    rep = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, devices_per_pod=devices_per_pod(mesh),
        model_flops=model_flops_for(meta["cfg"], meta["shape"], meta["kind"]),
    )
    out = rep.to_dict()
    out["compile_s"] = compile_s
    out["status"] = "ok"
    return out


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default="reports/dryrun.json")
    ap.add_argument("--append", action="store_true",
                    help="merge into an existing report file")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--kv-chunk", type=int, default=512)
    args = ap.parse_args()

    try:
        import os as _os
        cache_dir = "/tmp/jax_cache"
        _os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 5)
    except Exception:
        pass

    archs = [args.arch] if args.arch else list(ARCHS)
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))

    def key(r):
        return (r["arch"], r["shape"], r["mesh"])

    done = {key(r) for r in results if r.get("status") == "ok"}

    for arch in archs:
        cfg = get_config(arch)
        cells = runnable_cells(cfg)
        shapes = [args.shape] if args.shape else cells
        for shape_name in shapes:
            if shape_name not in cells:
                print(f"SKIP {arch} x {shape_name}: not runnable "
                      f"(full attention at 500k — see DESIGN.md §4)")
                continue
            for multi_pod in meshes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                if (arch, shape_name, mesh_name) in done:
                    print(f"cached {arch} x {shape_name} x {mesh_name}")
                    continue
                print(f"=== {arch} x {shape_name} x {mesh_name} ===", flush=True)
                try:
                    r = run_cell(arch, shape_name, multi_pod=multi_pod,
                                 q_chunk=args.q_chunk, kv_chunk=args.kv_chunk)
                    mem = r["memory_per_device"] or {}
                    print(
                        f"  ok in {r['compile_s']:.1f}s | "
                        f"t_comp={r['t_compute']*1e3:.2f}ms "
                        f"t_mem={r['t_memory']*1e3:.2f}ms "
                        f"t_coll={r['t_collective']*1e3:.2f}ms "
                        f"bottleneck={r['bottleneck']} "
                        f"| args/dev={mem.get('argument', 0)/2**30:.2f}GiB "
                        f"temp/dev={mem.get('temp', 0)/2**30:.2f}GiB",
                        flush=True,
                    )
                except Exception as e:
                    r = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                             status="error", error=f"{type(e).__name__}: {e}",
                             traceback=traceback.format_exc()[-2000:])
                    print(f"  ERROR: {type(e).__name__}: {e}", flush=True)
                results = [x for x in results if key(x) != (arch, shape_name, mesh_name)]
                results.append(r)
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=str)

    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{n_ok}/{len(results)} cells compiled OK -> {args.out}")


if __name__ == "__main__":
    main()
