"""Process-wide metrics registry: counters, gauges, streaming histograms.

Where :mod:`repro.trace` answers *what happened* (a post-hoc span log),
``repro.metrics`` answers *what is happening now*: is the prefetch buffer
starved, is the ReaderPool saturated, is the burst-buffer drain falling
behind.  tf-Darshan (arXiv:2008.04395) argues DL I/O needs always-on,
low-overhead performance data; this module is the always-on half.

Design constraints (same discipline as the tracer):

* **Near-zero overhead when disabled.**  The module-level :func:`inc` /
  :func:`observe` / :func:`set_gauge` / :func:`timer` helpers check one
  global and return immediately (or hand back a shared no-op singleton) —
  no allocation, nothing to GC.  Instrumented call sites stay in hot paths
  permanently.
* **Lock-free hot path when enabled.**  :class:`Counter` and
  :class:`Histogram` shard their state per thread (a cell is registered
  once per thread under a lock, then mutated lock-free under the GIL);
  reads merge the shards.  Many threads bumping one counter never contend.
* **Bounded memory.**  Histograms are fixed log-bucket sketches (DDSketch
  geometry): ``observe(v)`` lands in bucket ``ceil(log_gamma(v))`` with
  ``gamma = (1+alpha)/(1-alpha)``, so any quantile is recoverable to a
  **relative error <= alpha** without storing samples, and sketches from
  different threads merge by adding bucket counts.

Instruments are keyed by ``(name, labels)`` — Prometheus-style — so one
metric family (``storage.read_bytes``) carries per-tier series
(``{tier="hdd"}``).  :meth:`MetricsRegistry.collect` snapshots everything
into a plain dict the exporters (:mod:`repro.metrics.export`) and the
:class:`~repro.metrics.sampler.Sampler` consume.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def render_name(name: str, labels: LabelKey = ()) -> str:
    """Canonical ``name{k="v",...}`` rendering used as the snapshot key."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


def parse_name(rendered: str) -> Tuple[str, LabelKey]:
    """Inverse of :func:`render_name` (exporters round-trip through this)."""
    if "{" not in rendered:
        return rendered, ()
    name, _, rest = rendered.partition("{")
    rest = rest.rstrip("}")
    labels = []
    for part in filter(None, rest.split(",")):
        k, _, v = part.partition("=")
        labels.append((k, v.strip('"')))
    return name, tuple(sorted(labels))


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------
class _Cell:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0


class Counter:
    """Monotonic counter, sharded per thread (lock only on first touch)."""

    def __init__(self):
        self._local = threading.local()
        self._lock = threading.Lock()
        self._cells: List[_Cell] = []

    def _cell(self) -> _Cell:
        c = getattr(self._local, "cell", None)
        if c is None:
            c = _Cell()
            with self._lock:
                self._cells.append(c)
            self._local.cell = c
        return c

    def inc(self, value: float = 1.0) -> None:
        if value < 0:
            raise ValueError(f"counters only go up, got {value}")
        self._cell().value += value

    def value(self) -> float:
        with self._lock:
            return float(sum(c.value for c in self._cells))


class Gauge:
    """Point-in-time value: ``set()`` replaces, ``add()`` accumulates
    (e.g. a backlog that grows on enqueue and shrinks on drain)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, delta: float) -> None:
        with self._lock:
            self._value += delta

    def value(self) -> float:
        with self._lock:
            return self._value


class FunctionGauge:
    """Gauge polled at collect time (pool size, queue depth, ...)."""

    def __init__(self, fn: Callable[[], float]):
        self._fn = fn

    def value(self) -> Optional[float]:
        try:
            return float(self._fn())
        except Exception:
            return None  # a dead provider must not poison collection


class _HistShard:
    __slots__ = ("buckets", "count", "sum", "min", "max", "zero")

    def __init__(self):
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.zero = 0  # values <= 0 (log bucket undefined)


class Histogram:
    """Streaming log-bucket sketch (DDSketch geometry), per-thread sharded.

    ``observe(v)`` costs one ``math.log``, one dict increment and a few
    scalar updates — no samples are retained.  ``quantile(q)`` merges the
    thread shards and walks the cumulative bucket counts; the returned
    estimate is the bucket midpoint ``2*gamma^i/(gamma+1)``, which is
    within ``alpha`` relative error of the true sample at that rank.
    """

    def __init__(self, alpha: float = 0.05):
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        self.alpha = alpha
        self.gamma = (1.0 + alpha) / (1.0 - alpha)
        self._lgamma = math.log(self.gamma)
        self._local = threading.local()
        self._lock = threading.Lock()
        self._shards: List[_HistShard] = []

    def _shard(self) -> _HistShard:
        s = getattr(self._local, "shard", None)
        if s is None:
            s = _HistShard()
            with self._lock:
                self._shards.append(s)
            self._local.shard = s
        return s

    def observe(self, value: float) -> None:
        s = self._shard()
        v = float(value)
        s.count += 1
        s.sum += v
        if v < s.min:
            s.min = v
        if v > s.max:
            s.max = v
        if v <= 0.0:
            s.zero += 1
            return
        idx = math.ceil(math.log(v) / self._lgamma)
        s.buckets[idx] = s.buckets.get(idx, 0) + 1

    # -- merged views --------------------------------------------------------
    def snapshot(self) -> dict:
        """Merge all thread shards into a plain-dict sketch (the exchange
        format: JSON-serializable, mergeable, quantile-queryable)."""
        with self._lock:
            shards = list(self._shards)
        buckets: Dict[int, int] = {}
        count = 0
        total = 0.0
        vmin = math.inf
        vmax = -math.inf
        zero = 0
        for s in shards:
            count += s.count
            total += s.sum
            zero += s.zero
            if s.min < vmin:
                vmin = s.min
            if s.max > vmax:
                vmax = s.max
            for idx, n in s.buckets.items():
                buckets[idx] = buckets.get(idx, 0) + n
        return dict(
            gamma=self.gamma,
            count=count,
            sum=total,
            min=(vmin if count else 0.0),
            max=(vmax if count else 0.0),
            zero=zero,
            buckets=buckets,
        )

    def quantile(self, q: float) -> float:
        return hist_quantile(self.snapshot(), q)

    def count(self) -> int:
        return int(self.snapshot()["count"])


def hist_quantile(snap: dict, q: float) -> float:
    """Quantile from a sketch snapshot (works on live or deserialized
    sketches; JSON round-trips may have stringified bucket keys)."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    count = snap["count"]
    if count == 0:
        return 0.0
    gamma = snap["gamma"]
    rank = max(0, math.ceil(q / 100.0 * count) - 1)  # 0-based target rank
    if rank < snap["zero"]:
        return min(0.0, snap["min"])
    # JSON round-trips stringify the bucket keys — normalize to ints
    buckets = {int(k): v for k, v in snap["buckets"].items()}
    seen = snap["zero"]
    for idx in sorted(buckets):
        seen += buckets[idx]
        if rank < seen:
            # bucket i covers (gamma^(i-1), gamma^i]; midpoint minimizes
            # worst-case relative error to alpha
            est = 2.0 * gamma ** idx / (gamma + 1.0)
            return min(max(est, snap["min"]), snap["max"])
    return snap["max"]


def merge_hist_snapshots(a: dict, b: dict) -> dict:
    """Merge two sketches (same gamma) — cross-process/thread aggregation."""
    if a["gamma"] != b["gamma"]:
        raise ValueError("cannot merge sketches with different gamma")
    buckets = {int(k): v for k, v in a["buckets"].items()}
    for k, v in b["buckets"].items():
        k = int(k)
        buckets[k] = buckets.get(k, 0) + v
    count = a["count"] + b["count"]
    return dict(
        gamma=a["gamma"],
        count=count,
        sum=a["sum"] + b["sum"],
        min=(min(a["min"], b["min"]) if count else 0.0),
        max=(max(a["max"], b["max"]) if count else 0.0),
        zero=a["zero"] + b["zero"],
        buckets=buckets,
    )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
class MetricsRegistry:
    """Thread-safe instrument registry.

    Instrument creation takes a lock once per ``(name, labels)``; the
    returned instruments are lock-free on their hot paths.  ``collect()``
    snapshots every instrument into a plain dict keyed by the canonical
    rendered name.
    """

    def __init__(self, enabled: bool = True, alpha: float = 0.05):
        self.enabled = enabled
        self.alpha = alpha
        self._epoch = time.monotonic()
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, LabelKey], Counter] = {}
        self._gauges: Dict[Tuple[str, LabelKey], Gauge] = {}
        self._fn_gauges: Dict[Tuple[str, LabelKey], FunctionGauge] = {}
        self._hists: Dict[Tuple[str, LabelKey], Histogram] = {}

    # -- instrument access (get-or-create) -----------------------------------
    def counter(self, name: str, **labels) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter())
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge())
        return g

    def histogram(self, name: str, alpha: Optional[float] = None,
                  **labels) -> Histogram:
        key = (name, _label_key(labels))
        h = self._hists.get(key)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(
                    key, Histogram(self.alpha if alpha is None else alpha))
        return h

    def register_gauge(self, name: str, fn: Callable[[], float],
                       **labels) -> None:
        """Register a polled gauge callback (replaces any previous one
        under the same name+labels)."""
        key = (name, _label_key(labels))
        with self._lock:
            self._fn_gauges[key] = FunctionGauge(fn)

    def unregister_gauge(self, name: str, **labels) -> None:
        key = (name, _label_key(labels))
        with self._lock:
            self._fn_gauges.pop(key, None)

    # -- snapshot -------------------------------------------------------------
    def collect(self) -> dict:
        """Snapshot all instruments: ``{"t", "counters", "gauges",
        "histograms"}`` with canonical rendered-name keys."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            fn_gauges = dict(self._fn_gauges)
            hists = dict(self._hists)
        out_g: Dict[str, float] = {}
        for (name, labels), g in gauges.items():
            out_g[render_name(name, labels)] = g.value()
        for (name, labels), fg in fn_gauges.items():
            v = fg.value()
            if v is not None:
                out_g[render_name(name, labels)] = v
        return dict(
            t=time.monotonic() - self._epoch,
            counters={render_name(n, ls): c.value()
                      for (n, ls), c in counters.items()},
            gauges=out_g,
            histograms={render_name(n, ls): h.snapshot()
                        for (n, ls), h in hists.items()},
        )


# ---------------------------------------------------------------------------
# Module-level API (what instrumented call sites use)
# ---------------------------------------------------------------------------
class _NullMetric:
    """Shared do-nothing instrument/context for the disabled path."""

    __slots__ = ()

    def inc(self, value: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def add(self, delta: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def __enter__(self) -> "_NullMetric":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_METRIC = _NullMetric()


class _Timer:
    """Context manager that observes its wall time into a histogram."""

    __slots__ = ("_hist", "_t0")

    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self) -> "_Timer":
        self._t0 = time.monotonic()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._hist.observe(time.monotonic() - self._t0)
        return False


_active: Optional[MetricsRegistry] = None


def get_registry() -> Optional[MetricsRegistry]:
    return _active


def set_registry(reg: Optional[MetricsRegistry]) -> Optional[MetricsRegistry]:
    global _active
    _active = reg
    return reg


def start(enabled: bool = True, alpha: float = 0.05) -> MetricsRegistry:
    """Install (and return) a fresh global registry; persistent gauge
    providers (see :func:`register_gauge`) re-attach automatically."""
    reg = set_registry(MetricsRegistry(enabled=enabled, alpha=alpha))
    _attach_providers(reg)
    return reg


def stop() -> Optional[MetricsRegistry]:
    """Uninstall the global registry (its instruments stay readable)."""
    global _active
    r, _active = _active, None
    return r


def enabled() -> bool:
    r = _active
    return r is not None and r.enabled


def inc(name: str, value: float = 1.0, **labels) -> None:
    r = _active
    if r is not None and r.enabled:
        r.counter(name, **labels).inc(value)


def observe(name: str, value: float, **labels) -> None:
    r = _active
    if r is not None and r.enabled:
        r.histogram(name, **labels).observe(value)


def set_gauge(name: str, value: float, **labels) -> None:
    r = _active
    if r is not None and r.enabled:
        r.gauge(name, **labels).set(value)


def add_gauge(name: str, delta: float, **labels) -> None:
    r = _active
    if r is not None and r.enabled:
        r.gauge(name, **labels).add(delta)


def timer(name: str, **labels):
    """``with metrics.timer("pipeline.decode_s"):`` — observes wall time
    into a histogram; the shared no-op singleton when disabled."""
    r = _active
    if r is None or not r.enabled:
        return NULL_METRIC
    return _Timer(r.histogram(name, **labels))


def register_gauge(name: str, fn: Callable[[], float], **labels) -> None:
    """Register a polled gauge provider.

    Providers are remembered even while no registry is installed (the
    process-global ReaderPool may outlive many ``start()``/``stop()``
    cycles), and re-attach to every subsequently started registry."""
    with _providers_lock:
        _providers[(name, _label_key(labels))] = fn
    r = _active
    if r is not None:
        r.register_gauge(name, fn, **labels)


def unregister_gauge(name: str, **labels) -> None:
    with _providers_lock:
        _providers.pop((name, _label_key(labels)), None)
    r = _active
    if r is not None:
        r.unregister_gauge(name, **labels)


_providers: Dict[Tuple[str, LabelKey], Callable[[], float]] = {}
_providers_lock = threading.Lock()


def _attach_providers(reg: MetricsRegistry) -> None:
    with _providers_lock:
        items = list(_providers.items())
    for (name, labels), fn in items:
        reg.register_gauge(name, fn, **dict(labels))
