"""repro.metrics — live telemetry: the always-on half of observability.

:mod:`repro.trace` records spans for post-hoc attribution; this package
answers *live* questions with bounded memory and near-zero disabled cost:

* :mod:`repro.metrics.registry` — the instrument registry.
  :class:`Counter` and :class:`Histogram` (log-bucket sketch: p50/p95/p99
  to <= ``alpha`` relative error without storing samples, mergeable across
  threads) shard per thread; :class:`Gauge` is push-style,
  :class:`FunctionGauge` is polled at collect time.  Module-level
  :func:`inc` / :func:`observe` / :func:`set_gauge` / :func:`add_gauge` /
  :func:`timer` are the hot-path hooks — one global check and a shared
  no-op singleton when metrics are off.
* :mod:`repro.metrics.sampler` — background :class:`Sampler` thread:
  periodic ``collect()`` snapshots into a bounded series + JSONL sink.
* :mod:`repro.metrics.export` — Prometheus text exposition
  (:func:`to_prometheus_text` / :func:`from_prometheus_text`) and lossless
  JSONL snapshots (:func:`dump_jsonl` / :func:`load_jsonl`).
* :mod:`repro.metrics.stall` — :class:`StallDetector`: rolling-percentile
  step-duration watchdog that dumps a metrics+trace snapshot when tripped.

Instrumented producers: ``core/readerpool.py`` (size, queue depth,
in-flight), ``core/prefetcher.py`` (occupancy, producer stall, consumer
wait), ``core/dataset.py`` (records, decode latency, drops),
``core/storage.py`` (+ ``faults.py``: per-tier ops/bytes/latency, injected
faults), ``core/async_checkpoint.py`` / ``core/burst_buffer.py`` (pending
saves, snapshot/write/drain latency, drain backlog bytes),
``train/trainer.py`` (per-step heartbeat + stall detection).

Typical use::

    from repro import metrics

    reg = metrics.start()                    # install global registry
    sampler = metrics.Sampler(interval_s=0.5,
                              jsonl_path="reports/metrics.jsonl").start()
    ...run pipeline / training...
    sampler.stop()
    print(metrics.to_prometheus_text(reg))
    metrics.stop()
"""
from .registry import (
    NULL_METRIC,
    Counter,
    FunctionGauge,
    Gauge,
    Histogram,
    MetricsRegistry,
    add_gauge,
    enabled,
    get_registry,
    hist_quantile,
    inc,
    merge_hist_snapshots,
    observe,
    parse_name,
    register_gauge,
    render_name,
    set_gauge,
    set_registry,
    start,
    stop,
    timer,
    unregister_gauge,
)
from .export import (
    dump_jsonl,
    from_prometheus_text,
    hist_le_buckets,
    load_jsonl,
    series_markdown,
    snapshot_from_json,
    snapshot_to_json,
    to_prometheus_text,
)
from .sampler import Sampler
from .stall import StallDetector, StallEvent

__all__ = [
    # registry
    "MetricsRegistry", "Counter", "Gauge", "FunctionGauge", "Histogram",
    "NULL_METRIC", "hist_quantile", "merge_hist_snapshots",
    "render_name", "parse_name",
    # module-level hooks
    "start", "stop", "enabled", "get_registry", "set_registry",
    "inc", "observe", "set_gauge", "add_gauge", "timer",
    "register_gauge", "unregister_gauge",
    # export
    "to_prometheus_text", "from_prometheus_text", "hist_le_buckets",
    "dump_jsonl", "load_jsonl", "snapshot_to_json", "snapshot_from_json",
    "series_markdown",
    # sampler / stall
    "Sampler", "StallDetector", "StallEvent",
]
