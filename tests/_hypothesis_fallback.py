"""Deterministic mini-fallback for ``hypothesis`` on bare environments.

The real hypothesis (installed via the ``test`` extra in pyproject.toml)
shrinks failures and explores the strategy space adaptively; this shim only
replays a fixed pseudo-random sample of each strategy so the property tests
still *run* — with reproducible examples — when the package is absent.
Only the strategy combinators this suite actually uses are implemented.

Usage (at the top of a test module)::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_fallback import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import random
from types import SimpleNamespace

_SEED = 0xD5EED
_DEFAULT_EXAMPLES = 20
_MAX_EXAMPLES_CAP = 50  # keep bare-env runtime bounded


class _Strategy:
    __slots__ = ("draw",)

    def __init__(self, draw):
        self.draw = draw  # draw(rng) -> value


def _integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _floats(min_value, max_value, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def _booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: r.choice(seq))


def _binary(min_size=0, max_size=16):
    return _Strategy(
        lambda r: bytes(r.getrandbits(8)
                        for _ in range(r.randint(min_size, max_size)))
    )


def _lists(elements, min_size=0, max_size=16):
    return _Strategy(
        lambda r: [elements.draw(r)
                   for _ in range(r.randint(min_size, max_size))]
    )


strategies = SimpleNamespace(
    integers=_integers,
    floats=_floats,
    booleans=_booleans,
    sampled_from=_sampled_from,
    binary=_binary,
    lists=_lists,
)


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may sit above or below @given; check both targets
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
            rng = random.Random(_SEED)
            for _ in range(min(n, _MAX_EXAMPLES_CAP)):
                drawn = [s.draw(rng) for s in arg_strategies]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)
        # pytest must not see the strategy params as fixtures: hide the
        # wrapped signature so inspection falls back to (*args, **kwargs)
        del wrapper.__wrapped__
        return wrapper
    return deco
