"""Exporters: Prometheus text exposition + JSONL snapshots.

Two formats, two purposes:

* **Prometheus text** (:func:`to_prometheus_text`) — the live-scrape view.
  Counters and gauges render as ``name{labels} value``; histograms render
  in the standard cumulative form (``_bucket{le="..."}`` rows from the
  sketch's log buckets, plus ``_sum``/``_count``).  :func:`from_prometheus_
  text` parses the same schema back, and rendering is canonical (sorted,
  ``repr`` floats), so ``text -> parse -> render`` is the identity — the
  round-trip tests rely on this.
* **JSONL** (:func:`dump_jsonl` / :func:`load_jsonl`) — the archival view:
  one JSON object per line, each a full ``MetricsRegistry.collect()``
  snapshot *including raw sketch buckets*, so quantiles recompute exactly
  after a round-trip.  The :class:`~repro.metrics.sampler.Sampler` appends
  one line per tick, giving a time series CI uploads as an artifact.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Tuple, Union

from .registry import MetricsRegistry, hist_quantile, parse_name, render_name


def _sanitize(name: str) -> str:
    """Prometheus metric names: dots become underscores."""
    return name.replace(".", "_").replace("-", "_")


def _fmt(v: float) -> str:
    """Canonical float rendering (repr round-trips exactly in Python)."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def hist_le_buckets(snap: dict) -> List[Tuple[float, int]]:
    """Cumulative ``(le_upper_bound, count)`` pairs for one sketch snapshot
    (the Prometheus histogram series, shared with the round-trip tests)."""
    gamma = snap["gamma"]
    buckets = {int(k): v for k, v in snap["buckets"].items()}
    out: List[Tuple[float, int]] = []
    cum = snap["zero"]
    if cum:
        out.append((0.0, cum))
    for idx in sorted(buckets):
        cum += buckets[idx]
        out.append((gamma ** idx, cum))
    return out


def to_prometheus_text(snapshot: Union[dict, MetricsRegistry]) -> str:
    """Render a ``collect()`` snapshot (or a live registry) as Prometheus
    text exposition."""
    if isinstance(snapshot, MetricsRegistry):
        snapshot = snapshot.collect()
    lines: List[str] = []
    typed: set = set()  # one "# TYPE" line per metric family

    def _type(base: str, kind: str) -> None:
        if base not in typed:
            typed.add(base)
            lines.append(f"# TYPE {base} {kind}")

    def _series(rendered: str, value: float, extra_label: str = "") -> str:
        name, labels = parse_name(rendered)
        labels = list(labels)
        if extra_label:
            k, v = extra_label.split("=", 1)
            labels.append((k, v))
        return f"{render_name(_sanitize(name), tuple(labels))} {_fmt(value)}"

    for rendered in sorted(snapshot.get("counters", {})):
        name, _ = parse_name(rendered)
        _type(_sanitize(name), "counter")
        lines.append(_series(rendered, snapshot["counters"][rendered]))
    for rendered in sorted(snapshot.get("gauges", {})):
        name, _ = parse_name(rendered)
        _type(_sanitize(name), "gauge")
        lines.append(_series(rendered, snapshot["gauges"][rendered]))
    for rendered in sorted(snapshot.get("histograms", {})):
        hsnap = snapshot["histograms"][rendered]
        name, labels = parse_name(rendered)
        base = _sanitize(name)
        _type(base, "histogram")
        for le, cum in hist_le_buckets(hsnap):
            lines.append(_series(
                render_name(f"{base}_bucket", labels), cum,
                extra_label=f"le={_fmt(le)}"))
        lines.append(_series(
            render_name(f"{base}_bucket", labels), hsnap["count"],
            extra_label="le=+Inf"))
        lines.append(_series(render_name(f"{base}_sum", labels),
                             hsnap["sum"]))
        lines.append(_series(render_name(f"{base}_count", labels),
                             hsnap["count"]))
    return "\n".join(lines) + "\n"


def from_prometheus_text(text: str) -> dict:
    """Parse Prometheus text exposition back into a snapshot-shaped dict.

    Histograms come back in cumulative ``le``-bucket form (the sketch's
    internal log indices are not recoverable from the exposition), keyed
    under ``"histograms_le"``: ``{rendered_name: {"buckets": [(le, cum)],
    "sum": s, "count": n}}``.  Counters and gauges round-trip exactly.
    """
    types: Dict[str, str] = {}
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: Dict[str, dict] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        series, _, value = line.rpartition(" ")
        name, labels = parse_name(series)
        v = float(value)
        base, kind = name, None
        for suffix in ("_bucket", "_sum", "_count"):
            stem = name[: -len(suffix)] if name.endswith(suffix) else None
            if stem is not None and types.get(stem) == "histogram":
                base, kind = stem, suffix
                break
        if kind is not None:
            le = [lv for lk, lv in labels if lk == "le"]
            rest = tuple((lk, lv) for lk, lv in labels if lk != "le")
            h = hists.setdefault(render_name(base, rest),
                                 {"buckets": [], "sum": 0.0, "count": 0})
            if kind == "_bucket":
                if le and le[0] != "+Inf":
                    h["buckets"].append((float(le[0]), int(v)))
            elif kind == "_sum":
                h["sum"] = v
            else:
                h["count"] = int(v)
        elif types.get(name) == "counter":
            counters[series] = v
        else:
            gauges[series] = v
    for h in hists.values():
        h["buckets"].sort()
    return dict(counters=counters, gauges=gauges, histograms_le=hists)


# ---------------------------------------------------------------------------
# JSONL snapshots
# ---------------------------------------------------------------------------
def snapshot_to_json(snapshot: dict) -> str:
    """One snapshot -> one JSON line (sketch buckets included: lossless)."""
    return json.dumps(snapshot, sort_keys=True, separators=(",", ":"))


def snapshot_from_json(line: str) -> dict:
    """Inverse of :func:`snapshot_to_json`; bucket keys re-int-ified."""
    snap = json.loads(line)
    for h in snap.get("histograms", {}).values():
        h["buckets"] = {int(k): v for k, v in h["buckets"].items()}
    return snap


def dump_jsonl(snapshots: Iterable[dict], path: str) -> None:
    with open(path, "w") as f:
        for snap in snapshots:
            f.write(snapshot_to_json(snap) + "\n")


def load_jsonl(path: str) -> List[dict]:
    out: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(snapshot_from_json(line))
    return out


# ---------------------------------------------------------------------------
# Trace-report attachment
# ---------------------------------------------------------------------------
def series_markdown(snapshots: List[dict], max_gauges: int = 32) -> List[str]:
    """Render a sampled snapshot series as markdown lines — the gauge
    timeline section :func:`repro.trace.report.to_markdown` attaches under
    a per-stage span report (fig8: occupancy/backlog alongside spans)."""
    if not snapshots:
        return ["_no metric samples_"]
    names: List[str] = []
    for snap in snapshots:
        for k in snap.get("gauges", {}):
            if k not in names:
                names.append(k)
    lines = [f"{len(snapshots)} samples, "
             f"t={snapshots[0].get('t', 0.0):.2f}s .. "
             f"{snapshots[-1].get('t', 0.0):.2f}s", ""]
    for name in names[:max_gauges]:
        vals = [s["gauges"][name] for s in snapshots
                if name in s.get("gauges", {})]
        if not vals:
            continue
        lines.append(
            f"- `{name}`: first={vals[0]:.3g} last={vals[-1]:.3g} "
            f"min={min(vals):.3g} max={max(vals):.3g} ({len(vals)} pts)")
    last = snapshots[-1]
    if last.get("counters"):
        lines += ["", "final counters:", ""]
        for k in sorted(last["counters"]):
            lines.append(f"- `{k}` = {last['counters'][k]:.6g}")
    if last.get("histograms"):
        lines += ["", "final latency sketches (p50/p95/p99 ms):", ""]
        for k in sorted(last["histograms"]):
            h = last["histograms"][k]
            lines.append(
                f"- `{k}`: n={h['count']} "
                f"p50={hist_quantile(h, 50) * 1e3:.2f} "
                f"p95={hist_quantile(h, 95) * 1e3:.2f} "
                f"p99={hist_quantile(h, 99) * 1e3:.2f}")
    return lines
