"""Fig. 12 (ours): the full checkpoint-engine matrix, per storage tier.

Fig. 10 showed async snapshot checkpointing beats the paper's burst buffer
on training-thread blocked time; this benchmark closes the matrix with the
fused engine.  For each slow tier in hdd/ssd/optane/lustre, run the same
synthetic training loop under four strategies:

* ``direct``   — synchronous :class:`DirectCheckpointer` to the tier;
* ``bb``       — :class:`BurstBufferCheckpointer` (optane stage, blocking,
  + multi-stream background drain to the tier);
* ``async``    — :class:`AsyncCheckpointer` straight to the tier (snapshot
  blocks, sharded write in background);
* ``asyncbb``  — :class:`AsyncBurstBufferCheckpointer` (snapshot blocks;
  optane stage *and* the intra-file parallel drain both run in
  background threads).

Per strategy/tier we emit runtime, total training-thread blocked seconds,
post-loop drain time, effective steps/s, and the checkpoint/compute overlap
ratio from the trace.  Machine-readable ``BENCH_async_bb.json`` feeds the
CI regression gate: ``steps_per_s`` (throughput) and ``blocked_frac_saved``
(1 - asyncbb blocked / direct blocked — the headline win, robust to box
speed because it is a ratio) are the gated leaves.

Acceptance: on the hdd model, asyncbb total blocked time <= 0.5x the plain
burst buffer's (<= 0.6x in --smoke: tiny payloads make the snapshot a
bigger slice).  The burst buffer already hides the slow tier; asyncbb must
additionally hide the fast-tier write itself.

    PYTHONPATH=src python -m benchmarks.fig12_async_bb [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

from repro import trace
from repro.core import make_storage
from repro.core.async_burst_buffer import AsyncBurstBufferCheckpointer
from repro.core.async_checkpoint import AsyncCheckpointer
from repro.core.burst_buffer import BurstBufferCheckpointer, DirectCheckpointer

from .common import RESULTS_DIR, SCRATCH, emit

import numpy as np

CKPT_TIME_SCALE = float(os.environ.get("REPRO_CKPT_TIME_SCALE", "1.0"))
TIERS = ("hdd", "ssd", "optane", "lustre")
STRATEGIES = ("direct", "bb", "async", "asyncbb")


def make_state(layers: int, mb_each: int):
    rng = np.random.default_rng(0)
    return {
        f"layer{i}":
            rng.normal(size=(mb_each * 1024 * 256,)).astype(np.float32)
        for i in range(layers)
    }


def run_one(checkpointer, state, n_iters, ckpt_every, compute_s):
    """Synthetic training loop; returns (runtime_s, post_loop_drain_s)."""
    t0 = time.monotonic()
    for i in range(1, n_iters + 1):
        with trace.span(trace.STAGE_COMPUTE, "train_step"):
            time.sleep(compute_s)
        if i % ckpt_every == 0:
            checkpointer.save(i, state)
    runtime = time.monotonic() - t0
    t1 = time.monotonic()
    checkpointer.wait()
    drain = time.monotonic() - t1
    checkpointer.close()
    return runtime, drain


def ckpt_overlap(spans) -> float:
    """Fraction of write/stage/drain busy time overlapped by compute."""
    return trace.overlap_ratio(
        spans,
        fg_stages=(trace.STAGE_CKPT_WRITE, trace.STAGE_STAGE,
                   trace.STAGE_DRAIN),
        bg_stages=(trace.STAGE_COMPUTE,),
    )


def run(n_iters=9, ckpt_every=3, compute_s=0.05, state_layers=4,
        state_mb_each=2, smoke=False, name="fig12_async_bb",
        json_path=None) -> dict:
    state = make_state(state_layers, state_mb_each)
    rows = []
    tiers_out = {}

    with tempfile.TemporaryDirectory(dir=SCRATCH) as root:
        def storage(tag, kind):
            return make_storage(kind, os.path.join(root, tag),
                                time_scale=CKPT_TIME_SCALE)

        for tier in TIERS:
            makers = {
                "direct": lambda: DirectCheckpointer(
                    storage(f"direct_{tier}", tier), "ck/m",
                    n_shards=4, io_threads=4),
                "bb": lambda: BurstBufferCheckpointer(
                    storage(f"bb_fast_{tier}", "optane"),
                    storage(f"bb_slow_{tier}", tier), "ck/m",
                    n_shards=4, io_threads=4, drain_streams=4,
                    drain_chunk=1 << 20),
                "async": lambda: AsyncCheckpointer(
                    storage(f"async_{tier}", tier), "ck/m",
                    n_shards=4, io_threads=4),
                "asyncbb": lambda: AsyncBurstBufferCheckpointer(
                    storage(f"abb_fast_{tier}", "optane"),
                    storage(f"abb_slow_{tier}", tier), "ck/m",
                    n_shards=4, io_threads=4, drain_streams=4,
                    drain_chunk=1 << 20),
            }
            per_tier = {}
            for strategy in STRATEGIES:
                tracer = trace.start()
                ck = makers[strategy]()
                runtime, drain = run_one(ck, state, n_iters, ckpt_every,
                                         compute_s)
                trace.stop()
                blocked = sum(ck.blocked_s)
                ov = ckpt_overlap(tracer.spans())
                per_tier[strategy] = {
                    "runtime_s": round(runtime, 4),
                    "blocked_total_s": round(blocked, 4),
                    "post_loop_drain_s": round(drain, 4),
                    "steps_per_s": round(n_iters / runtime, 3),
                    "ckpt_compute_overlap": round(ov, 3),
                }
                rows.append(
                    f"strategy={strategy},tier={tier},runtime_s={runtime:.2f},"
                    f"blocked_s={blocked:.3f},post_loop_drain_s={drain:.2f},"
                    f"steps_per_s={n_iters / runtime:.2f},"
                    f"ckpt_compute_overlap={ov:.2f}")
            # headline ratio: how much of direct's blocked time asyncbb
            # eliminates (1.0 = all of it); a ratio, so box-speed robust
            per_tier["blocked_frac_saved"] = round(max(0.0, 1.0 - (
                per_tier["asyncbb"]["blocked_total_s"]
                / max(per_tier["direct"]["blocked_total_s"], 1e-9))), 4)
            tiers_out[tier] = per_tier

    abb_hdd = tiers_out["hdd"]["asyncbb"]["blocked_total_s"]
    bb_hdd = tiers_out["hdd"]["bb"]["blocked_total_s"]
    bb_ratio = abb_hdd / max(bb_hdd, 1e-9)
    threshold = 0.6 if smoke else 0.5
    derived = (
        f"asyncbb-vs-bb blocked ratio on hdd = {bb_ratio:.3f} "
        f"(acceptance: <={threshold}); blocked_frac_saved vs direct: "
        + ", ".join(f"{t}={tiers_out[t]['blocked_frac_saved']:.3f}"
                    for t in TIERS))
    emit(name, rows, derived)

    payload = {
        "benchmark": name,
        "config": {
            "n_iters": n_iters, "ckpt_every": ckpt_every,
            "compute_s": compute_s, "state_layers": state_layers,
            "state_mb_each": state_mb_each,
            "time_scale": CKPT_TIME_SCALE,
            "tiers": list(TIERS), "strategies": list(STRATEGIES),
        },
        "tiers": tiers_out,
        "asyncbb_vs_bb_blocked_ratio_hdd": round(bb_ratio, 4),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_json = json_path or os.path.join(RESULTS_DIR, "BENCH_async_bb.json")
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_json}")
    return payload


def run_smoke() -> dict:
    """Tiny-scale CI variant: same output shape, seconds of runtime."""
    return run(n_iters=6, ckpt_every=2, compute_s=0.02, state_layers=2,
               state_mb_each=1, smoke=True)


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    payload = run_smoke() if smoke else run()
    ratio = payload["asyncbb_vs_bb_blocked_ratio_hdd"]
    limit = 0.6 if smoke else 0.5
    ok = ratio <= limit
    print(f"# asyncbb/bb blocked ratio (hdd)={ratio} ok={ok}")
    if not ok:
        sys.exit(1)
