"""Vectorized read-engine semantics: interleave / shard / map_and_batch /
ReaderPool reuse / closeable iterators (ISSUE 3 tentpole + satellites)."""
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core import records
from repro.core.dataset import Dataset, image_pipeline, sharded_image_pipeline
from repro.core.microbench import run_microbench, run_sharded_microbench
from repro.core.readerpool import ReaderPool, reader_pool
from repro.core.storage import NativeStorage


def _expand(x):
    return [x * 10 + i for i in range(3)]


class TestInterleave:
    @pytest.mark.parametrize("cycle,block", [(1, 1), (2, 2), (3, 1), (4, 5)])
    def test_parallel_matches_serial(self, cycle, block):
        serial = list(Dataset.range(7).interleave(
            _expand, cycle_length=cycle, block_length=block))
        for npc in (2, 4):
            par = list(Dataset.range(7).interleave(
                _expand, cycle_length=cycle, block_length=block,
                num_parallel_calls=npc))
            assert par == serial

    def test_parallel_deterministic_under_jitter(self):
        def jittery(x):
            def gen():
                for i in range(4):
                    time.sleep(0.001 * ((x + i) % 3))
                    yield x * 100 + i
            return gen()

        runs = [
            list(Dataset.range(6).interleave(
                jittery, cycle_length=3, block_length=2,
                num_parallel_calls=3))
            for _ in range(3)
        ]
        assert runs[0] == runs[1] == runs[2]
        assert sorted(runs[0]) == sorted(
            x * 100 + i for x in range(6) for i in range(4))

    def test_round_robin_block_order(self):
        out = list(Dataset.range(2).interleave(
            _expand, cycle_length=2, block_length=2))
        assert out == [0, 1, 10, 11, 2, 12]

    def test_completeness_covers_all_elements(self):
        out = list(Dataset.range(10).interleave(
            _expand, cycle_length=4, block_length=3, num_parallel_calls=4))
        assert sorted(out) == sorted(x * 10 + i for x in range(10)
                                     for i in range(3))

    def test_fn_error_becomes_element_error(self):
        def boom(x):
            if x == 2:
                raise ValueError("bad shard")
            return _expand(x)

        out = list(Dataset.range(4).interleave(
            boom, cycle_length=2, num_parallel_calls=2).ignore_errors())
        assert sorted(out) == sorted(
            x * 10 + i for x in (0, 1, 3) for i in range(3))
        with pytest.raises(ValueError):
            list(Dataset.range(4).interleave(boom, cycle_length=2))

    def test_mid_stream_error_retires_slot_only(self):
        def poisoned(x):
            def gen():
                yield x * 10
                if x == 1:
                    raise RuntimeError("corrupt record")
                yield x * 10 + 1
            return gen()

        out = list(Dataset.range(3).interleave(
            poisoned, cycle_length=3, num_parallel_calls=2).ignore_errors())
        assert sorted(out) == [0, 1, 10, 20, 21]


class TestShard:
    def test_disjoint_and_complete(self):
        n = 5
        shards = [list(Dataset.range(23).shard(n, i)) for i in range(n)]
        flat = [x for s in shards for x in s]
        assert sorted(flat) == list(range(23))
        for i in range(n):
            for j in range(i + 1, n):
                assert not set(shards[i]) & set(shards[j])

    def test_validation(self):
        with pytest.raises(ValueError):
            Dataset.range(5).shard(0, 0)
        with pytest.raises(ValueError):
            Dataset.range(5).shard(2, 2)


class TestSortedListFiles:
    def test_list_files_sorted_regardless_of_backend_order(self):
        class ScrambledStorage:
            def listdir(self, path):
                # object-store-ish backend: arbitrary listing order
                return ["c.rrf", "a.rrf", "b.rrf", "x.txt"]

        out = list(Dataset.list_files(ScrambledStorage()))
        assert out == ["a.rrf", "b.rrf", "c.rrf"]


class TestMapAndBatch:
    def _write(self, x, out):
        out[...] = x
        return None

    def test_matches_map_batch(self):
        def fill(x, out):
            out[...] = x * 2.0
            return None

        fused = list(Dataset.range(10).map_and_batch(
            fill, 3, num_parallel_calls=3, out_shape=(2,)))
        legacy = list(Dataset.range(10).map(
            lambda x: np.full((2,), x * 2.0, np.float32)).batch(3))
        assert len(fused) == len(legacy) == 3
        for f, l in zip(fused, legacy):
            np.testing.assert_array_equal(f, l)

    def test_aux_labels(self):
        def fill(x, out):
            out[...] = x
            return np.int32(x + 100)

        batches = list(Dataset.range(4).map_and_batch(
            fill, 2, out_shape=(), out_dtype=np.float32))
        (b0, l0), (b1, l1) = batches
        np.testing.assert_array_equal(b0, [0.0, 1.0])
        np.testing.assert_array_equal(l0, [100, 101])
        np.testing.assert_array_equal(l1, [102, 103])
        assert l1.dtype == np.int32

    @pytest.mark.parametrize("npc", [1, 3])
    def test_ignore_errors_refills_slots(self, npc):
        def fill(x, out):
            if x % 3 == 0:
                raise ValueError("boom")
            out[...] = x
            return None

        batches = list(Dataset.range(12).map_and_batch(
            fill, 4, num_parallel_calls=npc, out_shape=(),
            ignore_errors=True))
        kept = sorted(v for b in batches for v in b.tolist())
        expect = sorted(float(x) for x in range(12) if x % 3 != 0)
        assert kept == expect  # 8 survivors -> 2 full batches

    def test_error_raises_without_ignore(self):
        def fill(x, out):
            if x == 5:
                raise RuntimeError("boom")
            out[...] = x
            return None

        for npc in (1, 2):
            with pytest.raises(RuntimeError):
                list(Dataset.range(10).map_and_batch(
                    fill, 4, num_parallel_calls=npc, out_shape=()))

    def test_drop_remainder_false_partial(self):
        batches = list(Dataset.range(5).map_and_batch(
            self._write, 2, out_shape=(), drop_remainder=False))
        assert [b.shape[0] for b in batches] == [2, 2, 1]
        np.testing.assert_array_equal(batches[-1], [4.0])

    def test_parallel_batches_deterministic(self):
        def fill(x, out):
            time.sleep(0.001 * (x % 3))
            out[...] = x
            return None

        a = [b.tolist() for b in Dataset.range(12).map_and_batch(
            fill, 4, num_parallel_calls=4, out_shape=())]
        b = [b.tolist() for b in Dataset.range(12).map_and_batch(
            fill, 4, num_parallel_calls=4, out_shape=())]
        assert a == b == [[0, 1, 2, 3], [4, 5, 6, 7], [8, 9, 10, 11]]


class TestReaderPool:
    def test_grow_only_and_reuse(self):
        pool = ReaderPool("t")
        pool.ensure(2)
        assert pool.size == 2
        pool.ensure(1)
        assert pool.size == 2  # never shrinks
        pool.ensure(4)
        assert pool.size == 4
        futs = [pool.submit(lambda i=i: i * i) for i in range(16)]
        assert [f.result() for f in futs] == [i * i for i in range(16)]
        pool.shutdown()

    def test_exception_propagates(self):
        pool = ReaderPool("t")

        def boom():
            raise ValueError("x")

        assert isinstance(pool.submit(boom).exception(), ValueError)
        pool.shutdown()

    def test_global_pool_shared_across_epochs(self):
        base = reader_pool(2)
        ds = Dataset.range(8).map(lambda x: x, num_parallel_calls=2)
        for _ in range(3):  # epochs reuse the pool — no new thread spawn
            assert list(ds) == list(range(8))
        assert reader_pool() is base


class TestCloseablePipelines:
    def _leaked(self, base):
        return [t for t in threading.enumerate()
                if t not in base and not t.name.startswith("reader")]

    def _assert_no_leak(self, base, timeout=5.0):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if not self._leaked(base):
                return
            time.sleep(0.02)
        raise AssertionError(f"leaked threads: {self._leaked(base)}")

    def test_closed_prefetch_pipeline_leaves_no_threads(self):
        base = set(threading.enumerate())
        ds = (Dataset.range(100000)
              .map(lambda x: x, num_parallel_calls=2)
              .batch(4)
              .prefetch(2))
        it = iter(ds)
        next(it)
        it.close()  # must propagate through batch -> map -> producer thread
        self._assert_no_leak(base)

    def test_abandoned_repeat_pipeline_closes(self):
        base = set(threading.enumerate())
        ds = Dataset.range(100).repeat().batch(4).prefetch(3)
        with iter(ds) as it:
            next(it)
            next(it)
        self._assert_no_leak(base)

    def test_close_interleave_with_running_fetches(self):
        # close() must wait out RUNNING block fetches before closing slot
        # sub-iterators — closing a generator while a pool worker executes
        # next() on it raises "generator already executing"
        def slow_stream(x):
            def gen():
                for i in range(50):
                    time.sleep(0.002)
                    yield x * 100 + i
            return gen()

        for _ in range(5):
            it = iter(Dataset.range(8).interleave(
                slow_stream, cycle_length=4, block_length=4,
                num_parallel_calls=4))
            next(it)
            it.close()  # must not raise, must not leak the upstream chain

    def test_close_idempotent_and_iter_after_close_possible(self):
        ds = Dataset.range(10).prefetch(1)
        it = iter(ds)
        assert next(it) == 0
        it.close()
        it.close()
        assert list(ds) == list(range(10))  # fresh iterator unaffected


class TestCacheConcurrency:
    def test_concurrent_epoch1_both_complete(self):
        calls = []
        lock = threading.Lock()

        def f(x):
            with lock:
                calls.append(x)
            time.sleep(0.0005)
            return x

        ds = Dataset.range(30).map(f).cache()
        results = [None, None]

        def consume(i):
            results[i] = list(ds)

        ts = [threading.Thread(target=consume, args=(i,)) for i in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(10.0)
        assert results[0] == results[1] == list(range(30))
        first_epoch_calls = len(calls)
        assert 30 <= first_epoch_calls <= 60  # each epoch-1 computes at most once
        assert list(ds) == list(range(30))
        assert len(calls) == first_epoch_calls  # epoch 2 served from memory

    def test_partial_epoch1_does_not_poison_cache(self):
        calls = []

        def f(x):
            calls.append(x)
            return x

        ds = Dataset.range(10).map(f).cache()
        with iter(ds) as it:
            for _ in range(3):
                next(it)
        assert list(ds) == list(range(10))  # complete despite partial epoch
        assert list(ds) == list(range(10))
        assert len(calls) == 3 + 10  # partial + one full epoch, then cached


@pytest.fixture(scope="module")
def sharded_corpus():
    with tempfile.TemporaryDirectory() as d:
        st = NativeStorage(d)
        paths, labels = records.write_sharded_image_dataset(
            st, 24, 6, mean_hw=(16, 16), n_classes=7, seed=3)
        yield st, paths, labels


class TestShardedImagePipeline:
    def test_streams_all_images_with_labels(self, sharded_corpus):
        st, paths, labels = sharded_corpus
        ds = sharded_image_pipeline(
            st, paths, labels, batch_size=4, cycle_length=2, block_length=2,
            num_parallel_calls=3, out_hw=(8, 8), seed=0)
        batches = list(ds)
        assert len(batches) == 6
        for imgs, lbls in batches:
            assert imgs.shape == (4, 8, 8, 3) and imgs.dtype == np.float32
            assert lbls.shape == (4,)
        seen = sorted(l for _, ls in batches for l in ls.tolist())
        assert seen == sorted(l for shard in labels for l in shard)

    def test_deterministic_across_runs(self, sharded_corpus):
        st, paths, labels = sharded_corpus

        def pull():
            ds = sharded_image_pipeline(
                st, paths, labels, batch_size=4, cycle_length=3,
                num_parallel_calls=4, out_hw=(8, 8), seed=11)
            return list(ds)

        for (a_img, a_lbl), (b_img, b_lbl) in zip(pull(), pull()):
            np.testing.assert_array_equal(a_img, b_img)
            np.testing.assert_array_equal(a_lbl, b_lbl)

    def test_worker_sharding_disjoint(self, sharded_corpus):
        st, paths, labels = sharded_corpus
        per_worker = []
        for rank in range(2):
            ds = sharded_image_pipeline(
                st, paths, labels, batch_size=1, cycle_length=2,
                out_hw=(8, 8), seed=0, num_shards=2, shard_index=rank)
            per_worker.append([int(l[0]) for _, l in ds])
        assert len(per_worker[0]) + len(per_worker[1]) == 24
        assert sorted(per_worker[0] + per_worker[1]) == sorted(
            l for shard in labels for l in shard)

    def test_decode_parity_with_host_preprocess(self, sharded_corpus):
        st, paths, labels = sharded_corpus
        blob = st.read_file(paths[0])
        views = list(records.iter_record_views(blob))
        ds = sharded_image_pipeline(
            st, [paths[0]], [labels[0]], batch_size=len(views),
            cycle_length=1, out_hw=(8, 8), seed=0)
        imgs, lbls = next(iter(ds))
        # the shard is streamed in record order (single shard, no shuffle
        # across shards) -> rows comparable against per-record preprocess
        for i, view in enumerate(views):
            expect = records.preprocess_image(bytes(view), 8, 8)
            np.testing.assert_allclose(imgs[i], expect, atol=1e-6)
        np.testing.assert_array_equal(lbls, labels[0])

    def test_read_only_mode_counts_bytes(self, sharded_corpus):
        st, paths, _ = sharded_corpus
        ds = sharded_image_pipeline(
            st, paths, batch_size=6, cycle_length=2, num_parallel_calls=2,
            preprocess=False)
        lens = [int(v) for b in ds for v in b]
        assert len(lens) == 24 and all(v > 16 for v in lens)

    def test_batched_numpy_preprocess_uniform_corpus(self):
        with tempfile.TemporaryDirectory() as d:
            st = NativeStorage(d)
            paths, labels = records.write_sharded_image_dataset(
                st, 12, 4, mean_hw=(16, 16), hw_jitter=0.0, seed=5)
            ds = sharded_image_pipeline(
                st, paths, labels, batch_size=4, cycle_length=2,
                out_hw=(8, 8), seed=0, batched_preprocess="numpy")
            batches = list(ds)
            assert len(batches) == 3
            imgs, lbls = batches[0]
            imgs = np.asarray(imgs)
            assert imgs.shape == (4, 8, 8, 3) and imgs.dtype == np.float32
            assert 0.0 <= imgs.min() and imgs.max() <= 1.0


class TestMicrobenchPaths:
    def test_vectorized_microbench_counts_corpus(self, sharded_corpus):
        st, shard_paths, _ = sharded_corpus
        with tempfile.TemporaryDirectory() as d:
            st2 = NativeStorage(d)
            paths, _ = records.write_image_dataset(
                st2, 16, mean_hw=(12, 12), seed=0)
            r = run_microbench(st2, paths, threads=2, batch_size=4,
                               out_hw=(8, 8), pipeline="vectorized")
            assert r.n_images == 16 and r.images_per_s > 0
        rs = run_sharded_microbench(st, shard_paths, threads=2, batch_size=4,
                                    out_hw=(8, 8))
        assert rs.n_images == 24 and rs.total_bytes > 0


class TestShardQuarantine:
    """Cross-epoch quarantine + probe-read re-admission (interleave)."""

    def _stream_fn(self, bad):
        def stream(path):
            def gen():
                if path in bad:
                    raise RuntimeError(f"corrupt {path}")
                for i in range(3):
                    yield (path, i)
            return gen()
        return stream

    def test_healed_shard_readmitted_next_epoch(self):
        from repro.core.dataset import ShardQuarantine

        bad = {"s1"}
        q = ShardQuarantine()
        ds = (Dataset.from_tensor_slices(["s0", "s1", "s2"])
              .interleave(self._stream_fn(bad), cycle_length=2,
                          num_parallel_calls=2, quarantine=q)
              .ignore_errors())
        ep1 = list(ds)
        assert {p for p, _ in ep1} == {"s0", "s2"}
        assert q.quarantined() == ["s1"] and len(q) == 1

        # epoch 2, still bad: the probe fails, the shard is skipped without
        # burning its retry budget or emitting error markers
        ep2 = list(ds)
        assert {p for p, _ in ep2} == {"s0", "s2"}
        assert len(q) == 1 and q.readmitted == 0

        bad.clear()             # the OST failover finished
        ep3 = list(ds)
        assert {p for p, _ in ep3} == {"s0", "s1", "s2"}
        assert len(ep3) == 9
        assert len(q) == 0 and q.readmitted == 1

    def test_readmission_increments_metric(self):
        from repro import metrics
        from repro.core.dataset import ShardQuarantine

        bad = {"s0"}
        q = ShardQuarantine()
        ds = (Dataset.from_tensor_slices(["s0", "s1"])
              .interleave(self._stream_fn(bad), cycle_length=2,
                          num_parallel_calls=2, quarantine=q)
              .ignore_errors())
        reg = metrics.start()
        try:
            list(ds)
            bad.clear()
            list(ds)
            counters = reg.collect()["counters"]
            assert counters.get("pipeline.readmitted_shards") == 1
            quarantined = sum(v for k, v in counters.items()
                              if k.startswith("pipeline.quarantined_shards"))
            assert quarantined == 1
        finally:
            metrics.stop()

    def test_probe_pulls_one_record_then_reopens(self):
        from repro.core.dataset import ShardQuarantine

        pulls = []

        def stream(path):
            def gen():
                for i in range(4):
                    pulls.append((path, i))
                    yield i
            return gen()

        q = ShardQuarantine()
        q.quarantine("s0", RuntimeError("old failure"))
        ds = (Dataset.from_tensor_slices(["s0"])
              .interleave(stream, cycle_length=1, quarantine=q)
              .ignore_errors())
        out = list(ds)
        assert out == [0, 1, 2, 3]      # full coverage after re-admission
        # the probe pulled exactly one extra record before the real stream
        assert len(pulls) == 5
        assert q.readmitted == 1

    def test_quarantine_via_sharded_pipeline_storage_fault(self):
        from repro.core.dataset import ShardQuarantine
        from repro.core.faults import FaultyStorage

        with tempfile.TemporaryDirectory() as d:
            st = NativeStorage(d)
            paths, labels = records.write_sharded_image_dataset(
                st, n_images=24, images_per_shard=6, mean_hw=(16, 16), seed=0)
            # sticky=False: only the matching shard fails (a bad OST object,
            # not a dead device)
            faulty = FaultyStorage(st, sticky=False).fail_on(
                paths[0], ops=("read",))
            q = ShardQuarantine()

            def epoch():
                ds = sharded_image_pipeline(
                    faulty, paths, labels, batch_size=6, cycle_length=2,
                    block_length=3, num_parallel_calls=2, prefetch=0,
                    out_hw=(8, 8), seed=3, quarantine=q)
                return sum(len(l) for _i, l in ds)

            assert epoch() == 18                    # bad shard dropped
            assert q.quarantined() == [paths[0]]
            faulty.heal()
            assert epoch() == 24                    # probed, readmitted, full
            assert len(q) == 0 and q.readmitted == 1
