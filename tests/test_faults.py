"""Fault injection & crash consistency: the atomicity guarantees, proven.

Every checkpointer documents "a crash mid-save leaves the previous
checkpoint restorable" — these tests kill the storage at exact points
(before the commit marker, on the marker itself, during the drain) with
:class:`FaultyStorage` and assert the previous step survives on every path:
CheckpointSaver, AsyncCheckpointer, and both tiers of
BurstBufferCheckpointer.
"""
import numpy as np
import pytest

from repro.core.async_checkpoint import AsyncCheckpointer
from repro.core.burst_buffer import BurstBufferCheckpointer
from repro.core.checkpoint import CheckpointSaver
from repro.core.faults import FaultInjected, FaultyStorage


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(64, 64)).astype(np.float32),
        "b": rng.normal(size=(64,)).astype(np.float32),
        "step": np.int32(seed),
    }


class TestFaultyStorage:
    def test_fail_after_counts_writes(self, tmp_storage):
        f = FaultyStorage(tmp_storage).fail_after(2)
        f.write_file("a", b"1")
        f.write_file("b", b"2")
        with pytest.raises(FaultInjected):
            f.write_file("c", b"3")
        assert tmp_storage.exists("a") and tmp_storage.exists("b")
        assert not tmp_storage.exists("c")  # fault fires before the write

    def test_sticky_failure_models_dead_device(self, tmp_storage):
        f = FaultyStorage(tmp_storage).fail_after(0)
        with pytest.raises(FaultInjected):
            f.write_file("a", b"1")
        with pytest.raises(FaultInjected):  # still dead
            f.write_file("b", b"2")
        f.heal()
        f.write_file("c", b"3")
        assert f.read_file("c") == b"3"

    def test_fail_on_path_substring(self, tmp_storage):
        f = FaultyStorage(tmp_storage).fail_on("marker")
        f.write_file("data-0", b"x")
        with pytest.raises(FaultInjected):
            f.write_file("the/marker", b"y")

    def test_read_faults(self, tmp_storage):
        tmp_storage.write_file("a", b"payload")
        f = FaultyStorage(tmp_storage).fail_after(0, ops=("read",))
        f.write_file("b", b"ok")  # writes unaffected
        with pytest.raises(FaultInjected):
            f.read_file("a")
        with pytest.raises(FaultInjected):
            f.read_range("a", 0, 3)


class TestSaverCrashConsistency:
    def test_crash_on_data_shard_keeps_previous(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        saver = CheckpointSaver(faulty, "ckpt/m", n_shards=2)
        t1 = tree(1)
        saver.save(1, t1)
        faulty.fail_after(0)  # first write of the next save dies
        with pytest.raises(FaultInjected):
            saver.save(2, tree(2))
        faulty.heal()
        assert saver.latest_step() == 1  # marker never moved
        out = saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])

    def test_crash_on_marker_write_keeps_previous(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        saver = CheckpointSaver(faulty, "ckpt/m")
        t1 = tree(1)
        saver.save(1, t1)
        faulty.fail_on("ckpt/checkpoint")  # kill exactly the commit
        with pytest.raises(FaultInjected):
            saver.save(2, tree(2))
        faulty.heal()
        # step-2 data landed but was never committed: previous still latest
        assert saver.latest_step() == 1
        out = saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])

    def test_crash_with_parallel_shard_writes(self, tmp_storage):
        """A failing shard aborts the whole save before the marker, even
        with the other shards written concurrently."""
        faulty = FaultyStorage(tmp_storage)
        saver = CheckpointSaver(faulty, "ckpt/m", n_shards=4, io_threads=4)
        t1 = tree(1)
        saver.save(1, t1)
        faulty.fail_after(2)  # third shard write of the next save dies
        with pytest.raises(FaultInjected):
            saver.save(2, tree(2))
        faulty.heal()
        assert saver.latest_step() == 1
        out = saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])


class TestAsyncCrashConsistency:
    def test_wait_surfaces_background_write_error(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        ac = AsyncCheckpointer(faulty, "ckpt/m")
        t1 = tree(1)
        ac.save(1, t1).result()
        faulty.fail_after(0)
        handle = ac.save(2, tree(2))  # snapshot succeeds; write will die
        assert isinstance(handle.exception(), FaultInjected)
        with pytest.raises(FaultInjected):
            ac.wait()
        faulty.heal()
        assert ac.latest_step() == 1
        out = ac.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        ac.close()

    def test_error_reported_once_not_resurfaced_forever(self, tmp_storage):
        """After a failed save is reported by wait(), a healed device and
        successful later saves must make wait() clean again."""
        faulty = FaultyStorage(tmp_storage)
        ac = AsyncCheckpointer(faulty, "ckpt/m")
        faulty.fail_after(0)
        ac.save(1, tree(1))
        with pytest.raises(FaultInjected):
            ac.wait()
        faulty.heal()
        ac.save(2, tree(2))
        ac.wait()  # must not re-raise the stale step-1 error
        assert ac.latest_step() == 2
        ac.close()


class TestBurstBufferCrashConsistency:
    def test_fast_tier_crash_mid_save_keeps_previous(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        faulty_fast = FaultyStorage(fast)
        bb = BurstBufferCheckpointer(faulty_fast, slow, "ckpt/m")
        t1 = tree(1)
        bb.save(1, t1)
        bb.wait()
        faulty_fast.fail_after(0)
        with pytest.raises(FaultInjected):
            bb.save(2, tree(2))
        faulty_fast.heal()
        bb.wait()
        # both tiers still restore step 1
        out = bb.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        slow_saver = CheckpointSaver(slow, "ckpt/m")
        assert slow_saver.latest_step() == 1
        out = slow_saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        bb.close()

    def test_drain_error_surfaces_in_wait_and_slow_tier_consistent(
            self, fast_slow_storage):
        fast, slow = fast_slow_storage
        faulty_slow = FaultyStorage(slow)
        bb = BurstBufferCheckpointer(fast, faulty_slow, "ckpt/m")
        t1 = tree(1)
        bb.save(1, t1)
        bb.wait()
        faulty_slow.fail_after(0)  # the next drain's first slow write dies
        bb.save(2, tree(2))        # staging to fast succeeds
        with pytest.raises(FaultInjected):
            bb.wait()
        faulty_slow.heal()
        # slow tier: marker still at step 1, and step 1 restores
        slow_saver = CheckpointSaver(slow, "ckpt/m")
        assert slow_saver.latest_step() == 1
        out = slow_saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        # fast tier holds the newer staged step — nothing was lost
        assert bb.fast_saver.latest_step() == 2
        bb.close()

    def test_drain_marker_crash_keeps_slow_consistent(self, fast_slow_storage):
        """Die exactly on the slow-tier commit marker: files of the new step
        are on the slow tier but it must still restore the previous step."""
        fast, slow = fast_slow_storage
        faulty_slow = FaultyStorage(slow)
        bb = BurstBufferCheckpointer(fast, faulty_slow, "ckpt/m")
        t1 = tree(1)
        bb.save(1, t1)
        bb.wait()
        faulty_slow.fail_on("ckpt/checkpoint")
        bb.save(2, tree(2))
        with pytest.raises(FaultInjected):
            bb.wait()
        faulty_slow.heal()
        slow_saver = CheckpointSaver(slow, "ckpt/m")
        assert slow_saver.latest_step() == 1
        out = slow_saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        bb.close()
