"""Quickstart: train a tiny LM end-to-end with the full I/O stack.

    PYTHONPATH=src python examples/quickstart.py

Data flows through the paper's pipeline (parallel map + shuffle + batch +
prefetch), training checkpoints through a burst buffer (fast tier + async
drain), and the run resumes from the newest checkpoint if re-run.
"""
import sys, tempfile, os
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import BurstBufferCheckpointer, Dataset, make_storage
from repro.core import records
from repro.train import steps as S
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer


def main():
    cfg = ARCHS["qwen3-4b"].smoke()
    opt = OptConfig(lr=3e-3)
    root = tempfile.mkdtemp()

    # 1. corpus on a simulated SSD tier
    data_st = make_storage("ssd", os.path.join(root, "data"), time_scale=0.05)
    shards = records.write_token_dataset(
        data_st, n_shards=8, docs_per_shard=16, seq_len=33,
        vocab_size=cfg.vocab_size)

    # 2. the paper's input pipeline: shuffle -> parallel read/decode -> batch -> prefetch
    def load(path):
        return records.decode_token_shard(data_st.read_file(path), 33)

    ds = (Dataset.from_tensor_slices(shards)
          .repeat()
          .shuffle(8, seed=0)
          .map(load, num_parallel_calls=4)
          .prefetch(2))

    def batches():
        for shard in ds:
            for i in range(0, len(shard), 4):
                yield {"tokens": jnp.asarray(shard[i:i + 4])}

    # 3. burst-buffer checkpointing: optane stage, hdd archive
    fast = make_storage("optane", os.path.join(root, "bb"), time_scale=0.05)
    slow = make_storage("hdd", os.path.join(root, "archive"), time_scale=0.05)
    ckpt = BurstBufferCheckpointer(fast, slow, "ckpt/quickstart")

    # 4. train
    state = S.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(S.make_train_step(cfg, opt, None, remat=False,
                                     q_chunk=16, kv_chunk=16))
    tr = Trainer(step, state, batches(), checkpointer=ckpt, ckpt_every=5)
    hist = tr.run(15)
    ckpt.wait()
    print(f"step {tr.step}: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print("report:", {k: v for k, v in tr.report().items() if k != 'timer'})
    print("archived checkpoint steps on slow tier:",
          [d.step for d in ckpt.drains])
    ckpt.close()


if __name__ == "__main__":
    main()
