"""Fig. 8 analogue, upgraded to tf-Darshan-style attribution: run the
AlexNet mini-app under :mod:`repro.trace` and reproduce the paper's
read/write timeline with *per-stage* spans instead of 1 Hz dstat buckets.

Emits:

* ``reports/fig8_trace.json`` — Chrome ``trace_event`` JSON (open in
  Perfetto / chrome://tracing) with spans attributed to storage reads,
  decode/map, prefetch, checkpoint writes and burst-buffer drains;
* ``reports/fig8_trace.md`` — Darshan-style markdown report: per-stage
  bytes, op counts, p50/p95/p99 latencies, compute/input overlap ratio,
  plus a :mod:`repro.metrics` gauge timeline (prefetch occupancy, drain
  backlog, reader-pool depth) sampled live during the run;
* the usual ``name,key=val`` CSV rows.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp

from repro import metrics, trace
from repro.configs import ALEXNET_SMOKE as CFG
from repro.core import make_storage, records
from repro.core.burst_buffer import BurstBufferCheckpointer
from repro.core.dataset import image_pipeline
from repro.models import alexnet as A
from repro.train.trainer import Trainer

from .common import RESULTS_DIR, SCRATCH, TIME_SCALE, emit

N_STEPS = 12
CKPT_EVERY = 4

#: the acceptance surface: stages the trace must attribute spans to
EXPECTED_STAGES = (
    trace.STAGE_STORAGE_READ,
    trace.STAGE_DECODE,
    trace.STAGE_PREFETCH,
    trace.STAGE_CKPT_WRITE,
    trace.STAGE_DRAIN,
)


def run(name: str = "fig8_trace") -> dict:
    tmp = tempfile.TemporaryDirectory(dir=SCRATCH)
    data_st = make_storage("ssd", os.path.join(tmp.name, "data"),
                           time_scale=TIME_SCALE)
    fast_st = make_storage("optane", os.path.join(tmp.name, "fast"),
                           time_scale=TIME_SCALE)
    slow_st = make_storage("hdd", os.path.join(tmp.name, "slow"),
                           time_scale=TIME_SCALE)
    paths, labels = records.write_image_dataset(
        data_st, 96, mean_hw=(48, 48), n_classes=CFG.n_classes)

    params = A.init_params(jax.random.PRNGKey(0), CFG)
    state = {"params": params, "step": jnp.int32(0)}

    @jax.jit
    def train_step(state, batch):
        imgs, lbls = batch
        loss, g = jax.value_and_grad(
            lambda p: A.loss_fn(p, imgs, lbls, CFG))(state["params"])
        new_p = jax.tree.map(lambda p, gg: p - 1e-4 * gg, state["params"], g)
        return {"params": new_p, "step": state["step"] + 1}, {"loss": loss}

    # warm the jit cache outside the traced region so compilation doesn't
    # masquerade as compute time
    warm = image_pipeline(data_st, paths, labels, batch_size=8,
                          num_parallel_calls=2, prefetch=0,
                          out_hw=(CFG.in_hw, CFG.in_hw), repeat=True)
    _, _ = train_step(state, next(iter(warm)))

    tracer = trace.start()  # -- everything below is attributed ------------
    metrics.start()         # gauge timeline rides along in the report
    sampler = metrics.Sampler(interval_s=0.05)
    sampler.start()
    ds = image_pipeline(data_st, paths, labels, batch_size=8,
                        num_parallel_calls=4, prefetch=2,
                        out_hw=(CFG.in_hw, CFG.in_hw), repeat=True)
    ckpt = BurstBufferCheckpointer(fast_st, slow_st, "ckpt/model",
                                   n_shards=2)
    tr = Trainer(train_step, state, iter(ds), checkpointer=ckpt,
                 ckpt_every=CKPT_EVERY, resume=False)
    tr.run(N_STEPS)
    ckpt.wait()
    ckpt.close()
    sampler.stop()
    metric_points = sampler.points()
    metrics.stop()
    trace.stop()

    spans = tracer.spans()
    counters = tracer.counters()
    stats = trace.aggregate(spans)
    overlap = trace.overlap_ratio(spans)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    json_path = os.path.join(RESULTS_DIR, f"{name}.json")
    md_path = os.path.join(RESULTS_DIR, f"{name}.md")
    trace.dump_chrome_trace(spans, json_path, counters,
                            process_name="alexnet-miniapp")
    with open(md_path, "w") as f:
        f.write(trace.to_markdown(
            spans, title="AlexNet mini-app I/O trace (fig8)",
            counters=counters, metrics_series=metric_points))

    rows = []
    for st in stats.values():
        rows.append(
            f"stage={st.stage},ops={st.ops},mb={st.mb:.2f},"
            f"total_s={st.total_s:.3f},p50_ms={st.p50_ms:.2f},"
            f"p95_ms={st.p95_ms:.2f},p99_ms={st.p99_ms:.2f}")
    missing = [s for s in EXPECTED_STAGES if s not in stats]
    derived = (
        f"stages={len(stats)} (expected>={len(EXPECTED_STAGES)}"
        f"{' MISSING:' + '/'.join(missing) if missing else ''}); "
        f"compute/input overlap={overlap:.2f} (paper Fig. 6: ~1 when "
        f"prefetch hides I/O); spans={len(spans)}; "
        f"exports={json_path},{md_path}")
    emit(name, rows, derived)
    tmp.cleanup()
    return dict(stats=stats, overlap=overlap, spans=len(spans),
                missing=missing)


if __name__ == "__main__":
    run()
