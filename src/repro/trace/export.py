"""Exporters: Chrome ``trace_event`` JSON (Perfetto/chrome://tracing loadable).

Format reference: the Trace Event Format spec (JSON Array / JSON Object
flavors).  We emit the Object flavor ``{"traceEvents": [...]}`` with:

* ``ph:"M"`` metadata events naming the process and each thread;
* ``ph:"X"`` complete events — one per span, ``ts``/``dur`` in microseconds,
  ``cat`` carrying the pipeline stage, ``args`` carrying bytes and any
  user attrs (nesting is implied by ts/dur containment per tid);
* ``ph:"C"`` counter events for gauges (prefetch buffer depth, ...).

:func:`from_chrome_trace` parses the same schema back into records, so a
trace survives a JSON round-trip losslessly (used by tests and by offline
analysis of traces captured on another machine).
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Tuple, Union

from .tracer import CounterRecord, SpanRecord, Tracer

_PID = 1  # single-process collector; shards would use distinct pids


def to_chrome_trace(
    spans: Iterable[SpanRecord],
    counters: Iterable[CounterRecord] = (),
    process_name: str = "repro",
) -> dict:
    """Build the Trace-Event-Format JSON object for ``spans``/``counters``."""
    events: List[dict] = [
        dict(ph="M", name="process_name", pid=_PID, tid=0,
             args=dict(name=process_name)),
    ]
    seen_tids: Dict[int, str] = {}
    spans = list(spans)
    for r in spans:
        if r.tid not in seen_tids:
            seen_tids[r.tid] = r.thread
    for tid, tname in sorted(seen_tids.items()):
        events.append(
            dict(ph="M", name="thread_name", pid=_PID, tid=tid,
                 args=dict(name=tname))
        )
    for r in spans:
        args: Dict[str, object] = dict(bytes=r.nbytes)
        if r.args:
            args.update(r.args)
        events.append(
            dict(ph="X", name=r.name or r.stage, cat=r.stage, pid=_PID,
                 tid=r.tid, ts=r.t0 * 1e6, dur=r.dur * 1e6, args=args)
        )
    for c in counters:
        events.append(
            dict(ph="C", name=c.name, pid=_PID, tid=0, ts=c.t * 1e6,
                 args={c.name: c.value})
        )
    return dict(traceEvents=events, displayTimeUnit="ms")


def dump_chrome_trace(
    source: Union[Tracer, Iterable[SpanRecord]],
    path: str,
    counters: Optional[Iterable[CounterRecord]] = None,
    process_name: str = "repro",
) -> dict:
    """Serialize ``source`` (a Tracer or span list) to ``path``; returns the
    trace object for further inspection."""
    if isinstance(source, Tracer):
        spans = source.spans()
        if counters is None:
            counters = source.counters()
    else:
        spans = list(source)
    obj = to_chrome_trace(spans, counters or (), process_name)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def from_chrome_trace(
    obj: Union[dict, str],
) -> Tuple[List[SpanRecord], List[CounterRecord]]:
    """Parse a Trace-Event-Format object (or its JSON string) back into
    ``(spans, counters)``.  Metadata events are consumed to recover thread
    names; unknown phases are ignored (the spec allows many)."""
    if isinstance(obj, str):
        obj = json.loads(obj)
    events = obj["traceEvents"] if isinstance(obj, dict) else obj
    thread_names: Dict[int, str] = {}
    spans: List[SpanRecord] = []
    counters: List[CounterRecord] = []
    for ev in events:
        ph = ev.get("ph")
        if ph == "M" and ev.get("name") == "thread_name":
            thread_names[int(ev.get("tid", 0))] = ev["args"]["name"]
    for ev in events:
        ph = ev.get("ph")
        if ph == "X":
            args = dict(ev.get("args") or {})
            nbytes = int(args.pop("bytes", 0))
            tid = int(ev.get("tid", 0))
            cat = ev.get("cat", "")
            name = ev.get("name", "")
            spans.append(
                SpanRecord(
                    stage=cat or name,
                    name="" if name == cat else name,
                    tid=tid,
                    thread=thread_names.get(tid, f"tid-{tid}"),
                    t0=float(ev["ts"]) / 1e6,
                    dur=float(ev.get("dur", 0.0)) / 1e6,
                    nbytes=nbytes,
                    args=args or None,
                )
            )
        elif ph == "C":
            name = ev.get("name", "")
            vals = ev.get("args") or {}
            value = vals.get(name, next(iter(vals.values()), 0.0))
            counters.append(
                CounterRecord(name=name, t=float(ev["ts"]) / 1e6,
                              value=float(value), tid=int(ev.get("tid", 0)))
            )
    spans.sort(key=lambda r: (r.t0, -r.dur))
    counters.sort(key=lambda c: c.t)
    return spans, counters
