"""Storage tier simulator: bandwidth pacing + thread scaling shape."""
import os
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core.stats import IOTracer
from repro.core.storage import (
    NativeStorage, SimulatedStorage, TIERS, TierSpec, make_storage,
)


class TestNative:
    def test_roundtrip_and_meta(self, tmp_storage):
        tmp_storage.write_file("a/b.bin", b"xyz", sync=True)
        assert tmp_storage.read_file("a/b.bin") == b"xyz"
        assert tmp_storage.exists("a/b.bin")
        assert tmp_storage.size("a/b.bin") == 3
        tmp_storage.rename("a/b.bin", "a/c.bin")
        assert not tmp_storage.exists("a/b.bin")
        tmp_storage.remove("a")
        assert not tmp_storage.exists("a")

    def test_tracer_counts(self):
        tracer = IOTracer()
        with tempfile.TemporaryDirectory() as d:
            st = NativeStorage(d, tracer)
            st.write_file("f", b"x" * 1000)
            st.read_file("f")
        t = tracer.totals()
        assert t["write_bytes"] == 1000 and t["read_bytes"] == 1000
        assert t["write_ops"] == 1 and t["read_ops"] == 1


class TestSimulated:
    def test_write_bandwidth_paced(self):
        spec = TierSpec("slow", 10e6, 10e6, 10e6, 10e6, 0, 0)
        with tempfile.TemporaryDirectory() as d:
            st = SimulatedStorage(d, spec)
            t0 = time.monotonic()
            st.write_file("f", b"x" * 2_000_000)  # 2MB at 10MB/s >= 0.2s
            el = time.monotonic() - t0
        assert el >= 0.18, f"not paced: {el}"

    def test_read_faster_tier_is_faster(self):
        # RAM-backed scratch where available (same idiom as benchmarks/
        # common.py): the modelled device pacing must dominate, not the
        # machine's real disk — on a loaded box a 3 MB /tmp read can cost
        # more than the whole modelled optane op
        scratch = "/dev/shm" if os.path.isdir("/dev/shm") else None
        with tempfile.TemporaryDirectory(dir=scratch) as d1, \
                tempfile.TemporaryDirectory(dir=scratch) as d2:
            # time_scale=1: modelled hdd ~48ms vs optane ~3ms — both far
            # above the ~1ms sleep/IO noise floor, so the 2x margin is robust
            hdd = make_storage("hdd", d1, time_scale=1.0)
            opt = make_storage("optane", d2, time_scale=1.0)
            data = b"x" * 3_000_000
            hdd.write_file("f", data)
            opt.write_file("f", data)
            t0 = time.monotonic(); hdd.read_file("f"); t_hdd = time.monotonic() - t0
            t0 = time.monotonic(); opt.read_file("f"); t_opt = time.monotonic() - t0
        assert t_hdd > t_opt * 2

    def test_thread_scaling_saturates_at_aggregate(self):
        """Many concurrent readers can't exceed the aggregate cap."""
        spec = TierSpec("cap", read_bw=20e6, write_bw=20e6,
                        stream_read_bw=10e6, stream_write_bw=10e6,
                        seek_latency=0, seek_contention=0)
        with tempfile.TemporaryDirectory() as d:
            st = SimulatedStorage(d, spec)
            for i in range(8):
                st.write_file(f"f{i}", b"x" * 500_000)
            t0 = time.monotonic()
            with ThreadPoolExecutor(8) as pool:
                list(pool.map(lambda i: st.read_file(f"f{i}"), range(8)))
            el = time.monotonic() - t0
        # 4MB at 20MB/s aggregate -> >= 0.2s regardless of 8 threads
        assert el >= 0.17, f"aggregate cap violated: {el}"

    def test_seek_contention_penalizes_hdd_concurrency(self):
        spec = TIERS["hdd"]
        lat2 = spec.seek_latency * (1 + spec.seek_contention)
        assert lat2 > spec.seek_latency

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            make_storage("floppy", "/tmp/x")


class TestTracerTimeline:
    def test_timeline_csv(self):
        tracer = IOTracer(interval_s=0.05)
        with tempfile.TemporaryDirectory() as d:
            st = NativeStorage(d, tracer)
            st.write_file("f", b"x" * 100)
            time.sleep(0.12)
            st.read_file("f")
        rows = tracer.timeline()
        assert rows[0]["write_mb"] > 0
        assert rows[-1]["read_mb"] > 0
        csv = tracer.to_csv()
        assert csv.splitlines()[0].startswith("t_s,")
