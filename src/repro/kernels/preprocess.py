"""Fused image normalize+cast — Pallas TPU kernel (input-pipeline hot spot).

The paper's mapped function ends with convert_image_dtype + normalization on
the CPU.  On a TPU pod the natural split (DESIGN.md hardware-adaptation) is:
host decodes/resizes, device does the arithmetic.  This kernel fuses
uint8->f32 cast, [0,1] scaling, and per-channel (x - mean)/std in one VMEM
pass.

TPU layout choice: NHWC with C=3 would waste 128-wide lanes, so the wrapper
moves channels to the sublane dim: (B, C, H*W).  Each grid step handles one
image's (C, PIX_TILE) tile; mean/std live in SMEM-like small refs (C, 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PIX_TILE = 2048


def _normalize_kernel(x_ref, mean_ref, std_ref, o_ref):
    x = x_ref[...].astype(jnp.float32) * (1.0 / 255.0)   # (1, C, T)
    mean = mean_ref[...][None, :, :]                     # (1, C, 1)
    std = std_ref[...][None, :, :]
    o_ref[...] = (x - mean) / std


def normalize_images(x: jax.Array, mean: jax.Array, std: jax.Array,
                     *, interpret: bool = True) -> jax.Array:
    """x: (B, C, P) uint8, mean/std: (C,) -> (B, C, P) float32."""
    B, C, P = x.shape
    tile = min(PIX_TILE, P)
    grid = (B, pl.cdiv(P, tile))
    return pl.pallas_call(
        _normalize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, tile), lambda b, i: (b, 0, i)),
            pl.BlockSpec((C, 1), lambda b, i: (0, 0)),
            pl.BlockSpec((C, 1), lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, tile), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((B, C, P), jnp.float32),
        interpret=interpret,
    )(x, mean.reshape(C, 1), std.reshape(C, 1))
