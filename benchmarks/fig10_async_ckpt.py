"""Fig. 10 extension: async snapshot checkpointing vs the paper's options.

The paper's burst buffer (§III-C/V-C) shrinks checkpoint stalls by staging
on a fast tier — but training still blocks for the full fast-tier write.
``AsyncCheckpointer`` blocks only for the host snapshot and overlaps the
entire sharded write with training (the write-side analogue of the paper's
prefetcher result: complete compute/input overlap).

Protocol: N_ITERS synthetic training iterations (fixed COMPUTE_S compute
slices under trace spans), checkpoint every CKPT_EVERY.  For each tier in
hdd/ssd/optane/lustre compare:

* ``direct``  — synchronous :class:`DirectCheckpointer` to the tier;
* ``bb``      — :class:`BurstBufferCheckpointer`, optane stage + multi-stream
  drain to the tier;
* ``async``   — :class:`AsyncCheckpointer` to the tier (4 shards, parallel
  shard writes).

Emits per-run rows (runtime, training-thread blocked seconds, checkpoint
bytes, and the checkpoint-write/compute overlap ratio measured from the
trace) plus a Darshan-style trace report for the async-hdd run proving the
write spans land under compute (reports/fig10_async_ckpt_trace.md).
"""
from __future__ import annotations

import os
import tempfile
import time

from repro import trace
from repro.core import make_storage
from repro.core.async_checkpoint import AsyncCheckpointer
from repro.core.burst_buffer import BurstBufferCheckpointer, DirectCheckpointer

from .common import RESULTS_DIR, SCRATCH, emit

import numpy as np

N_ITERS = 9
CKPT_EVERY = 3
COMPUTE_S = 0.05          # synthetic compute slice per iteration
STATE_LAYERS = 4          # equal layers -> shard-parallel writes can help
STATE_MB_EACH = 2         # 4 x 2MB = 8MB checkpoint payload
CKPT_TIME_SCALE = float(os.environ.get("REPRO_CKPT_TIME_SCALE", "1.0"))
TIERS = ("hdd", "ssd", "optane", "lustre")


def make_state():
    rng = np.random.default_rng(0)
    return {
        f"layer{i}":
            rng.normal(size=(STATE_MB_EACH * 1024 * 256,)).astype(np.float32)
        for i in range(STATE_LAYERS)
    }


def run_one(checkpointer, state):
    """Synthetic training loop; returns (runtime_s, post_loop_drain_s)."""
    t0 = time.monotonic()
    for i in range(1, N_ITERS + 1):
        with trace.span(trace.STAGE_COMPUTE, "train_step"):
            time.sleep(COMPUTE_S)
        if i % CKPT_EVERY == 0:
            checkpointer.save(i, state)
    runtime = time.monotonic() - t0
    t1 = time.monotonic()
    checkpointer.wait()
    drain = time.monotonic() - t1
    checkpointer.close()
    return runtime, drain


def ckpt_overlap(spans) -> float:
    """Fraction of checkpoint-write/drain busy time overlapped by compute."""
    return trace.overlap_ratio(
        spans,
        fg_stages=(trace.STAGE_CKPT_WRITE, trace.STAGE_DRAIN),
        bg_stages=(trace.STAGE_COMPUTE,),
    )


def run() -> None:
    state = make_state()
    rows = []
    blocked = {}  # (strategy, tier) -> blocked seconds per save
    async_hdd_report = None

    with tempfile.TemporaryDirectory(dir=SCRATCH) as root:
        def storage(tag, kind):
            return make_storage(kind, os.path.join(root, tag),
                                time_scale=CKPT_TIME_SCALE)

        for tier in TIERS:
            runs = {
                "direct": lambda: DirectCheckpointer(
                    storage(f"direct_{tier}", tier), "ck/m",
                    n_shards=4, io_threads=4),
                "bb": lambda: BurstBufferCheckpointer(
                    storage(f"bb_fast_{tier}", "optane"),
                    storage(f"bb_slow_{tier}", tier), "ck/m",
                    n_shards=4, io_threads=4, drain_streams=4),
                "async": lambda: AsyncCheckpointer(
                    storage(f"async_{tier}", tier), "ck/m",
                    n_shards=4, io_threads=4),
            }
            for strategy, make_ck in runs.items():
                tracer = trace.start()
                ck = make_ck()
                runtime, drain = run_one(ck, state)
                trace.stop()
                spans = tracer.spans()
                b = sum(ck.blocked_s)
                blocked[(strategy, tier)] = b
                ov = ckpt_overlap(spans)
                rows.append(
                    f"strategy={strategy},tier={tier},runtime_s={runtime:.2f},"
                    f"blocked_s={b:.3f},post_loop_drain_s={drain:.2f},"
                    f"ckpt_compute_overlap={ov:.2f}")
                if strategy == "async" and tier == "hdd":
                    async_hdd_report = trace.to_markdown(
                        spans, title="fig10: async checkpoint to hdd "
                        "(write spans overlap compute)")

    frac = blocked[("async", "hdd")] / max(blocked[("direct", "hdd")], 1e-9)
    emit("fig10_async_ckpt", rows,
         f"async blocked fraction vs direct on hdd={frac:.3f} "
         f"(acceptance: <=0.20); bb blocked on hdd="
         f"{blocked[('bb', 'hdd')]:.3f}s (stages on optane, still blocks "
         f"for the fast-tier write)")

    if async_hdd_report:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, "fig10_async_ckpt_trace.md")
        with open(path, "w") as f:
            f.write(async_hdd_report)
        print(f"# trace report -> {path}")


if __name__ == "__main__":
    run()
