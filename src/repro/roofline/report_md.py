"""Render EXPERIMENTS.md tables from dry-run JSON artifacts.

    PYTHONPATH=src python -m repro.roofline.report_md reports/dryrun.json
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional


def _fmt_cell(r: Dict) -> List[str]:
    mem = r.get("memory_per_device") or {}
    peak = (mem.get("argument", 0) + mem.get("temp", 0)) / 2**30
    return [
        r["arch"], r["shape"], r["mesh"],
        f"{r['t_compute']*1e3:.1f}", f"{r['t_memory']*1e3:.1f}",
        f"{r['t_collective']*1e3:.1f}", r["bottleneck"],
        f"{r['mfu']:.3f}", f"{r['useful_flops_ratio']:.2f}",
        f"{peak:.1f}",
    ]


HEADER = ["arch", "shape", "mesh", "t_comp ms", "t_mem ms", "t_coll ms",
          "bottleneck", "MFU bound", "useful/HLO", "peak GiB/dev"]


def table(cells: List[Dict], mesh: Optional[str] = None) -> str:
    rows = [HEADER, ["---"] * len(HEADER)]
    for r in sorted(cells, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r.get("status") != "ok":
            rows.append([r["arch"], r["shape"], r["mesh"], "ERROR"] +
                        [""] * (len(HEADER) - 4))
            continue
        if mesh and r["mesh"] != mesh:
            continue
        rows.append(_fmt_cell(r))
    return "\n".join("| " + " | ".join(row) + " |" for row in rows)


def compare(baseline: List[Dict], optimized: List[Dict]) -> str:
    """Before/after table for cells present in both files."""
    key = lambda r: (r["arch"], r["shape"], r["mesh"])
    base = {key(r): r for r in baseline if r.get("status") == "ok"}
    rows = [["arch", "shape", "mesh", "term", "before", "after", "delta"],
            ["---"] * 7]
    for r in optimized:
        if r.get("status") != "ok" or key(r) not in base:
            continue
        b = base[key(r)]
        for term, label, scale in (
            ("t_compute", "compute ms", 1e3),
            ("t_memory", "memory ms", 1e3),
            ("t_collective", "collective ms", 1e3),
        ):
            before, after = b[term] * scale, r[term] * scale
            delta = (after - before) / before * 100 if before else 0.0
            rows.append([r["arch"], r["shape"], r["mesh"], label,
                         f"{before:.1f}", f"{after:.1f}", f"{delta:+.0f}%"])
        bm = b.get("memory_per_device") or {}
        om = r.get("memory_per_device") or {}
        bp = (bm.get("argument", 0) + bm.get("temp", 0)) / 2**30
        op = (om.get("argument", 0) + om.get("temp", 0)) / 2**30
        rows.append([r["arch"], r["shape"], r["mesh"], "peak GiB",
                     f"{bp:.1f}", f"{op:.1f}",
                     f"{(op-bp)/bp*100:+.0f}%" if bp else ""])
    return "\n".join("| " + " | ".join(row) + " |" for row in rows)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun.json"
    cells = json.load(open(path))
    if len(sys.argv) > 2:
        opt = json.load(open(sys.argv[2]))
        print(compare(cells, opt))
    else:
        print(table(cells))


if __name__ == "__main__":
    main()
