"""Fig. 14 (ours): tiered block read-cache — cold vs warm epoch throughput.

The paper's repeated-epoch observation (§IV-B: "after the first epoch all
samples ... cached in memory") made warm reads free on their 256 GB node;
our simulated tiers have no OS page cache, so until now every epoch paid
the cold-device cost.  This benchmark measures what `repro.core.cache`
buys back, per tier (hdd / ssd / optane / lustre), three configurations:

* ``dram``      — BlockCache with a budget covering the working set:
  epoch 1 cold (device-bound), epoch 2 warm (DRAM-bound).  Gate:
  ``warm_speedup`` = warm/cold samples/s (>= 2x on hdd at full scale).
* ``spill``     — budget of *half* the working set plus an optane-model
  spill tier: warm epochs hit DRAM + the fast arena instead of the slow
  device (>= 1.3x on hdd at full scale).
* ``readahead`` — cold epoch with the ReadaheadScheduler prefetching
  upcoming shards' blocks onto the reader pool, vs the plain cold epoch.

Single-flight proof rides along: an unarmed ``FaultyStorage`` between the
cache and the simulated device logs every inner read op; a cold epoch
(readahead racing consumers included) must issue **exactly one** read per
block — no duplicate device reads, ever.

Emits the usual CSV rows plus machine-readable ``BENCH_cache.json``
(gated leaves: per-epoch ``samples_per_s``, per-mode ``warm_speedup``).

    PYTHONPATH=src python -m benchmarks.fig14_cache [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

from repro.core import BlockCache, CachingStorage, make_storage, records
from repro.core.dataset import sharded_image_pipeline
from repro.core.faults import FaultyStorage

from .common import RESULTS_DIR, SCRATCH, emit

# Real-time pacing (like fig4/fig11): the modelled device dominates, so
# cold-vs-warm is the device's ratio, not this box's Python overhead.
TIME_SCALE = 1.0
BLOCK = 64 * 1024


def _read_ops(counted: FaultyStorage) -> int:
    with counted._lock:
        return sum(1 for (op, _p, _n) in counted.op_log
                   if op in ("read_file", "read_range"))


def _epoch(storage, paths, labels, cfg, readahead=None) -> float:
    """One full epoch through the sharded pipeline; returns samples/s."""
    ds = sharded_image_pipeline(
        storage, paths, labels, batch_size=cfg["batch_size"],
        cycle_length=cfg["cycle_length"], block_length=8,
        num_parallel_calls=cfg["threads"], prefetch=0,
        out_hw=tuple(cfg["out_hw"]), seed=1, readahead=readahead)
    n = 0
    t0 = time.perf_counter()
    for _imgs, lab in ds:
        n += len(lab)
    dt = time.perf_counter() - t0
    return n / dt


def _measure_tier(tier: str, tmp: str, cfg: dict) -> dict:
    st = make_storage(tier, os.path.join(tmp, tier), time_scale=TIME_SCALE)
    paths, labels = records.write_sharded_image_dataset(
        st, cfg["n_images"], cfg["images_per_shard"],
        mean_hw=tuple(cfg["mean_hw"]), seed=0)
    working_set = sum(st.size(p) for p in paths)
    counted = FaultyStorage(st)   # unarmed: a transparent read-op counter
    out: dict = {"working_set_bytes": working_set}

    # --- dram: budget covers the working set -------------------------------
    with BlockCache(2 * working_set, block_size=BLOCK,
                    name=f"fig14-{tier}-dram") as cache:
        cst = CachingStorage(counted, cache)
        blocks = sum(cst.n_blocks(p) for p in paths)
        r0 = _read_ops(counted)
        cold = _epoch(cst, paths, labels, cfg)
        cold_reads = _read_ops(counted) - r0
        s_warm = cache.stats()
        warm = _epoch(cst, paths, labels, cfg)
        s2 = cache.stats()
        warm_lookups = (s2["hits"] + s2["misses"]
                        - s_warm["hits"] - s_warm["misses"])
        warm_hits = s2["hits"] - s_warm["hits"]
        out["dram"] = {
            "cold": {"samples_per_s": round(cold, 2)},
            "warm": {"samples_per_s": round(warm, 2)},
            "warm_speedup": round(warm / cold, 3),
            "warm_hit_ratio": round(warm_hits / max(1, warm_lookups), 4),
            "single_flight_ok": cold_reads == blocks,
            "cold_reads": cold_reads,
            "blocks": blocks,
        }

    # --- spill: half the working set in DRAM, rest on a fast arena ---------
    spill_st = make_storage("optane", os.path.join(tmp, f"{tier}-spill"),
                            time_scale=TIME_SCALE)
    with BlockCache(max(BLOCK, working_set // 2), block_size=BLOCK,
                    spill_storage=spill_st,
                    spill_capacity_bytes=2 * working_set,
                    name=f"fig14-{tier}-spill") as cache:
        cst = CachingStorage(counted, cache)
        cold = _epoch(cst, paths, labels, cfg)
        warm = _epoch(cst, paths, labels, cfg)
        s = cache.stats()
        out["spill"] = {
            "cold": {"samples_per_s": round(cold, 2)},
            "warm": {"samples_per_s": round(warm, 2)},
            "warm_speedup": round(warm / cold, 3),
            "spills": s["spills"],
            "spill_hits": s["spill_hits"],
        }

    # --- readahead: cold epoch, prefetcher racing the consumers ------------
    with BlockCache(2 * working_set, block_size=BLOCK,
                    name=f"fig14-{tier}-ra") as cache:
        cst = CachingStorage(counted, cache)
        blocks = sum(cst.n_blocks(p) for p in paths)
        r0 = _read_ops(counted)
        cold_ra = _epoch(cst, paths, labels, cfg, readahead=cfg["window"])
        cold_reads = _read_ops(counted) - r0
        out["readahead"] = {
            "cold": {"samples_per_s": round(cold_ra, 2)},
            "readahead_gain": round(
                cold_ra / out["dram"]["cold"]["samples_per_s"], 3),
            "single_flight_ok": cold_reads == blocks,
            "cold_reads": cold_reads,
            "blocks": blocks,
        }
    return out


def run(tiers=("hdd", "ssd", "optane", "lustre"), n_images=192,
        images_per_shard=12, mean_hw=(72, 72), out_hw=(24, 24),
        batch_size=16, threads=4, cycle_length=4, window=8,
        name="fig14_cache", json_path=None) -> dict:
    cfg = {
        "tiers": list(tiers), "n_images": n_images,
        "images_per_shard": images_per_shard, "mean_hw": list(mean_hw),
        "out_hw": list(out_hw), "batch_size": batch_size,
        "threads": threads, "cycle_length": cycle_length,
        "window": window, "block": BLOCK, "time_scale": TIME_SCALE,
    }
    result = {}
    with tempfile.TemporaryDirectory(dir=SCRATCH) as tmp:
        for tier in tiers:
            result[tier] = _measure_tier(tier, tmp, cfg)

    rows = []
    for tier, r in result.items():
        for mode in ("dram", "spill"):
            m = r[mode]
            rows.append(
                f"{tier},mode={mode},"
                f"cold={m['cold']['samples_per_s']:.1f},"
                f"warm={m['warm']['samples_per_s']:.1f},"
                f"warm_speedup={m['warm_speedup']:.2f}")
        ra = r["readahead"]
        rows.append(
            f"{tier},mode=readahead,cold={ra['cold']['samples_per_s']:.1f},"
            f"gain={ra['readahead_gain']:.2f},"
            f"single_flight={ra['single_flight_ok']}")
    hdd = result.get("hdd") or result[list(result)[0]]
    derived = (
        f"hdd warm_speedup dram={hdd['dram']['warm_speedup']:.2f}x "
        f"(target >=2x) spill={hdd['spill']['warm_speedup']:.2f}x "
        f"(target >=1.3x); single-flight cold reads == blocks: "
        f"{hdd['dram']['single_flight_ok'] and hdd['readahead']['single_flight_ok']}")
    emit(name, rows, derived)

    payload = {"benchmark": name, "config": cfg, "tiers": result}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_json = json_path or os.path.join(RESULTS_DIR, "BENCH_cache.json")
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_json}")
    return payload


def run_smoke() -> dict:
    """Tiny-scale CI variant: same output shape, seconds of runtime."""
    return run(tiers=("hdd", "ssd"), n_images=48, images_per_shard=8,
               mean_hw=(48, 48), out_hw=(16, 16), batch_size=8, threads=2,
               cycle_length=2)


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    payload = run_smoke() if smoke else run()
    hdd = payload["tiers"]["hdd"]
    # smoke thresholds are deliberately looser: tiny corpora leave less
    # cold-read time to win back, and shared CI boxes are noisy
    dram_floor, spill_floor = (1.5, 1.02) if smoke else (2.0, 1.3)
    ok = (hdd["dram"]["warm_speedup"] >= dram_floor
          and hdd["spill"]["warm_speedup"] >= spill_floor
          and hdd["dram"]["single_flight_ok"]
          and hdd["readahead"]["single_flight_ok"])
    print(f"# hdd dram={hdd['dram']['warm_speedup']}x "
          f"(floor {dram_floor}) spill={hdd['spill']['warm_speedup']}x "
          f"(floor {spill_floor}) "
          f"single_flight={hdd['dram']['single_flight_ok']}/"
          f"{hdd['readahead']['single_flight_ok']} ok={ok}")
    if not ok:
        sys.exit(1)
