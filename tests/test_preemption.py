"""Preemption-safe checkpoint lifecycle (PR 10 tentpole).

* ``preempt(deadline_s)`` on every engine: stop new saves, cancel queued
  snapshots except the newest, promote that one to its durability tier
  within the deadline, and record what was abandoned.
* Drain watchdog: a drain stream wedged in a stuck slow-tier op (the
  :meth:`FaultyStorage.hang` model) is detected within ~2x the stall
  timeout, aborted, its chunk re-queued on a fresh stream — and the save
  still completes; a chunk that stalls on every attempt surfaces
  :class:`DrainStallError`.
* Trainer integration: ``Trainer.preempt(deadline_s)`` rides the stop
  path, records the :class:`PreemptionReport`, and a restart resumes from
  the preempted step — including a step that was staged on the fast tier
  but never drained.
"""
import tempfile
import time

import numpy as np
import pytest

from repro.core.async_burst_buffer import AsyncBurstBufferCheckpointer
from repro.core.async_checkpoint import AsyncCheckpointer
from repro.core.burst_buffer import (BurstBufferCheckpointer,
                                     DirectCheckpointer, DrainStallError)
from repro.core.checkpoint import CheckpointSaver
from repro.core.faults import FaultyStorage
from repro.core.recovery import (ABANDONED, COMMITTED, STAGED,
                                 CheckpointManager)
from repro.core.storage import NativeStorage

PREFIX = "ckpt/m"


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(256,)).astype(np.float32),
            "step": np.int64(seed)}


def wait_until(cond, timeout=5.0):
    deadline = time.monotonic() + timeout
    while not cond():
        if time.monotonic() >= deadline:
            return False
        time.sleep(0.005)
    return True


# ---------------------------------------------------------------------------
# engine-level preempt()
# ---------------------------------------------------------------------------
class TestDirectPreempt:
    def test_trivially_durable_and_rejects_later_saves(self, tmp_storage):
        ck = DirectCheckpointer(tmp_storage, PREFIX)
        ck.save(1, tree(1))
        rep = ck.preempt(deadline_s=1.0)
        assert rep.committed_step == 1
        assert rep.abandoned_steps == [] and rep.deadline_met
        with pytest.raises(RuntimeError):
            ck.save(2, tree(2))


class TestBurstBufferPreempt:
    def test_staged_steps_already_durable(self, tmp_storage):
        with tempfile.TemporaryDirectory() as d2:
            bb = BurstBufferCheckpointer(tmp_storage, NativeStorage(d2),
                                         PREFIX)
            bb.save(1, tree(1))
            rep = bb.preempt(deadline_s=1.0)
            assert rep.committed_step == 1 and rep.deadline_met
            with pytest.raises(RuntimeError):
                bb.save(2, tree(2))
            bb.wait()
            bb.close()


class TestAsyncPreempt:
    def test_promotes_newest_cancels_older_queued(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        ac = AsyncCheckpointer(faulty, PREFIX, keep=10, max_pending=3)
        trees = {s: tree(s) for s in (1, 2, 3)}
        # step 1's first data write wedges for a while: steps 2 and 3 queue
        # behind it on the single writer thread
        faulty.hang(on=".data-", duration=0.3)
        h1 = ac.save(1, trees[1])
        h2 = ac.save(2, trees[2])
        h3 = ac.save(3, trees[3])
        assert wait_until(lambda: faulty.hung_now == 1)
        rep = ac.preempt(deadline_s=30.0)
        # 2 was queued-not-started -> cancelled; 3 promoted and committed;
        # 1 was already running -> ran to completion (not abandoned)
        assert rep.abandoned_steps == [2]
        assert rep.deadline_met
        assert rep.committed_step == 3
        assert h2.cancelled() and not h1.cancelled() and not h3.cancelled()
        assert rep.elapsed_s <= 30.0
        with pytest.raises(RuntimeError):
            ac.save(4, tree(4))
        ac.close()
        saver = CheckpointSaver(tmp_storage, PREFIX)
        out = saver.restore_pytree(trees[3])
        np.testing.assert_array_equal(out["w"], trees[3]["w"])

    def test_deadline_miss_reports_abandoned(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        ac = AsyncCheckpointer(faulty, PREFIX, max_pending=2)
        faulty.hang(on=".data-")  # forever, until released
        h1 = ac.save(1, tree(1))
        assert wait_until(lambda: faulty.hung_now == 1)
        t0 = time.monotonic()
        rep = ac.preempt(deadline_s=0.2)
        elapsed = time.monotonic() - t0
        assert not rep.deadline_met
        assert rep.abandoned_steps == [1]
        assert rep.committed_step is None  # nothing ever landed
        assert 0.15 <= elapsed < 5.0  # waited the budget, not forever
        # the promoted save was left running, not killed: once the device
        # un-wedges it commits as normal and close() is clean
        faulty.heal()
        assert wait_until(h1.done)
        ac.close()
        assert ac.latest_step() == 1

    def test_cancelled_save_releases_backpressure_slot(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        ac = AsyncCheckpointer(faulty, PREFIX, max_pending=2)
        faulty.hang(on=".data-")
        ac.save(1, tree(1))
        assert wait_until(lambda: faulty.hung_now == 1)
        ac.save(2, tree(2))  # fills the second (and last) pending slot
        rep = ac.preempt(deadline_s=0.1)  # cancels 2, times out on... no:
        # newest is 2 -> 2 is promoted; nothing older is queued-unstarted
        # except none (1 is running).  2 can't start behind wedged 1 ->
        # deadline miss; its cancel-or-timeout must not deadlock the sema.
        assert not rep.deadline_met and 2 in rep.abandoned_steps
        faulty.heal()
        ac.close()


class TestAsyncBurstBufferPreempt:
    def test_promote_to_fast_tier_within_deadline(self):
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            fast_inner, slow = NativeStorage(d1), NativeStorage(d2)
            fast = FaultyStorage(fast_inner)
            abb = AsyncBurstBufferCheckpointer(fast, slow, PREFIX, keep=10,
                                               max_pending=3)
            trees = {s: tree(s) for s in (1, 2, 3)}
            fast.hang(on=".data-", duration=0.3)  # wedge step 1's stage
            abb.save(1, trees[1])
            abb.save(2, trees[2])
            abb.save(3, trees[3])
            assert wait_until(lambda: fast.hung_now == 1)
            rep = abb.preempt(deadline_s=30.0)
            assert rep.abandoned_steps == [2]
            assert rep.deadline_met and rep.committed_step == 3
            # the promoted step is durable at the preemption (fast) tier
            # even if the node dies before any drain finishes
            fast_saver = CheckpointSaver(fast_inner, PREFIX)
            out = fast_saver.restore_pytree(trees[3], step=3)
            np.testing.assert_array_equal(out["w"], trees[3]["w"])
            abb.close()


# ---------------------------------------------------------------------------
# drain watchdog
# ---------------------------------------------------------------------------
class TestDrainWatchdog:
    TIMEOUT = 0.15

    def _bb(self, fast, slow, **kw):
        kw.setdefault("drain_stall_timeout", self.TIMEOUT)
        kw.setdefault("drain_streams", 2)
        kw.setdefault("drain_chunk", 256)  # several chunks per shard
        return BurstBufferCheckpointer(fast, slow, PREFIX, keep=10, **kw)

    def test_hung_stream_aborted_and_chunk_requeued(self, tmp_storage):
        with tempfile.TemporaryDirectory() as d2:
            slow_inner = NativeStorage(d2)
            slow = FaultyStorage(slow_inner)
            bb = self._bb(tmp_storage, slow)
            t = tree(1)
            # one data-chunk write wedges forever (one-shot: the re-queued
            # attempt on the replacement stream goes through)
            slow.hang(on=".data-")
            t0 = time.monotonic()
            bb.save(1, t)
            bb.wait()
            wall = time.monotonic() - t0
            assert bb.drain_stalls >= 1 and bb.drain_aborts >= 1
            # detection within ~2x the stall timeout (plus transfer slack)
            assert wall < self.TIMEOUT * 2 + 2.0
            out = CheckpointSaver(slow_inner, PREFIX).restore_pytree(t)
            np.testing.assert_array_equal(out["w"], t["w"])
            slow.heal()  # un-park the leaked stream thread
            bb.close()

    def test_chunk_stalling_every_attempt_raises_drain_stall_error(
            self, tmp_storage):
        with tempfile.TemporaryDirectory() as d2:
            slow = FaultyStorage(NativeStorage(d2))
            bb = self._bb(tmp_storage, slow, drain_requeue_limit=1)
            slow.hang(on=".data-", repeat=True)  # every attempt wedges
            bb.save(1, tree(1))
            with pytest.raises(DrainStallError):
                bb.wait()
            assert bb.drain_stalls >= 2  # initial attempt + the re-queue
            slow.heal()
            bb.close()

    def test_healthy_drain_unaffected_by_watchdog(self, tmp_storage):
        with tempfile.TemporaryDirectory() as d2:
            slow = NativeStorage(d2)
            bb = self._bb(tmp_storage, slow)
            for s in (1, 2):
                bb.save(s, tree(s))
            bb.wait()
            assert bb.drain_stalls == 0 and bb.drain_aborts == 0
            assert CheckpointSaver(slow, PREFIX).latest_step() == 2
            bb.close()

    def test_watchdog_metrics_counters(self, tmp_storage):
        from repro import metrics

        with tempfile.TemporaryDirectory() as d2:
            slow = FaultyStorage(NativeStorage(d2))
            bb = self._bb(tmp_storage, slow)
            slow.hang(on=".data-")
            reg = metrics.start()
            try:
                bb.save(1, tree(1))
                bb.wait()
                counters = reg.collect()["counters"]
                stalls = sum(v for k, v in counters.items()
                             if k.startswith("ckpt.drain_stalls"))
                aborts = sum(v for k, v in counters.items()
                             if k.startswith("ckpt.drain_aborts"))
                assert stalls >= 1 and aborts >= 1
            finally:
                metrics.stop()
            slow.heal()
            bb.close()


# ---------------------------------------------------------------------------
# fused manager + trainer integration
# ---------------------------------------------------------------------------
def make_stream_setup():
    """Deterministic fold state (same harness as test_recovery)."""
    consumed = []
    state = {"w": np.float64(0.0), "step": np.int64(0)}

    def step_fn(state, batch):
        b = np.float64(batch)
        consumed.append(float(b))
        return ({"w": state["w"] * np.float64(0.5) + b,
                 "step": state["step"] + np.int64(1)}, {"loss": b})

    return state, step_fn, consumed


def make_data_iter():
    from repro.core.dataset import Dataset, ResumableIterator

    return ResumableIterator(lambda ep: Dataset.from_tensor_slices(
        [np.float64(ep * 100 + i + 1) for i in range(8)]))


class TestTrainerPreemption:
    def _trainer(self, mgr, n_steps=0, **kw):
        from repro.train.trainer import Trainer

        state, step_fn, consumed = make_stream_setup()
        tr = Trainer(step_fn, state, make_data_iter(), checkpointer=mgr,
                     ckpt_every=2, **kw)
        return tr, consumed

    def test_preempt_records_report_and_restart_resumes(self):
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            fast, slow = NativeStorage(d1), NativeStorage(d2)
            mgr = CheckpointManager(slow, PREFIX, engine="asyncbb",
                                    fast_storage=fast, keep_last=3)
            tr, consumed = self._trainer(mgr)
            tr.on_step = lambda step, m: (step == 3 and tr.preempt(10.0))
            tr.run(6)
            assert len(consumed) == 3  # stopped at the step-3 boundary
            rep = tr.preemption_report
            assert rep is not None and rep.deadline_met
            assert rep.committed_step == 3
            assert tr.report()["preemption"]["committed_step"] == 3
            mgr.wait()
            mgr.close()

            mgr2 = CheckpointManager(slow, PREFIX, engine="asyncbb",
                                     fast_storage=fast, keep_last=3)
            tr2, consumed2 = self._trainer(mgr2)
            assert tr2.recovered_step == 3
            tr2.run(3)
            # the resumed stream continues exactly where the preempted one
            # stopped: no sample skipped, none replayed
            assert consumed2 == [4.0, 5.0, 6.0]
            mgr2.wait()
            mgr2.close()

    def test_restart_from_staged_not_drained_step(self):
        """The preemption-restart contract: a step durable only on the
        fast tier (drain wedged at preemption time) must be restorable."""
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            fast = NativeStorage(d1)
            slow = FaultyStorage(NativeStorage(d2))
            mgr = CheckpointManager(slow, PREFIX, engine="asyncbb",
                                    fast_storage=fast, keep_last=3)
            slow.hang(on=".data-", repeat=True)  # no drain ever commits
            tr, consumed = self._trainer(mgr)
            tr.on_step = lambda step, m: (step == 4 and tr.preempt(10.0))
            tr.run(8)
            rep = tr.preemption_report
            assert rep is not None and rep.committed_step == 4
            assert mgr.step_states()[4] == STAGED  # never COMMITTED
            assert mgr.latest_valid() == 4  # restorable via the fast tier

            mgr2 = CheckpointManager(slow, PREFIX, engine="asyncbb",
                                     fast_storage=fast, keep_last=3)
            tr2, consumed2 = self._trainer(mgr2)
            assert tr2.recovered_step == 4
            # one step (below ckpt_every): node 1's wedged drains are still
            # parked, so only its manager ever publishes to the slow tier
            tr2.run(1)
            assert consumed2 == [5.0]
            mgr2.close()
            slow.heal()   # un-wedge node 1's drains
            mgr.wait()    # they commit (and run deferred GC) cleanly
            assert mgr.step_states()[4] == COMMITTED
            mgr.close()

    def test_direct_engine_stop_path_still_works(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX, keep_last=3)
        tr, consumed = self._trainer(mgr)
        tr.on_step = lambda step, m: (step == 3 and tr.request_stop())
        tr.run(6)
        rep = tr.preemption_report
        assert rep is not None and rep.committed_step == 3
        assert mgr.latest_valid() == 3
        mgr.close()

    def test_abandoned_steps_marked_in_lifecycle(self):
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            fast = FaultyStorage(NativeStorage(d1))
            slow = NativeStorage(d2)
            mgr = CheckpointManager(slow, PREFIX, engine="asyncbb",
                                    fast_storage=fast, keep_last=5,
                                    max_pending=3)
            fast.hang(on=".data-", duration=0.3)
            for s in (1, 2, 3):
                mgr.save(s, tree(s))
            assert wait_until(lambda: fast.hung_now == 1)
            rep = mgr.preempt(10.0)
            assert rep.abandoned_steps == [2]
            assert mgr.abandoned_steps == [2]
            assert mgr.step_states()[2] == ABANDONED
            assert mgr.step_states()[3] in (STAGED, COMMITTED)
            mgr.close()
