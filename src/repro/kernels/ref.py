"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

BLOCK = 256


# -- quantize ----------------------------------------------------------------
def quantize_blocks_ref(x: jax.Array):
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_blocks_ref(q: jax.Array, s: jax.Array):
    return q.astype(jnp.float32) * s


# -- preprocess -----------------------------------------------------------------
def normalize_images_ref(x: jax.Array, mean: jax.Array, std: jax.Array):
    xf = x.astype(jnp.float32) / 255.0
    return (xf - mean[None, :, None]) / std[None, :, None]


# -- flash attention ---------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal=True):
    """q/k/v: (BH, S, hd); naive softmax attention in fp32."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
