"""qwen2-vl-7b — dense VLM backbone with M-RoPE.
[arXiv:2409.12191; hf] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064. Vision frontend is a STUB: positions ids (t/h/w) and patch
embeddings come precomputed via input_specs()."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    mrope=True,
    rope_theta=1e6,
    modality_stub=True,
    modality_seq=0,         # decoder-only: patch embeds merged upstream
    source="arXiv:2409.12191; hf",
)
