"""Burst-buffer checkpointing (paper §III-C, Fig. 9/10 — the 2.6x result).

Training writes each checkpoint synchronously to a *fast, small* tier
(Optane in the paper; any :class:`Storage` here), then immediately resumes
while a background drainer copies the files to the *slow, large* tier (HDD)
and finally deletes the staged copy to free buffer capacity.  The commit
marker on the slow tier is only written after all files of a step have
landed, so either tier is always restorable to a consistent step.

``DirectCheckpointer`` (same interface, no staging) is the paper's baseline
of checkpointing straight to a device.

The drain is **multi-stream and intra-file**: the files of a step are
split into ``drain_chunk``-byte ranges and all ranges — across files *and
within* one large file — stream concurrently on ``drain_streams`` threads
(``Storage.read_range`` → ``Storage.write_range``, pwrite-style), the
write-side analogue of the paper's read thread-scaling and the same reason
parallel shard *writes* help in :class:`repro.core.checkpoint.
CheckpointSaver`.  A single multi-GB shard therefore no longer serializes
the whole drain behind one ``copy_to`` stream.

The slow-tier commit marker is written durably (``sync=True``) via
tmp+rename: the marker is the restorability commit point, so it must be an
atomic publish *and* a write barrier that flushes the drained data before
it — see the torn-write / reordered-fsync fault modes in
:mod:`repro.core.faults` for the crash models this survives.

For snapshot-async saves that don't block on the fast tier at all, see
:class:`repro.core.async_checkpoint.AsyncCheckpointer`, and for the fused
engine (snapshot-only blocking *plus* the burst-buffer drain) see
:class:`repro.core.async_burst_buffer.AsyncBurstBufferCheckpointer`.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

from .. import metrics, trace
from .checkpoint import CheckpointSaver, PreemptionReport, SaveResult, \
    CHECKPOINT_MARKER, write_marker


class DrainStallError(RuntimeError):
    """A drain chunk stalled past the watchdog timeout on every attempt
    (initial + ``drain_requeue_limit`` re-queues).  Deliberately *not* an
    OSError: the watchdog re-queue is itself the retry mechanism — this
    surfacing means the slow tier is wedged, not flaky."""


@dataclass
class DrainRecord:
    step: int
    n_bytes: int
    staged_s: float     # time training was blocked (fast-tier write)
    drain_s: float      # background copy time (overlapped)
    completed_at: float


class DirectCheckpointer:
    """Baseline: checkpoint synchronously to one storage tier.

    Error-delivery contract (parity with the async engines): a save failure
    raises *inline, exactly once* — there is no background work, so
    ``wait()``/``close()`` never have a deferred error to surface.  What
    they do share is the handle-lifecycle discipline: ``close()`` is
    idempotent and ``save()`` after ``close()`` raises, so engine-agnostic
    callers (Trainer, benchmarks) can treat all four checkpointers
    identically.
    """

    def __init__(self, storage, prefix: str = "ckpt/model", *, keep: int = 5,
                 n_shards: int = 1, sync: bool = True, quantize=None,
                 io_threads: Optional[int] = None):
        self.saver = CheckpointSaver(
            storage, prefix, keep=keep, n_shards=n_shards, sync=sync,
            quantize=quantize, io_threads=io_threads,
        )
        self.blocked_s: List[float] = []
        self._closed = False
        self._preempted = False

    def save(self, step: int, tree: Any, extra_meta: Optional[dict] = None) -> SaveResult:
        if self._closed:
            raise RuntimeError("save() on a closed DirectCheckpointer")
        if self._preempted:
            raise RuntimeError("save() on a preempted DirectCheckpointer")
        r = self.saver.save(step, tree, extra_meta)
        self.blocked_s.append(r.seconds)
        return r

    def restore_pytree(self, skeleton: Any, step: Optional[int] = None) -> Any:
        return self.saver.restore_pytree(skeleton, step)

    def restore_sharded(self, skeleton, shardings, step=None):
        return self.saver.restore_sharded(skeleton, shardings, step)

    def latest_step(self) -> Optional[int]:
        return self.saver.latest_step()

    def wait(self) -> None:  # interface parity: nothing in flight, no error
        return

    def preempt(self, deadline_s: Optional[float] = None) -> PreemptionReport:
        """Graceful shutdown: stop accepting saves.  Every completed save
        was synchronous, so the newest step is already durable — nothing is
        in flight to promote or abandon and the deadline is trivially met."""
        self._preempted = True
        return PreemptionReport(self.latest_step(), [], deadline_s, 0.0, True)

    def close(self) -> None:
        self._closed = True  # idempotent; later save() raises


class BurstBufferCheckpointer:
    """Stage to ``fast_storage``, drain asynchronously to ``slow_storage``."""

    def __init__(
        self,
        fast_storage,
        slow_storage,
        prefix: str = "ckpt/model",
        *,
        keep: int = 5,
        n_shards: int = 1,
        sync: bool = True,
        quantize=None,
        cleanup_fast: bool = True,
        drain_async: bool = True,
        io_threads: Optional[int] = None,
        drain_streams: int = 4,
        drain_chunk: int = 8 << 20,
        drain_stall_timeout: Optional[float] = None,
        drain_requeue_limit: int = 3,
    ):
        self.fast = fast_storage
        self.slow = slow_storage
        self.prefix = prefix
        self.keep = keep
        self.cleanup_fast = cleanup_fast
        self.drain_async = drain_async
        self.drain_streams = max(1, drain_streams)
        self.drain_chunk = drain_chunk
        #: Watchdog: a drain stream whose current chunk shows no heartbeat
        #: for this many seconds is aborted, its chunk re-queued on a fresh
        #: stream (``None`` disables).  Tune it above the worst-case single
        #: chunk transfer time, or healthy slow chunks get falsely aborted.
        self.drain_stall_timeout = drain_stall_timeout
        self.drain_requeue_limit = max(0, drain_requeue_limit)
        self.drain_stalls = 0   # stall events the watchdog detected
        self.drain_aborts = 0   # streams it gave up on (leaked until unwedged)
        #: Lifecycle hooks (used by the fused CheckpointManager): called with
        #: the step number after the fast-tier commit / after the slow-tier
        #: marker publish + cleanup.  They run on engine background threads.
        self.on_staged: Optional[Callable[[int], None]] = None
        self.on_drained: Optional[Callable[[int], None]] = None
        self._preempted = False
        self.fast_saver = CheckpointSaver(
            fast_storage, prefix, keep=keep, n_shards=n_shards, sync=sync,
            quantize=quantize, io_threads=io_threads,
        )
        d = prefix.rsplit("/", 1)[0] if "/" in prefix else "."
        self._dir = d
        slow_storage.makedirs(d)
        self.blocked_s: List[float] = []
        self.drains: List[DrainRecord] = []
        self._q: "queue.Queue" = queue.Queue()
        self._pending: List[int] = []      # steps staged but not yet drained
        self._drained: set = set()
        self._pending_lock = threading.Lock()
        self._errors: List[BaseException] = []
        self._thread: Optional[threading.Thread] = None
        if drain_async:
            self._thread = threading.Thread(target=self._drain_loop, daemon=True)
            self._thread.start()

    # -- producer (training thread) --------------------------------------------
    def save(self, step: int, tree: Any, extra_meta: Optional[dict] = None) -> SaveResult:
        if self._preempted:
            raise RuntimeError("save() on a preempted BurstBufferCheckpointer")
        r = self.fast_saver.save(step, tree, extra_meta)
        self.blocked_s.append(r.seconds)  # only the fast-tier write blocks
        m = metrics.enabled()
        if m:
            metrics.observe("ckpt.staged_s", r.seconds, ckpt=self.prefix)
            metrics.add_gauge("ckpt.drain_backlog_bytes", r.n_bytes,
                              ckpt=self.prefix)
        if self.on_staged is not None:
            self.on_staged(step)
        self._enqueue_drain(step, r, m)
        return r

    def _enqueue_drain(self, step: int, r: SaveResult, m: bool) -> None:
        with self._pending_lock:
            self._pending.append(step)
        # the job carries the save-time metrics flag so the backlog gauge is
        # decremented iff it was incremented (metrics may toggle mid-run)
        job = (step, list(r.files), r.n_bytes, r.seconds, m)
        if self.drain_async:
            self._q.put(job)
        else:
            self._drain_one(job)

    # -- drainer -----------------------------------------------------------------
    def _drain_loop(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                self._q.task_done()
                return
            try:
                self._drain_one(job)
            except BaseException as e:  # surface on wait()/close()
                with self._pending_lock:
                    self._errors.append(e)
            finally:
                self._q.task_done()

    def _drain_one(self, job) -> None:
        step, files, n_bytes, staged_s, m = job
        with trace.span(trace.STAGE_DRAIN, f"drain:{self.prefix}-{step}",
                        n_bytes):
            self._drain_files(step, files, n_bytes, staged_s, m)

    def _range_tasks(self, files: List[str]) -> List[Tuple[str, int, int]]:
        """Split every file of a step into ``drain_chunk``-byte ranges so
        one large shard drains on multiple streams (intra-file parallel)."""
        tasks: List[Tuple[str, int, int]] = []
        for path in files:
            size = self.fast.size(path)
            if size == 0:
                tasks.append((path, 0, 0))
                continue
            offset = 0
            while offset < size:
                tasks.append((path, offset, min(self.drain_chunk,
                                                size - offset)))
                offset += self.drain_chunk
        return tasks

    def _drain_range(self, path: str, offset: int, length: int) -> None:
        if length == 0:
            self.slow.write_file(path, b"", sync=False)
            return
        data = self.fast.read_range(path, offset, length)
        self.slow.write_range(path, offset, data, sync=False)

    def _drain_files(self, step: int, files: List[str], n_bytes: int,
                     staged_s: float, m: bool = True) -> None:
        t0 = time.monotonic()
        # read from fast tier (fast read cost), write to slow tier (slow
        # write cost).  All chunk ranges — across files and *within* each
        # file — stream on drain_streams parallel threads via pwrite-style
        # write_range; any failure aborts before the marker moves.  The
        # data writes are not individually synced: the marker write below
        # is the durability barrier.
        tasks = self._range_tasks(files)
        self._run_drain_tasks(tasks)
        # slow-tier commit marker after all files landed — written durably
        # (sync=True barrier) via tmp+rename: the marker is the commit
        # point, so it must never become durable before the data it
        # commits, and never be left half-written
        steps = self._slow_steps()
        if step not in steps:
            steps.append(step)
        steps.sort()
        retained = steps[-self.keep:]
        import json

        marker = json.dumps(dict(latest=step, all_steps=retained)).encode()
        write_marker(self.slow, f"{self._dir}/{CHECKPOINT_MARKER}", marker,
                     sync=True)
        for old in steps[:-self.keep] if len(steps) > self.keep else []:
            self._delete_slow_step(old)
        with self._pending_lock:
            # compact drained steps out of both structures: neither may
            # grow with run length (they used to leak one entry per save)
            self._drained.add(step)
            self._pending = [s for s in self._pending
                             if s not in self._drained]
            self._drained.intersection_update(self._pending)
            pending = set(self._pending)
        if self.cleanup_fast:
            # free buffer capacity (keep only the newest staged step around
            # for fast restore) — paper §V-C: "cleanup the buffer".  Never
            # evict steps still waiting in the drain queue.
            fast_steps = self.fast_saver.all_steps()
            keep_newest = max(fast_steps) if fast_steps else None
            for old in fast_steps:
                if old != keep_newest and old not in pending:
                    self.fast_saver._delete_step(old)
        self.drains.append(
            DrainRecord(step, n_bytes, staged_s, time.monotonic() - t0,
                        time.monotonic())
        )
        if metrics.enabled():
            metrics.observe("ckpt.drain_s", time.monotonic() - t0,
                            ckpt=self.prefix)
            metrics.inc("ckpt.drains", 1, ckpt=self.prefix)
        if m:
            metrics.add_gauge("ckpt.drain_backlog_bytes", -n_bytes,
                              ckpt=self.prefix)
        if self.on_drained is not None:
            # drain commit: the step is durable on the slow tier — the fused
            # manager runs its deferred retention/GC from this hook (on the
            # drain thread, so GC is serialized with marker publishes)
            self.on_drained(step)

    def _run_drain_tasks(self, tasks: List[Tuple[str, int, int]]) -> None:
        """Stream all chunk ranges of a step to the slow tier.

        Without a stall timeout this is the plain multi-stream pool; with
        one, each stream carries a heartbeat and a watchdog supervises it
        (:meth:`_run_drain_tasks_watchdog`)."""
        if self.drain_streams <= 1 or len(tasks) <= 1:
            for path, off, length in tasks:
                self._drain_range(path, off, length)
        elif self.drain_stall_timeout is None:
            with ThreadPoolExecutor(
                min(self.drain_streams, len(tasks)),
                thread_name_prefix="bb-drain",
            ) as pool:
                futs = [pool.submit(self._drain_range, path, off, length)
                        for path, off, length in tasks]
                for f in futs:
                    f.result()
        else:
            self._run_drain_tasks_watchdog(tasks)

    def _run_drain_tasks_watchdog(self, tasks: List[Tuple[str, int, int]]) -> None:
        """Watchdog-supervised multi-stream drain.

        Streams pull chunks from a shared queue, recording a heartbeat
        (chunk + claim time) before each transfer.  The coordinator (the
        drain thread) polls at ``stall_timeout / 4``: a stream whose chunk
        has shown no progress past the timeout is **aborted** — marked
        dead, its chunk re-queued, and a replacement stream spawned — so a
        single wedged slow-tier op delays the drain by at most ~one timeout
        instead of hanging ``wait()`` forever.  Aborted streams are daemon
        threads left parked inside the stuck op (a thread blocked in a
        syscall cannot be killed); if the op ever completes, the duplicate
        chunk write is byte-identical and harmless.  A chunk that stalls on
        every attempt (initial + ``drain_requeue_limit`` re-queues) raises
        :class:`DrainStallError` through the normal drain-error path."""
        timeout = self.drain_stall_timeout
        n_tasks = len(tasks)
        cond = threading.Condition()
        pending: deque = deque((i, 0) for i in range(n_tasks))  # (idx, tries)
        done: set = set()
        claims: dict = {}    # stream id -> (task idx, tries, heartbeat time)
        dead: set = set()    # streams the watchdog gave up on
        errors: List[BaseException] = []
        threads: dict = {}
        next_sid = [0]

        def finished() -> bool:
            return len(done) >= n_tasks or bool(errors)

        def stream(sid: int) -> None:
            while True:
                with cond:
                    while True:
                        if finished() or sid in dead:
                            return
                        if pending:
                            idx, tries = pending.popleft()
                            if idx in done:  # a leaked duplicate landed it
                                continue
                            claims[sid] = (idx, tries, time.monotonic())
                            break
                        cond.wait(min(timeout / 4.0, 0.05))
                path, off, length = tasks[idx]
                try:
                    self._drain_range(path, off, length)
                except BaseException as e:
                    with cond:
                        claims.pop(sid, None)
                        if sid not in dead:  # an abandoned stream's error
                            errors.append(e)  # belongs to its re-queued copy
                        cond.notify_all()
                    return
                with cond:
                    claims.pop(sid, None)
                    done.add(idx)
                    cond.notify_all()
                    if sid in dead:
                        return

        def spawn() -> None:
            sid = next_sid[0]
            next_sid[0] += 1
            t = threading.Thread(target=stream, args=(sid,),
                                 name=f"bb-drain-{sid}", daemon=True)
            threads[sid] = t
            t.start()

        for _ in range(min(self.drain_streams, n_tasks)):
            spawn()

        with cond:
            while not finished():
                cond.wait(min(timeout / 4.0, 0.05))
                now = time.monotonic()
                for sid, (idx, tries, hb) in list(claims.items()):
                    if now - hb <= timeout:
                        continue
                    # stall: abort the stream, re-queue its chunk
                    dead.add(sid)
                    claims.pop(sid)
                    self.drain_stalls += 1
                    self.drain_aborts += 1
                    if metrics.enabled():
                        metrics.inc("ckpt.drain_stalls", 1, ckpt=self.prefix)
                        metrics.inc("ckpt.drain_aborts", 1, ckpt=self.prefix)
                    path, off, length = tasks[idx]
                    if tries >= self.drain_requeue_limit:
                        errors.append(DrainStallError(
                            f"drain chunk {path!r}@{off}+{length} stalled "
                            f"past {timeout}s on {tries + 1} attempts "
                            f"(requeue limit {self.drain_requeue_limit})"))
                    else:
                        pending.append((idx, tries + 1))
                        spawn()
                cond.notify_all()
            cond.notify_all()
        for sid, t in threads.items():
            if sid not in dead:  # dead streams stay parked in the stuck op
                t.join(timeout=timeout + 1.0)
        if errors:
            raise errors[0]

    def preempt(self, deadline_s: Optional[float] = None) -> PreemptionReport:
        """Graceful shutdown: stop accepting saves.  ``save()`` blocks
        through the fast-tier commit, so everything saved is already
        durable at the preemption tier; background drains keep running
        (they copy already-durable steps, nothing is abandoned)."""
        self._preempted = True
        return PreemptionReport(self.latest_step(), [], deadline_s, 0.0, True)

    def _slow_steps(self) -> List[int]:
        import json

        p = f"{self._dir}/{CHECKPOINT_MARKER}"
        if not self.slow.exists(p):
            return []
        return list(json.loads(self.slow.read_file(p)).get("all_steps", []))

    def _delete_slow_step(self, step: int) -> None:
        base = f"{self.prefix}-{step}".rsplit("/", 1)[-1]
        for name in self.slow.listdir(self._dir):
            if name.startswith(base + "."):
                self.slow.remove(f"{self._dir}/{name}")

    # -- consumer-side API ---------------------------------------------------------
    def _take_errors(self) -> List[BaseException]:
        with self._pending_lock:
            errors, self._errors = self._errors, []
        return errors

    def wait(self) -> None:
        """Block until all queued drains have completed; raise the first
        background error.  Errors are reported **once** — a failed drain
        does not re-raise on every later ``wait()`` (the report-once
        contract :meth:`AsyncCheckpointer.wait` documents)."""
        self._q.join()
        errors = self._take_errors()
        if errors:
            raise errors[0]

    def close(self) -> None:
        """Stop the drain thread; surface (not silently drop) any pending
        drain error that no ``wait()`` ever reported."""
        if self._thread is not None:
            self._q.put(None)
            self._thread.join(timeout=60)
            self._thread = None
        errors = self._take_errors()
        if errors:
            raise errors[0]

    def latest_step(self) -> Optional[int]:
        s = self.fast_saver.latest_step()
        if s is not None:
            return s
        return self._slow_latest()

    def _slow_latest(self) -> Optional[int]:
        import json

        p = f"{self._dir}/{CHECKPOINT_MARKER}"
        if not self.slow.exists(p):
            return None
        return json.loads(self.slow.read_file(p))["latest"]

    def restore_pytree(self, skeleton: Any, step: Optional[int] = None) -> Any:
        """Restore preferring the fast tier (paper: buffer holds the newest)."""
        try:
            return self.fast_saver.restore_pytree(skeleton, step)
        except (FileNotFoundError, KeyError, OSError, ValueError):
            # ValueError covers a corrupt (torn) fast-tier marker/index
            slow_saver = CheckpointSaver(self.slow, self.prefix, keep=self.keep)
            return slow_saver.restore_pytree(skeleton, step)

    def restore_sharded(self, skeleton, shardings, step=None):
        try:
            return self.fast_saver.restore_sharded(skeleton, shardings, step)
        except (FileNotFoundError, KeyError, OSError, ValueError):
            slow_saver = CheckpointSaver(self.slow, self.prefix, keep=self.keep)
            return slow_saver.restore_sharded(skeleton, shardings, step)
