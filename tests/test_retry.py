"""RetryPolicy / RetryingStorage: transient faults absorbed below every
consumer, sticky faults and semantic errors still surfaced unchanged."""
import time

import pytest

from repro.core.faults import FaultInjected, FaultyStorage, TransientFault
from repro.core.retry import (RetryPolicy, RetryingStorage, default_classifier,
                              retry_call)

FAST = RetryPolicy(max_attempts=5, base_delay_s=1e-5, max_delay_s=1e-4)


class TestTransientFaultModel:
    """The new non-sticky FaultyStorage mode itself."""

    def test_burst_then_device_recovers(self, tmp_storage):
        tmp_storage.write_file("a", b"payload")
        f = FaultyStorage(tmp_storage).transient(n_ops=2, ops=("read",))
        with pytest.raises(TransientFault):
            f.read_file("a")
        with pytest.raises(TransientFault):
            f.read_file("a")
        assert f.read_file("a") == b"payload"  # non-sticky: alive again
        assert f.transients_injected == 2

    def test_fires_before_op_so_no_bytes_land(self, tmp_storage):
        f = FaultyStorage(tmp_storage).transient(n_ops=1, ops=("write",))
        with pytest.raises(TransientFault):
            f.write_file("x", b"data")
        assert not tmp_storage.exists("x")
        f.write_file("x", b"data")  # retry of the same call succeeds
        assert tmp_storage.read_file("x") == b"data"

    def test_rate_is_seeded_and_reproducible(self, tmp_storage):
        tmp_storage.write_file("a", b"p")

        def run(seed):
            f = FaultyStorage(tmp_storage).transient(
                rate=0.3, ops=("read",), seed=seed)
            hits = []
            for _ in range(50):
                try:
                    f.read_file("a")
                    hits.append(0)
                except TransientFault:
                    hits.append(1)
            return hits

        assert run(7) == run(7)
        assert sum(run(7)) > 0
        assert run(7) != run(8)

    def test_path_filter(self, tmp_storage):
        tmp_storage.write_file("data/shard-0", b"x")
        tmp_storage.write_file("other", b"y")
        f = FaultyStorage(tmp_storage).transient(
            n_ops=10, on="shard", ops=("read",))
        assert f.read_file("other") == b"y"  # non-matching path untouched
        with pytest.raises(TransientFault):
            f.read_file("data/shard-0")

    def test_heal_clears_transient_arming(self, tmp_storage):
        tmp_storage.write_file("a", b"p")
        f = FaultyStorage(tmp_storage).transient(n_ops=100, ops=("read",))
        f.heal()
        assert f.read_file("a") == b"p"

    def test_independent_of_sticky_arming(self, tmp_storage):
        """Transient reads + sticky writes can be armed together."""
        tmp_storage.write_file("a", b"p")
        f = FaultyStorage(tmp_storage)
        f.transient(n_ops=1, ops=("read",)).fail_after(1, ops=("write",))
        with pytest.raises(TransientFault):
            f.read_file("a")
        assert f.read_file("a") == b"p"
        f.write_file("w", b"1")
        with pytest.raises(FaultInjected):
            f.write_file("x", b"2")

    def test_invalid_rate_rejected(self, tmp_storage):
        with pytest.raises(ValueError):
            FaultyStorage(tmp_storage).transient(rate=1.5)


class TestRetryPolicy:
    def test_backoff_is_bounded_full_jitter(self):
        import random

        p = RetryPolicy(base_delay_s=0.01, max_delay_s=0.05)
        rng = random.Random(0)
        for i in range(10):
            d = p.backoff_s(i, rng)
            assert 0.0 <= d <= min(0.05, 0.01 * 2 ** i)

    def test_classifier_retries_io_not_semantic_errors(self):
        assert default_classifier(OSError("flaky"))
        assert default_classifier(TimeoutError())
        assert default_classifier(TransientFault("x"))
        assert not default_classifier(FileNotFoundError("gone"))
        assert not default_classifier(PermissionError("denied"))
        assert not default_classifier(ValueError("bug"))
        assert not default_classifier(KeyError("bug"))

    def test_max_attempts_validated(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)

    def test_retry_call_succeeds_within_budget(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("transient")
            return "ok"

        assert retry_call(FAST, flaky) == "ok"
        assert len(calls) == 3

    def test_retry_call_reraises_original_on_exhaustion(self):
        err = OSError("always")

        def dead():
            raise err

        with pytest.raises(OSError) as ei:
            retry_call(RetryPolicy(max_attempts=3, base_delay_s=1e-5), dead)
        assert ei.value is err  # the original, not a wrapper

    def test_non_retryable_raises_immediately(self):
        calls = []

        def bad():
            calls.append(1)
            raise FileNotFoundError("gone")

        with pytest.raises(FileNotFoundError):
            retry_call(FAST, bad)
        assert len(calls) == 1

    def test_deadline_cuts_retries_short(self):
        p = RetryPolicy(max_attempts=1000, base_delay_s=0.02,
                        max_delay_s=0.02, deadline_s=0.05)
        calls = []

        def dead():
            calls.append(1)
            raise OSError("down")

        t0 = time.monotonic()
        with pytest.raises(OSError):
            retry_call(p, dead)
        assert time.monotonic() - t0 < 2.0
        assert len(calls) < 1000


class TestRetryingStorage:
    def test_transparent_read_retry_and_counters(self, tmp_storage):
        tmp_storage.write_file("a", b"payload")
        f = FaultyStorage(tmp_storage).transient(n_ops=3, ops=("read",))
        rs = RetryingStorage(f, FAST)
        assert rs.read_file("a") == b"payload"
        assert rs.retries == 3
        assert rs.gave_up == 0
        assert f.transients_injected == 3

    def test_write_and_range_ops_retry(self, tmp_storage):
        f = FaultyStorage(tmp_storage).transient(
            n_ops=2, ops=("write", "append"))
        rs = RetryingStorage(f, FAST)
        rs.write_file("x", b"0123456789")
        assert tmp_storage.read_file("x") == b"0123456789"
        f.transient(n_ops=1, ops=("write",))
        rs.write_range("x", 2, b"AB")
        assert tmp_storage.read_file("x") == b"01AB456789"
        f.transient(n_ops=1, ops=("read",))
        assert rs.read_range("x", 0, 4) == b"01AB"

    def test_sticky_fault_exhausts_budget_and_reraises(self, tmp_storage):
        tmp_storage.write_file("a", b"p")
        f = FaultyStorage(tmp_storage).fail_after(0, ops=("read",))
        rs = RetryingStorage(f, RetryPolicy(max_attempts=3, base_delay_s=1e-5))
        with pytest.raises(FaultInjected):  # the original error type
            rs.read_file("a")
        assert rs.retries == 2        # attempts 2 and 3 were retries
        assert rs.gave_up == 1
        assert rs.give_up_log[0][0] == "read_file"

    def test_burst_longer_than_budget_gives_up(self, tmp_storage):
        tmp_storage.write_file("a", b"p")
        f = FaultyStorage(tmp_storage).transient(n_ops=10, ops=("read",))
        rs = RetryingStorage(f, RetryPolicy(max_attempts=3, base_delay_s=1e-5))
        with pytest.raises(TransientFault):
            rs.read_file("a")
        assert rs.gave_up == 1

    def test_missing_file_not_retried(self, tmp_storage):
        rs = RetryingStorage(tmp_storage, FAST)
        with pytest.raises(FileNotFoundError):
            rs.read_file("nope")
        assert rs.retries == 0  # semantic error: no budget burned

    def test_retry_writes_false_passes_through(self, tmp_storage):
        f = FaultyStorage(tmp_storage).transient(n_ops=1, ops=("write",))
        rs = RetryingStorage(f, FAST, retry_writes=False)
        with pytest.raises(TransientFault):
            rs.write_file("x", b"1")
        rs.write_file("x", b"1")  # device recovered; reads still retried

    def test_namespace_ops_delegate(self, tmp_storage):
        rs = RetryingStorage(tmp_storage, FAST)
        rs.makedirs("d")
        rs.write_file("d/a", b"1")
        assert rs.exists("d/a")
        assert "a" in rs.listdir("d")
        assert rs.size("d/a") == 1
        rs.rename("d/a", "d/b")
        assert rs.read_file("d/b") == b"1"
        rs.remove("d/b")
        assert not rs.exists("d/b")
        assert rs.name == f"retry({tmp_storage.name})"

    def test_counters_flow_to_live_metrics(self, tmp_storage):
        from repro import metrics

        tmp_storage.write_file("a", b"p")
        reg = metrics.start()
        try:
            f = FaultyStorage(tmp_storage).transient(n_ops=2, ops=("read",))
            rs = RetryingStorage(f, FAST)
            rs.read_file("a")
            f.fail_after(0, ops=("read",))
            with pytest.raises(FaultInjected):
                rs.read_file("a")
            counters = reg.collect()["counters"]
            retries = sum(v for k, v in counters.items()
                          if k.startswith("storage.retries"))
            gave_up = sum(v for k, v in counters.items()
                          if k.startswith("storage.gave_up"))
            assert retries >= 2
            assert gave_up == 1
        finally:
            metrics.stop()


class TestSleepHook:
    """RetryPolicy(sleep=...): backoff waits are injectable (fig13 drives
    them from the simulator's paced clock)."""

    def test_injected_sleep_receives_jittered_backoff(self):
        slept = []
        pol = RetryPolicy(max_attempts=4, base_delay_s=0.05, max_delay_s=0.5,
                          sleep=slept.append)
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 4:
                raise TimeoutError("flaky")
            return "ok"

        t0 = time.monotonic()
        assert retry_call(pol, flaky) == "ok"
        assert time.monotonic() - t0 < 0.05     # nothing actually slept
        assert len(slept) == 3
        for i, d in enumerate(slept):
            assert 0.0 <= d <= min(0.5, 0.05 * 2 ** i)

    def test_default_sleep_is_time_sleep(self):
        assert RetryPolicy().sleep is time.sleep

    def test_paced_sleep_runs_on_scaled_clock(self):
        import tempfile

        from repro.core.storage import SimulatedStorage, TIERS

        with tempfile.TemporaryDirectory() as d:
            sim = SimulatedStorage(d, TIERS["optane"], time_scale=0.01)
            t0 = time.monotonic()
            sim.paced_sleep(1.0)        # 1 s modelled -> 10 ms wall
            assert time.monotonic() - t0 < 0.5

    def test_retrying_storage_with_paced_backoff(self, tmp_storage):
        import tempfile as _tf

        from repro.core.storage import SimulatedStorage, TIERS

        with _tf.TemporaryDirectory() as d:
            sim = SimulatedStorage(d, TIERS["optane"], time_scale=0.01)
            sim.write_file("a", b"payload")
            f = FaultyStorage(sim).transient(n_ops=2, ops=("read",))
            pol = RetryPolicy(max_attempts=5, base_delay_s=0.2,
                              max_delay_s=0.2, sleep=sim.paced_sleep)
            rs = RetryingStorage(f, pol)
            t0 = time.monotonic()
            assert rs.read_file("a") == b"payload"
            # two retries of <=0.2 s modelled backoff -> milliseconds wall
            assert time.monotonic() - t0 < 1.0
            assert rs.retries == 2


class TestGiveUpLogRing:
    def test_log_bounded_counter_exact(self, tmp_storage):
        """A long soak against a dead tier must not grow memory: the log is
        a ring of the last GIVE_UP_LOG_LIMIT entries, ``gave_up`` is exact."""
        from repro.core.retry import GIVE_UP_LOG_LIMIT

        f = FaultyStorage(tmp_storage).fail_after(0, ops=("read",))
        rs = RetryingStorage(f, RetryPolicy(max_attempts=1))
        n = GIVE_UP_LOG_LIMIT + 20
        for i in range(n):
            with pytest.raises(FaultInjected):
                rs.read_file(f"p{i:04d}")
        assert rs.gave_up == n
        assert len(rs.give_up_log) == GIVE_UP_LOG_LIMIT
        # the ring keeps the newest entries, oldest evicted first
        assert f"p{n - 1:04d}" in rs.give_up_log[-1][1]
        assert f"p{n - GIVE_UP_LOG_LIMIT:04d}" in rs.give_up_log[0][1]
