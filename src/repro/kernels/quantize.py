"""Blockwise int8 quantize/dequantize — Pallas TPU kernel.

The transform behind three framework features: int8 optimizer states,
int8 checkpoint payloads (smaller bursts through the burst buffer), and the
compressed DCN gradient all-reduce.

Layout: values are viewed as (n_blocks, BLOCK) with BLOCK=256 lanes (two
128-lane registers), absmax-scaled per block to int8:

    scale = absmax(block) / 127 ;  q = round(x / scale)

Tiling: each grid step processes a (ROWS_PER_TILE, 256) VMEM tile — 8
sublanes x 256 lanes of fp32 in, int8 out + (ROWS_PER_TILE, 1) scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256
ROWS_PER_TILE = 256


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                 # (rows, BLOCK)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def quantize_blocks(x: jax.Array, *, interpret: bool = True):
    """x: (n_blocks, BLOCK) fp32/bf16 -> (q int8, scales fp32 (n_blocks,1))."""
    n, b = x.shape
    assert b == BLOCK, f"expected block dim {BLOCK}, got {b}"
    rows = min(ROWS_PER_TILE, n)
    grid = (pl.cdiv(n, rows),)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_blocks(q: jax.Array, s: jax.Array, *, interpret: bool = True):
    """(q int8 (n,BLOCK), scales (n,1)) -> fp32 (n, BLOCK)."""
    n, b = q.shape
    assert b == BLOCK
    rows = min(ROWS_PER_TILE, n)
    grid = (pl.cdiv(n, rows),)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, s)
