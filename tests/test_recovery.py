"""End-to-end crash recovery: the paper's "restart quickly from a
checkpoint", actually proven.

The harness trains a tiny deterministic model whose final params fold in
*every consumed batch in order* (``w = w/2 + batch``), so bit-identical
final params after a kill+resume proves the resumed run consumed exactly
the golden sample stream — no skipped and no replayed samples relative to
the checkpointed pipeline position.  The kill sweep dies at **every write
op** of a full training run (data shards, index, meta, commit marker, GC
marker — i.e. mid-save and mid-GC), under the clean, torn-write and
reordered-fsync+crash fault models, plus mid-step abandonment and
mid-drain kills through the burst-buffer engine; transient faults are
absorbed in place by the retry layer.
"""
import tempfile

import numpy as np
import pytest

from repro.core.burst_buffer import BurstBufferCheckpointer
from repro.core.checkpoint import CheckpointSaver
from repro.core.dataset import Dataset, ResumableIterator
from repro.core.faults import FaultInjected, FaultyStorage, TransientFault
from repro.core.recovery import (CheckpointManager, latest_valid_step,
                                 list_steps, valid_steps, validate_step)
from repro.core.retry import RetryPolicy, RetryingStorage
from repro.core.storage import NativeStorage

FAST_RETRY = RetryPolicy(max_attempts=6, base_delay_s=1e-5, max_delay_s=1e-4)

N_PER_EPOCH = 6
N_STEPS = 8
CKPT_EVERY = 2
PREFIX = "ckpt/m"


def sample_value(epoch: int, i: int) -> np.float64:
    return np.float64(epoch * 1000 + i + 1)


def make_iter() -> ResumableIterator:
    return ResumableIterator(lambda ep: Dataset.from_tensor_slices(
        [sample_value(ep, i) for i in range(N_PER_EPOCH)]))


def make_setup(consumed):
    """State + step fn: ``w`` folds in every batch (order-sensitive)."""
    state = {"w": np.float64(0.0), "step": np.int64(0)}

    def train_step(state, batch):
        b = np.float64(batch)
        consumed.append(float(b))
        new = {"w": state["w"] * np.float64(0.5) + b,
               "step": state["step"] + np.int64(1)}
        return new, {"loss": b}

    return state, train_step


def make_trainer(checkpointer, consumed, it=None):
    from repro.train.trainer import Trainer

    state, step_fn = make_setup(consumed)
    it = it if it is not None else make_iter()
    return Trainer(step_fn, state, it, checkpointer=checkpointer,
                   ckpt_every=CKPT_EVERY)


def golden_run():
    """Fault-free reference: (final_w, consumed sample stream)."""
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(NativeStorage(d), PREFIX, keep_last=2)
        consumed = []
        tr = make_trainer(mgr, consumed)
        tr.run(N_STEPS)
        return float(np.asarray(tr.state["w"])), consumed


def finish_from_checkpoint(storage, golden_w, golden_stream, ctx="",
                           keep_last=2):
    """Restart on ``storage``, run to N_STEPS total, assert bit-identical
    params and an exactly-aligned sample stream."""
    mgr = CheckpointManager(storage, PREFIX, keep_last=keep_last)
    consumed = []
    tr = make_trainer(mgr, consumed)
    start = tr.recovered_step or 0
    tr.run(N_STEPS - start)
    assert float(np.asarray(tr.state["w"])) == golden_w, ctx
    assert consumed == golden_stream[start:], ctx
    return start


def count_write_ops():
    """Clean run: total write ops issued (the sweep's injection points)."""
    with tempfile.TemporaryDirectory() as d:
        faulty = FaultyStorage(NativeStorage(d))
        mgr = CheckpointManager(faulty, PREFIX, keep_last=2)
        tr = make_trainer(mgr, [])
        tr.run(N_STEPS)
        return sum(1 for op, _, _ in faulty.op_log
                   if op.startswith("write") or op == "append_file")


# ---------------------------------------------------------------------------
# the kill sweep: die at every write op, under every fault model
# ---------------------------------------------------------------------------
class TestKillSweep:
    @pytest.mark.parametrize("model", ["clean", "torn"])
    def test_kill_at_every_write_op_then_resume(self, model):
        """Mid-save and mid-GC kills: every write op of the run is an
        injection point (shards, index, meta, save marker, GC marker)."""
        golden_w, golden_stream = golden_run()
        n_ops = count_write_ops()
        assert n_ops >= 8, "sweep must cover shards+index+meta+markers"
        for k in range(n_ops):
            with tempfile.TemporaryDirectory() as d:
                faulty = FaultyStorage(NativeStorage(d))
                mgr = CheckpointManager(faulty, PREFIX, keep_last=2)
                tr = make_trainer(mgr, [])
                if model == "clean":
                    faulty.fail_after(k)
                else:
                    faulty.torn_write(0.5, n_ops=k)
                with pytest.raises(FaultInjected):
                    tr.run(N_STEPS)
                tr.close()
                faulty.heal()
                finish_from_checkpoint(faulty, golden_w, golden_stream,
                                       ctx=f"model={model}, op {k}/{n_ops}")

    def test_mid_step_abandonment_at_every_step(self):
        """Kill between steps (no storage fault): resume replays only the
        post-checkpoint tail and still lands on the golden bits."""
        golden_w, golden_stream = golden_run()
        for j in range(1, N_STEPS):
            with tempfile.TemporaryDirectory() as d:
                storage = NativeStorage(d)
                mgr = CheckpointManager(storage, PREFIX, keep_last=2)
                tr = make_trainer(mgr, [])
                tr.run(j)      # process dies here: no final checkpoint
                tr.close()
                start = finish_from_checkpoint(
                    storage, golden_w, golden_stream, ctx=f"killed at {j}")
                assert start <= j  # resumed at/before the kill point

    def test_reordered_fsync_crash_then_resume(self):
        """Power loss with volatile caches (sync=False saves): unsynced
        writes roll back / survive out of order; restart must walk back to
        whatever is structurally valid and still finish bit-identical."""
        golden_w, golden_stream = golden_run()
        for j in range(1, N_STEPS):
            for keep in ("last", "none"):
                with tempfile.TemporaryDirectory() as d:
                    faulty = FaultyStorage(
                        NativeStorage(d)).reordered_fsync()
                    mgr = CheckpointManager(faulty, PREFIX, keep_last=2,
                                            sync=False)
                    tr = make_trainer(mgr, [])
                    tr.run(j)
                    tr.close()
                    faulty.crash(keep=keep)
                    faulty.heal()
                    finish_from_checkpoint(
                        faulty, golden_w, golden_stream,
                        ctx=f"crash(keep={keep}) after {j}")

    def test_transient_faults_absorbed_in_place(self):
        """A flaky (not dead) device under a retry-wrapped manager: the run
        completes without any restart and matches golden exactly."""
        golden_w, golden_stream = golden_run()
        with tempfile.TemporaryDirectory() as d:
            faulty = FaultyStorage(NativeStorage(d)).transient(
                rate=0.2, ops=("read", "write"), seed=11)
            mgr = CheckpointManager(faulty, PREFIX, keep_last=2,
                                    retry_policy=FAST_RETRY)
            consumed = []
            tr = make_trainer(mgr, consumed)
            tr.run(N_STEPS)
            assert float(np.asarray(tr.state["w"])) == golden_w
            assert consumed == golden_stream
            assert faulty.transients_injected > 0
            assert mgr.storage.retries >= faulty.transients_injected
            assert mgr.storage.gave_up == 0

    def test_transient_burst_beyond_budget_then_resume(self):
        """A transient burst longer than the retry budget escapes, kills
        the run — and the restart still recovers (transient x mid-save)."""
        golden_w, golden_stream = golden_run()
        with tempfile.TemporaryDirectory() as d:
            faulty = FaultyStorage(NativeStorage(d))
            mgr = CheckpointManager(faulty, PREFIX, keep_last=2,
                                    retry_policy=FAST_RETRY)
            tr = make_trainer(mgr, [])
            tr.run(3)  # checkpoint at step 2 landed
            faulty.transient(n_ops=50, ops=("write",))
            with pytest.raises(TransientFault):
                tr.run(N_STEPS - 3)
            tr.close()
            assert mgr.storage.gave_up >= 1
            faulty.heal()
            finish_from_checkpoint(faulty, golden_w, golden_stream,
                                   ctx="transient burst")


class TestMidDrainKill:
    """Kills inside the burst-buffer drain, recovery from the slow tier
    alone (the node — and its fast tier — is gone)."""

    def _run_with_bb(self, fast, slow, consumed):
        bb = BurstBufferCheckpointer(fast, slow, PREFIX)
        tr = make_trainer(bb, consumed)
        tr.run(N_STEPS)
        return tr, bb

    def _count_slow_write_ops(self):
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            slow = FaultyStorage(NativeStorage(d2))
            tr, bb = self._run_with_bb(NativeStorage(d1), slow, [])
            tr.wait_for_checkpoints()
            bb.close()
            tr.close()
            return sum(1 for op, _, _ in slow.op_log
                       if op.startswith("write") or op == "append_file")

    def test_drain_killed_at_every_slow_write_op(self):
        golden_w, golden_stream = golden_run()
        n_ops = self._count_slow_write_ops()
        assert n_ops >= 8  # several drains x (data+index+meta+marker)
        for k in range(n_ops):
            with tempfile.TemporaryDirectory() as d1, \
                    tempfile.TemporaryDirectory() as d2:
                slow_inner = NativeStorage(d2)
                slow = FaultyStorage(slow_inner).torn_write(0.5, n_ops=k)
                tr, bb = self._run_with_bb(NativeStorage(d1), slow, [])
                with pytest.raises(FaultInjected):
                    tr.wait_for_checkpoints()
                try:
                    bb.close()
                except FaultInjected:
                    pass  # later drains of the same cascade
                tr.close()
                # fast tier is gone with the node: slow tier must carry a
                # valid step with pipeline position in its meta
                # early k: the fault may predate the first completed drain,
                # in which case a fresh start is the correct recovery
                finish_from_checkpoint(
                    slow_inner, golden_w, golden_stream,
                    ctx=f"drain op {k}/{n_ops}")


# ---------------------------------------------------------------------------
# CheckpointManager: retention, GC, corruption-aware restore
# ---------------------------------------------------------------------------
def small_tree(step: int):
    rng = np.random.default_rng(step)
    return {"w": rng.normal(size=(32,)).astype(np.float32),
            "step": np.int64(step)}


class TestCheckpointManager:
    def test_keep_last_bounds_disk(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX, keep_last=3)
        for s in range(1, 11):
            mgr.save(s, small_tree(s))
        assert mgr.all_steps() == [8, 9, 10]
        names = tmp_storage.listdir("ckpt")
        # 3 steps x (data+index+meta) + marker — nothing strays
        assert len([n for n in names if n != "checkpoint"]) == 9
        assert set(mgr.gc_deleted) == set(range(1, 8))

    def test_keep_every_pins_milestones(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX, keep_last=2,
                                keep_every=5)
        for s in range(1, 13):
            mgr.save(s, small_tree(s))
        assert mgr.all_steps() == [5, 10, 11, 12]

    def test_gc_never_deletes_only_valid_target(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX, keep_last=2)
        trees = {s: small_tree(s) for s in (1, 2, 3)}
        for s in (1, 2, 3):
            mgr.save(s, trees[s])
        assert mgr.all_steps() == [2, 3]
        # newest step torn: the only valid target is now 2, which plain
        # keep_last=1 retention would delete
        tmp_storage.write_file(f"{PREFIX}-3.data-00000-of-00001", b"xx")
        mgr2 = CheckpointManager(tmp_storage, PREFIX, keep_last=1)
        deleted = mgr2.gc()
        assert 2 not in deleted
        assert mgr2.latest_valid() == 2
        flat, _, s = mgr2.restore()
        assert s == 2
        np.testing.assert_array_equal(flat["w"], trees[2]["w"])

    def test_restore_walks_back_past_corruption(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX, keep_last=5)
        trees = {s: small_tree(s) for s in (1, 2, 3)}
        for s in (1, 2, 3):
            mgr.save(s, trees[s])
        # torn shard on 3, truncated meta on 2
        tmp_storage.write_file(f"{PREFIX}-3.data-00000-of-00001", b"torn")
        tmp_storage.write_file(f"{PREFIX}-2.meta", b'{"step"')
        assert mgr.latest_valid() == 1
        flat, meta, s = mgr.restore()
        assert s == 1
        np.testing.assert_array_equal(flat["w"], trees[1]["w"])

    def test_restore_survives_missing_marker(self, tmp_storage):
        """Marker-fallback: candidates come from the directory listing."""
        mgr = CheckpointManager(tmp_storage, PREFIX, keep_last=5)
        t = small_tree(7)
        mgr.save(7, t)
        tmp_storage.remove("ckpt/checkpoint")
        mgr2 = CheckpointManager(tmp_storage, PREFIX, keep_last=5)
        assert mgr2.latest_valid() == 7
        flat, _, s = mgr2.restore()
        assert s == 7
        np.testing.assert_array_equal(flat["w"], t["w"])

    def test_restore_survives_corrupt_marker(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX, keep_last=5)
        t = small_tree(1)
        mgr.save(1, t)
        tmp_storage.write_file("ckpt/checkpoint", b"{torn j")
        assert latest_valid_step(tmp_storage, PREFIX) == 1
        flat, _, s = mgr.restore()
        assert s == 1

    def test_gc_reclaims_strays_from_interrupted_gc(self, tmp_storage):
        """Files of a step outside the marker (crash between marker rewrite
        and deletion) are swept by the next GC."""
        mgr = CheckpointManager(tmp_storage, PREFIX, keep_last=2)
        for s in (1, 2, 3):
            mgr.save(s, small_tree(s))
        # simulate a crashed GC that never deleted step 1's files
        saver = CheckpointSaver(tmp_storage, PREFIX)
        saver.save_flat(1, {"w": np.zeros(4, np.float32)})
        assert 1 in list_steps(tmp_storage, PREFIX)
        mgr.gc()
        assert 1 not in list_steps(tmp_storage, PREFIX)

    def test_resume_fresh_when_nothing_saved(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX)
        skeleton = {"w": np.zeros(4)}
        res = mgr.resume(skeleton)
        assert res.fresh and res.step is None
        assert res.state is skeleton

    def test_resume_restores_params_and_iterator(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX)
        t = small_tree(4)
        it = make_iter()
        for _ in range(4):
            next(it)
        mgr.save(4, t, extra_meta={"pipeline": it.state()})
        it2 = make_iter()
        res = mgr.resume(small_tree(0), data_iter=it2)
        assert res.step == 4
        assert res.pipeline == {"epoch": 0, "offset": 4, "version": 1}
        np.testing.assert_array_equal(res.state["w"], t["w"])
        assert float(next(it2)) == float(sample_value(0, 4))

    def test_explicit_step_restore(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX, keep_last=5)
        trees = {s: small_tree(s) for s in (1, 2)}
        for s in (1, 2):
            mgr.save(s, trees[s])
        flat, _, s = mgr.restore(step=1)
        assert s == 1
        np.testing.assert_array_equal(flat["w"], trees[1]["w"])

    def test_validation_args(self, tmp_storage):
        with pytest.raises(ValueError):
            CheckpointManager(tmp_storage, PREFIX, keep_last=0)
        with pytest.raises(ValueError):
            CheckpointManager(tmp_storage, PREFIX, keep_every=0)


class TestParallelRestoreUnderTransients:
    def test_io_threads_restore_with_transient_read_faults(self, tmp_storage):
        """Satellite: parallel-shard restore (io_threads > 1) was only
        tested fault-free — under transient read faults every shard read
        must retry independently and the restore must be bit-identical."""
        faulty = FaultyStorage(tmp_storage)
        rs = RetryingStorage(faulty, FAST_RETRY)
        saver = CheckpointSaver(rs, PREFIX, n_shards=4, io_threads=4)
        rng = np.random.default_rng(0)
        t = {f"w{i}": rng.normal(size=(64, 16)).astype(np.float32)
             for i in range(6)}
        saver.save(1, t)
        # seeded rate spreads faults across the concurrent shard reads
        # (a burst would be absorbed by whichever read hits it first)
        faulty.transient(rate=0.3, ops=("read",), seed=5)
        out = saver.restore_pytree(t)
        for k in t:
            np.testing.assert_array_equal(out[k], t[k])
        assert faulty.transients_injected > 0
        assert rs.retries == faulty.transients_injected and rs.gave_up == 0

    def test_io_threads_restore_gives_up_on_dead_device(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        rs = RetryingStorage(faulty, RetryPolicy(max_attempts=2,
                                                 base_delay_s=1e-5))
        saver = CheckpointSaver(rs, PREFIX, n_shards=4, io_threads=4)
        t = {"w": np.arange(512, dtype=np.float32)}
        saver.save(1, t)
        faulty.fail_after(0, ops=("read",))
        with pytest.raises(FaultInjected):
            saver.restore_pytree(t)
        assert rs.gave_up >= 1


# ---------------------------------------------------------------------------
# pipeline: retry transparency + shard quarantine
# ---------------------------------------------------------------------------
class TestPipelineRetryAndQuarantine:
    def _shards(self, storage, n=3, recs=4):
        names = []
        for i in range(n):
            storage.write_file(f"s{i}", bytes(range(i * recs, (i + 1) * recs)))
            names.append(f"s{i}")
        return names

    def test_transient_reads_absorbed_no_drops(self, tmp_storage):
        names = self._shards(tmp_storage)
        faulty = FaultyStorage(tmp_storage).transient(n_ops=2, ops=("read",))
        rs = RetryingStorage(faulty, FAST_RETRY)

        def stream(name):
            return iter(rs.read_file(name))

        ds = Dataset.from_tensor_slices(names).interleave(
            stream, cycle_length=2).ignore_errors()
        out = sorted(ds.as_numpy())
        assert out == list(range(12))  # nothing dropped, nothing duplicated
        assert rs.retries >= 2 and rs.gave_up == 0

    def test_shard_quarantined_only_after_budget_exhausted(self, tmp_storage):
        from repro import metrics

        names = self._shards(tmp_storage)
        # every read of s1 fails (path-filtered burst), without the device
        # going sticky-dead for the other shards
        faulty = FaultyStorage(tmp_storage).transient(
            n_ops=100, on="s1", ops=("read",))
        rs = RetryingStorage(faulty, RetryPolicy(max_attempts=3,
                                                 base_delay_s=1e-5))

        def stream(name):
            return iter(rs.read_file(name))

        reg = metrics.start()
        try:
            ds = Dataset.from_tensor_slices(names).interleave(
                stream, cycle_length=3).ignore_errors()
            out = sorted(ds.as_numpy())
            # s1's records are gone (quarantined), the rest all survive
            assert out == list(range(0, 4)) + list(range(8, 12))
            counters = reg.collect()["counters"]
            quarantined = sum(v for k, v in counters.items()
                              if k.startswith("pipeline.quarantined_shards"))
            assert quarantined == 1
        finally:
            metrics.stop()
        assert rs.gave_up == 1  # the drop happened only after the budget


# ---------------------------------------------------------------------------
# ResumableIterator semantics
# ---------------------------------------------------------------------------
class TestResumableIterator:
    def test_epoch_rollover_and_bounded_epochs(self):
        it = ResumableIterator(lambda ep: Dataset.from_tensor_slices(
            [sample_value(ep, i) for i in range(3)]), epochs=2)
        vals = [float(v) for v in it]
        assert vals == [1.0, 2.0, 3.0, 1001.0, 1002.0, 1003.0]
        assert it.state() == {"epoch": 2, "offset": 0, "version": 1}

    def test_state_counts_delivered_not_prefetched(self):
        ds = Dataset.from_tensor_slices(list(range(10))).prefetch(4)
        it = ResumableIterator(ds)
        for _ in range(3):
            next(it)
        # prefetch buffer is ahead, but only 3 elements were delivered
        assert it.state()["offset"] == 3
        it.close()

    def test_restore_mid_epoch_resumes_exact_element(self):
        def factory(ep):
            return Dataset.from_tensor_slices(
                [sample_value(ep, i) for i in range(5)])

        it = ResumableIterator(factory)
        got = [float(next(it)) for _ in range(7)]
        st = it.state()
        it2 = ResumableIterator(factory)
        it2.restore_state(st)
        tail = [float(next(it2)) for _ in range(3)]
        more = [float(next(it)) for _ in range(3)]
        assert tail == more
        it.close(), it2.close()

    def test_restore_replays_per_epoch_shuffle_order(self):
        """A seeded-per-epoch shuffle factory must resume onto the exact
        same shuffled order (the factory rebuilds epoch e from its seed)."""
        def factory(ep):
            return Dataset.from_tensor_slices(
                list(range(8))).shuffle(8, seed=100 + ep)

        it = ResumableIterator(factory)
        [next(it) for _ in range(11)]  # 3 elements into epoch 1
        st = it.state()
        it2 = ResumableIterator(factory)
        it2.restore_state(st)
        assert [next(it2) for _ in range(5)] == [next(it) for _ in range(5)]
        # epoch 1's order actually differs from epoch 0's (seed moved)
        assert list(factory(0)) != list(factory(1))
        it.close(), it2.close()

    def test_restore_past_end_rolls_into_next_epoch(self):
        factory = lambda ep: Dataset.from_tensor_slices([ep * 10, ep * 10 + 1])
        it = ResumableIterator(factory)
        it.restore_state({"epoch": 0, "offset": 2, "version": 1})
        assert next(it) == 10  # epoch 0 exhausted by the skip -> epoch 1

    def test_empty_source_terminates(self):
        it = ResumableIterator(Dataset.from_tensor_slices([]))
        with pytest.raises(StopIteration):
            next(it)

    def test_dataset_source_repeats_same_order(self):
        it = ResumableIterator(Dataset.from_tensor_slices([1, 2]), epochs=3)
        assert list(it) == [1, 2, 1, 2, 1, 2]

    def test_rejects_non_dataset_source(self):
        with pytest.raises(TypeError):
            ResumableIterator([1, 2, 3])

    def test_context_manager_closes(self):
        ds = Dataset.from_tensor_slices(list(range(4))).prefetch(2)
        with ResumableIterator(ds) as it:
            next(it)
        assert it._it is None


# ---------------------------------------------------------------------------
# fused manager (PR 10): lifecycle states, deferred GC, dual-tier restore
# ---------------------------------------------------------------------------
def _wait_until(cond, timeout=10.0):
    import time

    deadline = time.monotonic() + timeout
    while not cond():
        if time.monotonic() >= deadline:
            return False
        time.sleep(0.005)
    return True


class TestFusedManager:
    def test_lifecycle_states_direct_engine(self, tmp_storage):
        from repro.core.recovery import COMMITTED

        mgr = CheckpointManager(tmp_storage, PREFIX)
        mgr.save(1, small_tree(1))
        assert mgr.step_states()[1] == COMMITTED

    def test_lifecycle_states_async_engine(self, tmp_storage):
        from repro.core.recovery import COMMITTED

        mgr = CheckpointManager(tmp_storage, PREFIX, engine="async")
        mgr.save(1, small_tree(1))
        mgr.wait()
        assert mgr.step_states()[1] == COMMITTED
        mgr.close()

    def test_gc_deferred_past_drain_commit(self):
        """Retention must never collect a step staged on the fast tier but
        not yet drained — it is the preemption-restart target."""
        from repro.core.recovery import COMMITTED, STAGED

        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            fast = NativeStorage(d1)
            slow = FaultyStorage(NativeStorage(d2))
            mgr = CheckpointManager(slow, PREFIX, engine="asyncbb",
                                    fast_storage=fast, keep_last=2,
                                    max_pending=2)
            slow.hang(on=".data-", repeat=True)  # drains wedge forever
            trees = {s: small_tree(s) for s in range(1, 6)}
            for s in range(1, 6):
                mgr.save(s, trees[s])
            assert _wait_until(lambda: mgr.engine.pending() == 0)
            # every step staged, none drained: GC has never run.  (Partial
            # slow-tier files may exist — index/meta chunks drain on other
            # streams — but nothing validates and nothing was collected.)
            states = mgr.step_states()
            assert all(states[s] == STAGED for s in range(1, 6))
            assert valid_steps(slow, PREFIX) == []
            assert mgr.gc_deleted == []
            assert mgr.valid_steps() == [1, 2, 3, 4, 5]  # fast tier carries
            assert mgr.latest_valid() == 5
            flat, _, s = mgr.restore()
            assert s == 5
            np.testing.assert_array_equal(flat["w"], trees[5]["w"])
            # un-wedge: drains commit in order, deferred GC kicks in
            slow.heal()
            mgr.wait()
            assert mgr.step_states()[5] == COMMITTED
            assert mgr.all_steps() == [4, 5]  # keep_last applied, at last
            assert set(mgr.gc_deleted) == {1, 2, 3}
            mgr.close()

    def test_restore_falls_back_when_fast_tier_corrupt(self):
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            fast, slow = NativeStorage(d1), NativeStorage(d2)
            mgr = CheckpointManager(slow, PREFIX, engine="bb",
                                    fast_storage=fast, keep_last=3)
            t = small_tree(1)
            mgr.save(1, t)
            mgr.wait()
            # fast copy torn after the drain: restore must take the slow one
            fast.write_file(f"{PREFIX}-1.data-00000-of-00001", b"xx")
            flat, _, s = mgr.restore()
            assert s == 1
            np.testing.assert_array_equal(flat["w"], t["w"])
            mgr.close()

    def test_close_idempotent_and_error_exactly_once(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage).fail_on(".data-")
        mgr = CheckpointManager(faulty, PREFIX, engine="async")
        mgr.save(1, small_tree(1))  # background write will die
        with pytest.raises(FaultInjected):
            mgr.close()
        mgr.close()  # second close: no-op, the error was delivered once
        with pytest.raises(RuntimeError):
            mgr.save(2, small_tree(2))

    def test_close_with_pending_saves_drains_them(self):
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            fast, slow = NativeStorage(d1), NativeStorage(d2)
            mgr = CheckpointManager(slow, PREFIX, engine="asyncbb",
                                    fast_storage=fast, keep_last=3)
            for s in (1, 2, 3):
                mgr.save(s, small_tree(s))
            mgr.close()  # drains the stager and the drain queue
            mgr.close()  # idempotent
            assert latest_valid_step(slow, PREFIX) == 3

    def test_blocked_s_comes_from_engine(self, tmp_storage):
        mgr = CheckpointManager(tmp_storage, PREFIX, engine="async")
        mgr.save(1, small_tree(1))
        mgr.wait()
        assert mgr.blocked_s is mgr.engine.blocked_s
        assert len(mgr.blocked_s) == 1
        mgr.close()

    def test_engine_validation(self, tmp_storage):
        with pytest.raises(ValueError):
            CheckpointManager(tmp_storage, PREFIX, engine="warp")
        with pytest.raises(ValueError):
            CheckpointManager(tmp_storage, PREFIX, engine="asyncbb")


# ---------------------------------------------------------------------------
# satellite: kill sweep through the fused manager + asyncbb engine
# ---------------------------------------------------------------------------
class TestFusedKillSweep:
    """The TestKillSweep guarantee, re-proven through the fused
    manager+asyncbb save/drain/GC path: die (or wedge) at every slow-tier
    write op and the restart — on a fresh node with an empty fast tier —
    still lands bit-identical params with no skipped/replayed samples."""

    def _fused_mgr(self, fast, slow, **kw):
        kw.setdefault("keep_last", 2)
        return CheckpointManager(slow, PREFIX, engine="asyncbb",
                                 fast_storage=fast, **kw)

    def _finish_fused(self, slow_storage, golden_w, golden_stream, ctx=""):
        """Restart on a fresh node: empty fast tier, healed slow tier."""
        with tempfile.TemporaryDirectory() as d_fast:
            mgr = self._fused_mgr(NativeStorage(d_fast), slow_storage)
            consumed = []
            tr = make_trainer(mgr, consumed)
            start = tr.recovered_step or 0
            tr.run(N_STEPS - start)
            tr.wait_for_checkpoints()
            mgr.close()
            tr.close()
            assert float(np.asarray(tr.state["w"])) == golden_w, ctx
            assert consumed == golden_stream[start:], ctx
            return start

    def _count_slow_write_ops(self):
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            slow = FaultyStorage(NativeStorage(d2))
            mgr = self._fused_mgr(NativeStorage(d1), slow)
            tr = make_trainer(mgr, [])
            tr.run(N_STEPS)
            tr.wait_for_checkpoints()
            mgr.close()
            tr.close()
            return sum(1 for op, _, _ in slow.op_log
                       if op.startswith("write") or op == "append_file")

    @pytest.mark.parametrize("model", ["clean", "torn"])
    def test_kill_at_every_slow_write_op(self, model):
        golden_w, golden_stream = golden_run()
        n_ops = self._count_slow_write_ops()
        assert n_ops >= 8  # drain chunks + markers + GC marker rewrites
        for k in range(n_ops):
            with tempfile.TemporaryDirectory() as d1, \
                    tempfile.TemporaryDirectory() as d2:
                slow_inner = NativeStorage(d2)
                slow = FaultyStorage(slow_inner)
                if model == "clean":
                    slow.fail_after(k)
                else:
                    slow.torn_write(0.5, n_ops=k)
                mgr = self._fused_mgr(NativeStorage(d1), slow)
                tr = make_trainer(mgr, [])
                tr.run(N_STEPS)  # stages are fast-tier: the run completes
                with pytest.raises(FaultInjected):
                    tr.wait_for_checkpoints()  # the drain error surfaces
                try:
                    mgr.close()
                except FaultInjected:
                    pass  # later drains of the same sticky cascade
                tr.close()
                self._finish_fused(slow_inner, golden_w, golden_stream,
                                   ctx=f"model={model}, op {k}/{n_ops}")

    def test_reordered_fsync_crash_on_slow_tier(self):
        golden_w, golden_stream = golden_run()
        for j in (2, 4, N_STEPS - 1):
            for keep in ("last", "none"):
                with tempfile.TemporaryDirectory() as d1, \
                        tempfile.TemporaryDirectory() as d2:
                    slow_inner = NativeStorage(d2)
                    slow = FaultyStorage(slow_inner).reordered_fsync()
                    mgr = self._fused_mgr(NativeStorage(d1), slow)
                    tr = make_trainer(mgr, [])
                    tr.run(j)
                    tr.wait_for_checkpoints()
                    mgr.close()
                    tr.close()
                    slow.crash(keep=keep)  # power loss: volatile writes gone
                    slow.heal()
                    self._finish_fused(
                        slow_inner, golden_w, golden_stream,
                        ctx=f"crash(keep={keep}) after {j}")

    def test_hung_drain_absorbed_by_watchdog(self):
        """A wedged (not dead) slow tier mid-run: the watchdog re-queues
        the chunk and the run itself completes bit-identical — no restart
        needed at all."""
        golden_w, golden_stream = golden_run()
        for arm in ({"on": ".data-"}, {"n_ops": 2, "ops": ("write_range",)}):
            with tempfile.TemporaryDirectory() as d1, \
                    tempfile.TemporaryDirectory() as d2:
                slow = FaultyStorage(NativeStorage(d2))
                mgr = self._fused_mgr(NativeStorage(d1), slow,
                                      drain_stall_timeout=0.1,
                                      drain_streams=2, drain_chunk=64)
                slow.hang(**arm)  # one-shot wedge: the re-queue succeeds
                consumed = []
                tr = make_trainer(mgr, consumed)
                tr.run(N_STEPS)
                tr.wait_for_checkpoints()
                assert mgr.engine.drain_stalls >= 1, arm
                assert float(np.asarray(tr.state["w"])) == golden_w
                assert consumed == golden_stream
                slow.heal()  # un-park the leaked stream
                mgr.close()
                tr.close()
