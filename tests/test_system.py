"""End-to-end behaviour: the paper's full workload on CPU smoke scale.

Pipeline -> AlexNet training -> checkpointing through a burst buffer ->
restart — i.e. the complete mini-application of §III, miniaturized.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALEXNET_SMOKE as ACFG
from repro.core import (
    BurstBufferCheckpointer, Dataset, IOTracer, image_pipeline, make_storage,
)
from repro.core import records
from repro.core.microbench import run_microbench, thread_scaling_sweep
from repro.models import alexnet as A
from repro.train.trainer import Trainer


@pytest.fixture(scope="module")
def corpus():
    with tempfile.TemporaryDirectory() as d:
        st = make_storage("native", d)
        paths, labels = records.write_image_dataset(
            st, 48, mean_hw=(24, 24), n_classes=ACFG.n_classes, seed=3)
        yield st, paths, labels


class TestMicrobench:
    def test_reports_sane_bandwidth(self, corpus):
        st, paths, _ = corpus
        r = run_microbench(st, paths, threads=2, batch_size=8, out_hw=(16, 16))
        assert r.n_images == 48 and r.images_per_s > 0 and r.mb_per_s > 0

    def test_read_only_faster_than_preprocess(self, corpus):
        st, paths, _ = corpus
        rp = run_microbench(st, paths, threads=2, batch_size=8,
                            out_hw=(64, 64), preprocess=True)
        rr = run_microbench(st, paths, threads=2, batch_size=8,
                            preprocess=False)
        assert rr.images_per_s > rp.images_per_s  # paper Fig. 5 vs Fig. 4


class TestEndToEnd:
    def test_alexnet_train_with_pipeline_and_burst_buffer(self, corpus):
        st, paths, labels = corpus
        ds = image_pipeline(
            st, paths, labels, batch_size=8, num_parallel_calls=2,
            out_hw=(ACFG.in_hw, ACFG.in_hw), prefetch=1, repeat=True, seed=0)

        params = A.init_params(jax.random.PRNGKey(0), ACFG)
        state = {"params": params, "step": jnp.int32(0)}

        @jax.jit
        def train_step(state, batch):
            imgs, lbls = batch
            loss, g = jax.value_and_grad(
                lambda p: A.loss_fn(p, imgs, lbls, ACFG))(state["params"])
            new_p = jax.tree.map(lambda p, gg: p - 1e-3 * gg,
                                 state["params"], g)
            return {"params": new_p, "step": state["step"] + 1}, {"loss": loss}

        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            fast = make_storage("optane", d1, time_scale=0.02)
            slow = make_storage("hdd", d2, time_scale=0.02)
            bb = BurstBufferCheckpointer(fast, slow, "ckpt/alexnet")
            tr = Trainer(train_step, state, iter(ds), checkpointer=bb,
                         ckpt_every=3)
            hist = tr.run(6)
            bb.wait()
            assert len(hist) == 6
            assert all(np.isfinite(h["loss"]) for h in hist)
            # both checkpoints landed on the slow tier
            from repro.core.checkpoint import CheckpointSaver
            assert CheckpointSaver(slow, "ckpt/alexnet").all_steps() == [3, 6]
            bb.close()

            # restart picks up where we left off
            state2 = {"params": A.init_params(jax.random.PRNGKey(1), ACFG),
                      "step": jnp.int32(0)}
            bb2 = BurstBufferCheckpointer(fast, slow, "ckpt/alexnet")
            tr2 = Trainer(train_step, state2, iter(ds), checkpointer=bb2)
            assert tr2.step == 6
            bb2.close()
