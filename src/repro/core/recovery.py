"""Checkpoint retention + corruption-aware restore + train-state resume.

The paper's restart story (§III-C) is "restart quickly from a checkpoint";
PR 2/7 made the *save* path crash-consistent, this module makes recovery
actually work end-to-end:

* :class:`CheckpointManager` owns **retention** (keep-last-k plus
  keep-every-n milestones) on top of a :class:`~repro.core.checkpoint.
  CheckpointSaver`, with a GC whose invariant is *never delete the only
  valid restore target* and whose ordering is crash-safe: the marker is
  rewritten to the retained set **first**, files are deleted second — a
  crash in between leaves stray files (reclaimed by the next GC), never a
  marker pointing at deleted data.
* :func:`validate_step` / :func:`latest_valid_step` — structural
  validation (meta + index parse, every shard present and long enough for
  its tensor extents) that detects torn writes, rolled-back unsynced data
  and half-deleted steps *without* reading tensor bytes.  ``restore()``
  walks valid steps newest-first, past corrupt/torn/unsynced checkpoints —
  the marker-fallback generalization of the burst-buffer restore: step
  candidates come from the union of the marker and a directory listing, so
  a torn/missing marker alone never makes data unreachable.
* :meth:`CheckpointManager.resume` — TrainState-level restart: restores
  params into a skeleton **and** re-positions a
  :class:`~repro.core.dataset.ResumableIterator` from the pipeline state
  the trainer attached at save time (``extra_meta["pipeline"]``), so a
  resumed run neither skips nor replays samples.

The manager implements the checkpointer interface the
:class:`~repro.train.trainer.Trainer` expects (``save``/``latest_step``/
``restore_pytree``/``wait``/``close``/``blocked_s``), so it can drop in
wherever a :class:`~repro.core.burst_buffer.DirectCheckpointer` does —
optionally with a :class:`~repro.core.retry.RetryingStorage` wrap for
transient-fault absorption (``retry_policy=...``).
"""
from __future__ import annotations

import json
import re
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from .checkpoint import (CHECKPOINT_MARKER, CheckpointSaver, SaveResult,
                         unflatten_pytree, write_marker)
from .retry import RetryingStorage, RetryPolicy

#: Effectively-infinite retention for the inner saver: the manager owns GC.
_NO_SAVER_GC = 1 << 30


def _split_prefix(prefix: str) -> Tuple[str, str]:
    """``"ckpt/model"`` -> ``("ckpt", "model")``."""
    if "/" in prefix:
        d, name = prefix.rsplit("/", 1)
    else:
        d, name = ".", prefix
    return d, name


def list_steps(storage, prefix: str) -> List[int]:
    """Steps present on disk (by filename), sorted ascending.

    Deliberately *not* marker-based: after a torn marker write or a
    half-finished GC the marker under-reports what is restorable.
    """
    d, name = _split_prefix(prefix)
    pat = re.compile(re.escape(name) + r"-(\d+)\.(meta|index|data-\d+-of-\d+)$")
    steps: Set[int] = set()
    try:
        names = storage.listdir(d)
    except (FileNotFoundError, OSError):
        return []
    for n in names:
        m = pat.match(n)
        if m:
            steps.add(int(m.group(1)))
    return sorted(steps)


def marker_steps(storage, prefix: str) -> List[int]:
    """Steps the commit marker claims (``[]`` on a missing/corrupt marker)."""
    d, _ = _split_prefix(prefix)
    path = f"{d}/{CHECKPOINT_MARKER}"
    try:
        if not storage.exists(path):
            return []
        marker = json.loads(storage.read_file(path))
        steps = {int(s) for s in marker.get("all_steps", [])}
        if "latest" in marker and marker["latest"] is not None:
            steps.add(int(marker["latest"]))
        return sorted(steps)
    except (OSError, ValueError, KeyError, TypeError):
        return []


def validate_step(storage, prefix: str, step: int) -> bool:
    """Structural validity: can ``restore(step)`` possibly succeed?

    Checks the meta and index parse as JSON, and that every data shard
    exists with at least the bytes its tensor extents require — which
    catches torn shard writes (truncated content), unsynced writes rolled
    back by a crash (missing/short files), and half-deleted steps, without
    reading any tensor data.
    """
    base = f"{prefix}-{step}"
    try:
        meta = json.loads(storage.read_file(f"{base}.meta"))
        if int(meta["step"]) != step:
            return False
        index = json.loads(storage.read_file(f"{base}.index"))
        n_shards = int(index["n_shards"])
        need = [0] * n_shards
        for e in index["tensors"].values():
            s = int(e["shard"])
            need[s] = max(need[s], int(e["offset"]) + int(e["length"]))
        for s in range(n_shards):
            p = f"{base}.data-{s:05d}-of-{n_shards:05d}"
            if storage.size(p) < need[s]:
                return False
        return True
    except (OSError, ValueError, KeyError, TypeError):
        return False


def valid_steps(storage, prefix: str) -> List[int]:
    """All structurally-valid steps, sorted ascending.  Candidates are the
    union of the directory listing and the marker (marker-fallback: either
    source alone may be damaged)."""
    cands = set(list_steps(storage, prefix)) | set(marker_steps(storage, prefix))
    return [s for s in sorted(cands) if validate_step(storage, prefix, s)]


def latest_valid_step(storage, prefix: str) -> Optional[int]:
    vs = valid_steps(storage, prefix)
    return vs[-1] if vs else None


@dataclass
class ResumeResult:
    """What :meth:`CheckpointManager.resume` recovered.

    ``step is None`` means no restorable checkpoint existed — ``state`` is
    the untouched skeleton and training starts fresh.
    """

    step: Optional[int]
    state: Any
    meta: Dict[str, Any] = field(default_factory=dict)
    pipeline: Optional[Dict[str, Any]] = None
    restore_s: float = 0.0

    @property
    def fresh(self) -> bool:
        return self.step is None


class CheckpointManager:
    """Retention + corruption-aware restore over a sharded saver.

    ``keep_last`` newest steps are retained; ``keep_every`` additionally
    pins every n-th step as a permanent milestone (TF's
    ``keep_checkpoint_every_n_hours``, in steps).  The latest *valid* step
    is always retained regardless of either rule.  ``retry_policy`` wraps
    the storage in :class:`~repro.core.retry.RetryingStorage` so transient
    device faults are absorbed below the checkpoint protocol.
    """

    def __init__(
        self,
        storage,
        prefix: str = "ckpt/model",
        *,
        keep_last: int = 5,
        keep_every: Optional[int] = None,
        retry_policy: Optional[RetryPolicy] = None,
        n_shards: int = 1,
        sync: bool = True,
        quantize: Optional[str] = None,
        io_threads: Optional[int] = None,
    ):
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        if keep_every is not None and keep_every < 1:
            raise ValueError(f"keep_every must be >= 1, got {keep_every}")
        if retry_policy is not None:
            storage = RetryingStorage(storage, retry_policy)
        self.storage = storage
        self.prefix = prefix
        self.keep_last = keep_last
        self.keep_every = keep_every
        # the inner saver never GCs (keep=inf): deletion policy lives here,
        # where "valid" is a first-class concept
        self.saver = CheckpointSaver(
            storage, prefix, keep=_NO_SAVER_GC, n_shards=n_shards, sync=sync,
            quantize=quantize, io_threads=io_threads,
        )
        self._dir, _ = _split_prefix(prefix)
        self.blocked_s: List[float] = []
        self.gc_deleted: List[int] = []  # every step GC ever removed

    # -- save + retention ------------------------------------------------------
    def save(self, step: int, tree: Any,
             extra_meta: Optional[dict] = None) -> SaveResult:
        r = self.saver.save(step, tree, extra_meta)
        self.blocked_s.append(r.seconds)
        self.gc()
        return r

    def retained_steps(self) -> List[int]:
        """The set the current policy would keep, given what's on disk."""
        steps = list_steps(self.storage, self.prefix)
        if not steps:
            return []
        retained: Set[int] = set(steps[-self.keep_last:])
        if self.keep_every:
            retained |= {s for s in steps if s % self.keep_every == 0}
        lv = latest_valid_step(self.storage, self.prefix)
        if lv is not None:
            retained.add(lv)
        return sorted(retained)

    def gc(self) -> List[int]:
        """Apply retention; return the steps deleted.

        Ordering is crash-safe: the marker is rewritten to the retained set
        *before* any file is deleted, so a crash mid-GC strands extra files
        (reclaimed by the next GC) but never publishes a marker whose steps
        are gone.  The latest valid step is always in the retained set —
        GC can never delete the only restore target.
        """
        steps = list_steps(self.storage, self.prefix)
        if not steps:
            return []
        retained = set(self.retained_steps())
        doomed = [s for s in steps if s not in retained]
        lv = latest_valid_step(self.storage, self.prefix)
        latest = lv if lv is not None else max(retained)
        marker = json.dumps(
            dict(latest=latest, all_steps=sorted(retained))).encode()
        write_marker(self.storage, self.saver._marker_path(), marker,
                     sync=self.saver.sync)
        for s in doomed:
            self.saver._delete_step(s)
        self.gc_deleted.extend(doomed)
        return doomed

    # -- introspection ---------------------------------------------------------
    def all_steps(self) -> List[int]:
        return list_steps(self.storage, self.prefix)

    def valid_steps(self) -> List[int]:
        return valid_steps(self.storage, self.prefix)

    def latest_valid(self) -> Optional[int]:
        return latest_valid_step(self.storage, self.prefix)

    def latest_step(self) -> Optional[int]:
        """Newest *restorable* step (the Trainer's resume entry point) —
        deliberately stricter than the marker's ``latest``."""
        return self.latest_valid()

    # -- restore ---------------------------------------------------------------
    def restore(self, step: Optional[int] = None
                ) -> Tuple[Dict[str, Any], dict, int]:
        """Restore ``step`` (or the newest restorable step), walking back
        past corrupt/torn/unsynced checkpoints.  Returns
        ``(flat, meta, step_restored)``.
        """
        if step is not None:
            flat, meta = self.saver.restore(step)
            return flat, meta, step
        for s in reversed(self.valid_steps()):
            try:
                flat, meta = self.saver.restore(s)
                return flat, meta, s
            except (OSError, ValueError, KeyError):
                continue  # damage validate_step can't see (e.g. bad JSON field)
        raise FileNotFoundError(
            f"no restorable checkpoint under {self.prefix}")

    def restore_pytree(self, skeleton: Any, step: Optional[int] = None) -> Any:
        import jax

        flat, _meta, _s = self.restore(step)
        treedef = jax.tree_util.tree_structure(skeleton)
        return unflatten_pytree(flat, treedef)

    def resume(self, skeleton: Any, *, data_iter: Any = None,
               step: Optional[int] = None) -> ResumeResult:
        """TrainState-level restart: params + input-pipeline position.

        Restores the newest restorable checkpoint into ``skeleton``'s
        structure; if the checkpoint carries pipeline state (the trainer
        attaches ``extra_meta={"pipeline": it.state()}`` at save time) and
        ``data_iter`` supports ``restore_state``, the iterator is
        re-positioned so the resumed run neither skips nor replays samples.
        With no checkpoint at all, returns a fresh :class:`ResumeResult`
        (``step=None``, skeleton untouched).
        """
        import jax

        t0 = time.monotonic()
        try:
            flat, meta, s = self.restore(step)
        except FileNotFoundError:
            if step is not None:
                raise
            return ResumeResult(step=None, state=skeleton)
        treedef = jax.tree_util.tree_structure(skeleton)
        state = unflatten_pytree(flat, treedef)
        pipeline = (meta.get("extra") or {}).get("pipeline")
        if data_iter is not None and pipeline is not None \
                and hasattr(data_iter, "restore_state"):
            data_iter.restore_state(pipeline)
        return ResumeResult(step=s, state=state, meta=meta,
                            pipeline=pipeline,
                            restore_s=time.monotonic() - t0)

    # -- checkpointer-interface parity ----------------------------------------
    def wait(self) -> None:
        return

    def close(self) -> None:
        return
