"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; TPU is the
lowering target).  On a real TPU deployment pass ``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import preprocess as _pre
from . import quantize as _q

BLOCK = _q.BLOCK


# -- quantize ----------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x: jax.Array, *, interpret: bool = True):
    """Any-shape tensor -> (q (n,BLOCK) int8, scales (n,1) f32, meta).

    meta = (shape, pad) needed by :func:`dequantize`."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, BLOCK)
    q, s = _q.quantize_blocks(blocks, interpret=interpret)
    return q, s


def dequantize(q: jax.Array, s: jax.Array, shape, dtype=jnp.float32,
               *, interpret: bool = True) -> jax.Array:
    flat = _q.dequantize_blocks(q, s, interpret=interpret).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


# -- preprocess -----------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("interpret",))
def normalize_images_nhwc(x: jax.Array, mean: jax.Array, std: jax.Array,
                          *, interpret: bool = True) -> jax.Array:
    """x: (B, H, W, C) uint8 -> normalized (B, H, W, C) f32 (fused kernel)."""
    B, H, W, C = x.shape
    xc = jnp.transpose(x, (0, 3, 1, 2)).reshape(B, C, H * W)
    out = _pre.normalize_images(xc, mean, std, interpret=interpret)
    return jnp.transpose(out.reshape(B, C, H, W), (0, 2, 3, 1))


@functools.partial(jax.jit, static_argnames=("out_h", "out_w", "interpret"))
def resize_convert_nhwc(x: jax.Array, out_h: int, out_w: int,
                        *, interpret: bool = True) -> jax.Array:
    """x: (B, H, W, C) u8/u16/f32 -> (B, out_h, out_w, C) f32 in [0,1]
    (fused matmul-bilinear resize + dtype-convert kernel)."""
    return _pre.resize_convert_images(x, out_h, out_w, interpret=interpret)


# -- flash attention ---------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array,
                         *, causal: bool = True, bq: int = _fa.DEFAULT_BQ,
                         bk: int = _fa.DEFAULT_BK, interpret: bool = True
                         ) -> jax.Array:
    """q: (B, Sq, H, hd), k/v: (B, Skv, Hkv, hd) GQA -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    # broadcast KV heads for GQA, flatten (B, H)
    kb = jnp.repeat(k, group, axis=2)
    vb = jnp.repeat(v, group, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = kb.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    vf = vb.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    o = _fa.flash_attention(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                            interpret=interpret)
    return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
