"""The paper's AlexNet mini-application (§III-B), end to end.

    PYTHONPATH=src python examples/alexnet_miniapp.py [--tier hdd|ssd|optane]

Generates a Caltech-101-like corpus on a simulated tier, trains AlexNet with
the full input pipeline, and prints per-step data-wait vs compute (the
paper's prefetch-overlap observable) plus a dstat-style I/O trace.

``--trace OUT.json`` adds per-op span collection (Chrome trace + Darshan
report); ``--metrics OUT.jsonl`` adds live telemetry (sampled gauge/counter
time series, Prometheus snapshot, per-step stall detection).  The two
compose: with both, the trace report embeds the metrics timeline.

``--ckpt DIR`` turns on fault-tolerant checkpointing: a
:class:`~repro.core.recovery.CheckpointManager` saves params *and* the
input-pipeline position into DIR every ``--ckpt-every`` steps.  Kill the
run, rerun with ``--resume``, and it restores the newest **valid**
checkpoint (walking back past torn/corrupt ones) and repositions the
iterator so no sample is skipped or replayed — the corpus is seeded, so a
rerun regenerates identical data::

    PYTHONPATH=src python examples/alexnet_miniapp.py \\
        --ckpt /tmp/alexckpt --steps 8
    PYTHONPATH=src python examples/alexnet_miniapp.py \\
        --ckpt /tmp/alexckpt --resume --steps 8

``--ckpt-engine direct|async|bb|asyncbb`` picks the checkpoint engine the
manager drives (the fused lifecycle: async engines overlap the save with
training; bb/asyncbb stage through a fast buffer under DIR first).
``--preempt-at N`` demos graceful preemption: at step N the trainer stops,
promotes the final save within ``--preempt-deadline`` seconds, and prints
the preemption report; rerun with ``--resume`` to restart exactly there::

    PYTHONPATH=src python examples/alexnet_miniapp.py \\
        --ckpt /tmp/alexckpt --ckpt-engine asyncbb --ckpt-every 2 \\
        --steps 8 --preempt-at 5
    PYTHONPATH=src python examples/alexnet_miniapp.py \\
        --ckpt /tmp/alexckpt --ckpt-engine asyncbb --resume --steps 8
"""
import argparse, os, sys, tempfile
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import metrics, trace
from repro.configs import ALEXNET_SMOKE as CFG
from repro.core import CheckpointManager, IOTracer, ResumableIterator, \
    image_pipeline, make_storage, sharded_image_pipeline
from repro.core import records
from repro.models import alexnet as A
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", default="ssd")
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--prefetch", type=int, default=1)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--sharded", action="store_true",
                    help="stream the corpus from multi-record shards via "
                         "the interleaved read engine instead of "
                         "one-file-per-image")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="collect per-op spans and write a Chrome trace "
                         "(open in Perfetto); also prints the per-stage "
                         "Darshan-style report")
    ap.add_argument("--metrics", metavar="OUT.jsonl", default=None,
                    help="enable live telemetry: sample the metrics "
                         "registry (prefetch occupancy, storage latency "
                         "sketches, per-step heartbeat) into a JSONL time "
                         "series and print the final Prometheus-text "
                         "snapshot; composes with --trace")
    ap.add_argument("--ckpt", metavar="DIR", default=None,
                    help="checkpoint params + pipeline position into DIR "
                         "via CheckpointManager (keep-last retention, "
                         "corruption-aware restore)")
    ap.add_argument("--ckpt-every", type=int, default=5,
                    help="save every N steps (with --ckpt; default 5)")
    ap.add_argument("--ckpt-engine", default="direct",
                    choices=("direct", "async", "bb", "asyncbb"),
                    help="checkpoint engine the manager drives (with "
                         "--ckpt; bb/asyncbb stage through a fast buffer "
                         "under DIR)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest valid checkpoint from --ckpt "
                         "and continue — params and input position")
    ap.add_argument("--preempt-at", type=int, default=None, metavar="STEP",
                    help="demo graceful preemption: stop at STEP, promote "
                         "the final save within the deadline, print the "
                         "preemption report (requires --ckpt)")
    ap.add_argument("--preempt-deadline", type=float, default=5.0,
                    help="graceful-shutdown budget in seconds (with "
                         "--preempt-at; default 5)")
    args = ap.parse_args()
    if args.resume and not args.ckpt:
        ap.error("--resume requires --ckpt DIR")
    if args.preempt_at is not None and not args.ckpt:
        ap.error("--preempt-at requires --ckpt DIR")

    tracer = IOTracer(0.25)
    st = make_storage(args.tier, tempfile.mkdtemp(), tracer, time_scale=0.2)
    if args.sharded:
        shard_paths, shard_labels = records.write_sharded_image_dataset(
            st, 128, 16, mean_hw=(64, 64), n_classes=CFG.n_classes)
    else:
        paths, labels = records.write_image_dataset(
            st, 128, mean_hw=(64, 64), n_classes=CFG.n_classes)
    tracer.reset()

    def build_pipeline(seed=0, repeat=True):
        if args.sharded:
            return sharded_image_pipeline(st, shard_paths, shard_labels,
                                          batch_size=16,
                                          cycle_length=args.threads,
                                          num_parallel_calls=args.threads,
                                          prefetch=args.prefetch,
                                          out_hw=(CFG.in_hw, CFG.in_hw),
                                          seed=seed, repeat=repeat)
        return image_pipeline(st, paths, labels, batch_size=16,
                              num_parallel_calls=args.threads,
                              prefetch=args.prefetch,
                              out_hw=(CFG.in_hw, CFG.in_hw),
                              seed=seed, repeat=repeat)

    ckpt_mgr = None
    if args.ckpt:
        # resumable position needs finite epochs: one Dataset per epoch,
        # shuffled by a per-epoch seed the factory can replay on restore
        ds = ResumableIterator(lambda ep: build_pipeline(seed=ep,
                                                         repeat=False))
        # bb/asyncbb stage through a fast buffer inside the checkpoint dir
        # (persists across restarts: a staged-not-drained step is still
        # restorable after a preemption)
        fast = (make_storage("native", os.path.join(args.ckpt, "fastbuf"))
                if args.ckpt_engine in ("bb", "asyncbb") else None)
        ckpt_mgr = CheckpointManager(make_storage("native", args.ckpt),
                                     "ckpt/alexnet", keep_last=3,
                                     engine=args.ckpt_engine,
                                     fast_storage=fast)
    else:
        ds = build_pipeline(repeat=True)

    params = A.init_params(jax.random.PRNGKey(0), CFG)
    state = {"params": params, "step": jnp.int32(0)}

    @jax.jit
    def train_step(state, batch):
        imgs, lbls = batch
        loss, g = jax.value_and_grad(
            lambda p: A.loss_fn(p, imgs, lbls, CFG))(state["params"])
        new_p = jax.tree.map(lambda p, gg: p - 1e-4 * gg, state["params"], g)
        return {"params": new_p, "step": state["step"] + 1}, {"loss": loss}

    collector = trace.start() if args.trace else None
    sampler = None
    stall = None
    if args.metrics:
        metrics.start()
        sampler = metrics.Sampler(interval_s=0.1, jsonl_path=args.metrics)
        sampler.start()
        stall = metrics.StallDetector(min_samples=4)
    tr = Trainer(train_step, state, iter(ds), stall_detector=stall,
                 checkpointer=ckpt_mgr, ckpt_every=args.ckpt_every,
                 resume=args.resume,
                 preempt_deadline_s=args.preempt_deadline)
    if args.preempt_at is not None:
        def _maybe_preempt(step, _m, _tr=tr, _at=args.preempt_at):
            if step >= _at:
                _tr.preempt()
        tr.on_step = _maybe_preempt
    if args.resume:
        if tr.recovered_step is not None:
            pos = ds.state()
            print(f"resumed from step {tr.recovered_step} "
                  f"(latest valid checkpoint in {args.ckpt}) — input "
                  f"pipeline at epoch {pos['epoch']}, "
                  f"batch offset {pos['offset']}")
        else:
            print(f"--resume: no valid checkpoint under {args.ckpt}; "
                  f"starting fresh")
    tr.run(args.steps)
    if ckpt_mgr is not None:
        tr.wait_for_checkpoints()  # drain async saves before reporting
        ckpt_mgr.close()
    tr.close()  # repeat() pipeline: stop the prefetch producer promptly
    rep = tr.report()
    if rep["preemption"] is not None:
        p = rep["preemption"]
        print(f"preempted: committed step {p['committed_step']} in "
              f"{p['preempt_s']:.3f}s (deadline {p['deadline_s']}s, "
              f"met={p['deadline_met']}, abandoned={p['abandoned_steps']}) "
              f"— rerun with --resume to restart there")
    print(f"tier={args.tier} threads={args.threads} prefetch={args.prefetch}"
          f" sharded={args.sharded}")
    print(f"  data-wait fraction: {rep['data_wait_frac']:.1%} "
          f"(prefetch hides I/O when ~0)")
    print(f"  losses: {[round(h['loss'], 3) for h in tr.history]}")
    print("dstat-style read trace (MB/s):")
    print(tracer.to_csv())
    metric_points = None
    if sampler is not None:
        sampler.stop()
        metric_points = sampler.points()
        print(f"\nmetrics time series written to {args.metrics} "
              f"({len(metric_points)} samples)")
        print(metrics.to_prometheus_text(metrics.get_registry()))
        if stall is not None and stall.events:
            print(f"stalls detected: {stall.summary()}")
        metrics.stop()
    if collector is not None:
        trace.stop()
        trace.dump_chrome_trace(collector, args.trace,
                                process_name="alexnet-miniapp")
        print(f"\nChrome trace written to {args.trace}")
        print(trace.to_markdown(collector.spans(),
                                title="Per-stage I/O report",
                                counters=collector.counters(),
                                metrics_series=metric_points))


if __name__ == "__main__":
    main()
