"""Record container + image codec: unit + property tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import records


class TestRecordContainer:
    def test_roundtrip_single(self):
        payload = b"hello world" * 100
        blob = records.encode_record(payload)
        assert records.decode_single_record(blob) == payload

    def test_roundtrip_multi(self):
        payloads = [b"a" * i for i in range(0, 50, 7)]
        blob = b"".join(records.encode_record(p) for p in payloads)
        assert list(records.decode_records(blob)) == payloads

    def test_corrupt_payload_raises(self):
        blob = bytearray(records.encode_record(b"x" * 100))
        blob[20] ^= 0xFF  # flip a payload byte
        with pytest.raises(records.RecordError):
            list(records.decode_records(bytes(blob)))

    def test_truncated_raises(self):
        blob = records.encode_record(b"x" * 100)
        with pytest.raises(records.RecordError):
            list(records.decode_records(blob[:-3]))

    @given(st.lists(st.binary(min_size=0, max_size=500), max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_property_roundtrip(self, payloads):
        blob = b"".join(records.encode_record(p) for p in payloads)
        assert list(records.decode_records(blob)) == payloads


class TestImageCodec:
    @given(
        h=st.integers(1, 40), w=st.integers(1, 40), c=st.sampled_from([1, 3, 4])
    )
    @settings(max_examples=30, deadline=None)
    def test_property_image_roundtrip(self, h, w, c):
        rng = np.random.default_rng(0)
        img = rng.integers(0, 256, (h, w, c), dtype=np.uint8)
        out = records.decode_image(records.encode_image(img))
        np.testing.assert_array_equal(out, img)

    def test_bad_magic_raises(self):
        img = np.zeros((4, 4, 3), np.uint8)
        payload = bytearray(records.encode_image(img))
        payload[0] = ord(b"X")
        with pytest.raises(records.RecordError):
            records.decode_image(bytes(payload))

    def test_resize_identity(self):
        img = np.random.default_rng(0).random((16, 16, 3)).astype(np.float32)
        np.testing.assert_array_equal(records.resize_image(img, 16, 16), img)

    def test_resize_bilinear_constant(self):
        img = np.full((10, 12, 3), 7.0, np.float32)
        out = records.resize_image(img, 5, 20)
        assert out.shape == (5, 20, 3)
        np.testing.assert_allclose(out, 7.0, rtol=1e-6)

    def test_preprocess_dtype_and_range(self):
        img = np.random.default_rng(0).integers(0, 256, (30, 20, 3), dtype=np.uint8)
        out = records.preprocess_image(
            records.encode_image(img), 24, 24)
        assert out.dtype == np.float32 and out.shape == (24, 24, 3)
        assert out.min() >= 0.0 and out.max() <= 1.0


class TestZeroCopyDecode:
    def _blob(self, payloads):
        return b"".join(records.encode_record(p) for p in payloads)

    def test_record_views_bytes_identical_to_copy_path(self):
        payloads = [bytes([i]) * (10 + i * 7) for i in range(6)]
        blob = self._blob(payloads)
        views = list(records.iter_record_views(blob))
        assert all(isinstance(v, memoryview) for v in views)
        assert [bytes(v) for v in views] == list(records.decode_records(blob))
        assert [bytes(v) for v in views] == payloads

    def test_views_alias_blob_memory(self):
        blob = self._blob([b"x" * 64])
        (view,) = records.iter_record_views(blob)
        assert view.obj is blob  # a slice of the original buffer, not a copy

    def test_zero_copy_image_bytes_identical(self):
        rng = np.random.default_rng(0)
        img = rng.integers(0, 256, (23, 31, 3), dtype=np.uint8)
        blob = records.encode_record(records.encode_image(img))
        payload = records.decode_single_record(blob, copy=False)
        view_arr = records.decode_image(payload, copy=False)
        copy_arr = records.decode_image(records.decode_single_record(blob))
        assert not view_arr.flags.owndata  # shares payload memory
        assert not view_arr.flags.writeable
        np.testing.assert_array_equal(view_arr, copy_arr)
        assert view_arr.tobytes() == copy_arr.tobytes()

    def test_zero_copy_corruption_still_detected(self):
        blob = bytearray(self._blob([b"y" * 100]))
        blob[30] ^= 0xFF
        with pytest.raises(records.RecordError):
            list(records.iter_record_views(bytes(blob)))


class TestVectorizedResize:
    @pytest.mark.parametrize("in_hw,out_hw", [
        ((33, 47), (16, 24)), ((10, 10), (30, 20)), ((8, 9), (8, 9)),
        ((64, 48), (7, 5)),
    ])
    @pytest.mark.parametrize("dtype", [np.float32, np.uint8])
    def test_bit_identical_to_reference(self, in_hw, out_hw, dtype):
        rng = np.random.default_rng(1)
        if dtype == np.uint8:
            img = rng.integers(0, 256, (*in_hw, 3), dtype=np.uint8)
        else:
            img = rng.random((*in_hw, 3)).astype(np.float32)
        got = records.resize_image(img, *out_hw)
        ref = records.resize_image_reference(img, *out_hw)
        np.testing.assert_array_equal(got, ref)  # bit-identical, not allclose

    def test_out_buffer_receives_result(self):
        rng = np.random.default_rng(2)
        img = rng.random((20, 30, 3)).astype(np.float32)
        out = np.full((12, 14, 3), np.nan, np.float32)
        res = records.resize_image(img, 12, 14, out=out)
        assert res is out
        np.testing.assert_array_equal(
            out, records.resize_image_reference(img, 12, 14))

    def test_batch_matches_per_image(self):
        rng = np.random.default_rng(3)
        imgs = rng.integers(0, 256, (5, 17, 13, 3), dtype=np.uint8)
        batched = records.resize_batch(imgs, 9, 11)
        for i in range(5):
            np.testing.assert_array_equal(
                batched[i], records.resize_image(imgs[i], 9, 11))

    def test_lut_cached_across_calls(self):
        records.bilinear_lut.cache_clear()
        rng = np.random.default_rng(4)
        for _ in range(3):
            records.resize_image(rng.random((15, 15, 1)).astype(np.float32),
                                 6, 6)
        info = records.bilinear_lut.cache_info()
        assert info.misses == 1 and info.hits == 2

    def test_fused_preprocess_into_parity(self):
        rng = np.random.default_rng(5)
        for dtype, hi in ((np.uint8, 256), (np.uint16, 65536)):
            img = rng.integers(0, hi, (26, 19, 3)).astype(dtype)
            payload = records.encode_image(img)
            out = np.empty((12, 10, 3), np.float32)
            records.preprocess_image_into(payload, out)
            legacy = records.preprocess_image(payload, 12, 10)
            np.testing.assert_allclose(out, legacy, atol=1e-6)

    def test_fused_preprocess_same_size_shortcut(self):
        rng = np.random.default_rng(6)
        img = rng.integers(0, 256, (8, 8, 3), dtype=np.uint8)
        out = np.empty((8, 8, 3), np.float32)
        records.preprocess_image_into(records.encode_image(img), out)
        np.testing.assert_allclose(
            out, records.preprocess_image(records.encode_image(img), 8, 8),
            atol=1e-7)


class TestShardedWriter:
    def test_sharded_writer_roundtrip(self, tmp_storage):
        paths, labels = records.write_sharded_image_dataset(
            tmp_storage, 10, 4, mean_hw=(12, 12), n_classes=5, seed=0)
        assert len(paths) == 3  # 4 + 4 + 2
        assert [len(l) for l in labels] == [4, 4, 2]
        views = list(records.iter_record_views(tmp_storage.read_file(paths[0])))
        assert len(views) == 4
        img = records.decode_image(views[0], copy=False)
        assert img.ndim == 3 and img.dtype == np.uint8

    def test_uniform_corpus_has_fixed_hw(self, tmp_storage):
        paths, _ = records.write_sharded_image_dataset(
            tmp_storage, 6, 3, mean_hw=(16, 20), hw_jitter=0.0, seed=0)
        for p in paths:
            for v in records.iter_record_views(tmp_storage.read_file(p)):
                assert records.decode_image(v, copy=False).shape == (16, 20, 3)


class TestWriters:
    def test_image_dataset_writer(self, tmp_storage):
        paths, labels = records.write_image_dataset(
            tmp_storage, 10, mean_hw=(16, 16), n_classes=5)
        assert len(paths) == len(labels) == 10
        img = records.preprocess_image(
            records.decode_single_record(tmp_storage.read_file(paths[0])), 8, 8)
        assert img.shape == (8, 8, 3)
        assert all(0 <= l < 5 for l in labels)

    def test_token_dataset_writer(self, tmp_storage):
        paths = records.write_token_dataset(tmp_storage, 3, 4, 32, 1000)
        shard = records.decode_token_shard(tmp_storage.read_file(paths[0]), 32)
        assert shard.shape == (4, 32)
        assert shard.min() >= 0 and shard.max() < 1000
