"""§Roofline: render the per-cell table from the dry-run JSON artifact."""
from __future__ import annotations

import json
import os

from .common import emit


def run(path: str = "reports/dryrun.json") -> None:
    if not os.path.exists(path):
        print(f"roofline_table,skipped,no {path} (run repro.launch.dryrun first)")
        return
    cells = json.load(open(path))
    rows = []
    for r in sorted(cells, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r.get("status") != "ok":
            rows.append(f"{r['arch']},{r['shape']},{r['mesh']},ERROR")
            continue
        mem = r.get("memory_per_device") or {}
        peak = (mem.get("argument", 0) + mem.get("temp", 0)) / 2**30
        rows.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"t_compute_ms={r['t_compute']*1e3:.2f},"
            f"t_memory_ms={r['t_memory']*1e3:.2f},"
            f"t_collective_ms={r['t_collective']*1e3:.2f},"
            f"bottleneck={r['bottleneck']},"
            f"mfu_bound={r['mfu']:.3f},"
            f"useful_flops_ratio={r['useful_flops_ratio']:.2f},"
            f"peak_gib={peak:.1f}"
        )
    n_ok = sum(1 for r in cells if r.get("status") == "ok")
    emit("roofline_table", rows, f"{n_ok}/{len(cells)} cells compiled")


if __name__ == "__main__":
    run()
