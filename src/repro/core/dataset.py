"""A tf.data-like input pipeline (paper §II-A / Fig. 2), in pure Python.

The pipeline is a chain of lazily-evaluated nodes::

    Dataset.from_tensor_slices(paths)
        .shuffle(buffer_size, seed)
        .map(read_and_decode, num_parallel_calls=8)   # thread-pool I/O
        .ignore_errors()
        .batch(64)
        .prefetch(1)                                   # background thread

Semantics follow the paper's description of the TF Dataset API:

* ``map(num_parallel_calls=k)`` keeps ``k`` elements in flight on a thread
  pool.  ``deterministic=True`` (default) yields results in input order —
  like TF — by maintaining a window of futures; ``False`` yields in
  completion order (lower latency jitter, used for straggler mitigation).
* ``shuffle`` is TF's streaming buffer shuffle: fill a ``buffer_size``
  reservoir, emit a uniformly random element, refill.
* ``batch`` stacks ``n`` consecutive elements (pytree-aware).
* ``prefetch`` inserts the background-thread prefetcher (see prefetcher.py).
* ``cache`` memoizes the upstream stream in host memory after epoch 1
  (paper §IV-B: "after the first epoch all samples ... cached in memory").
* ``ignore_errors`` drops elements whose map fn raised (tf.contrib.data.
  ignore_errors), so corrupt records don't kill a large run.
"""
from __future__ import annotations

import random
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Iterable, Iterator, List, Optional, Sequence

import numpy as np

from .. import trace
from .prefetcher import PrefetchIterator


class _ErrorMarker:
    """Carries an element-level failure downstream (TF semantics: the error
    surfaces at the iterator unless ``ignore_errors()`` drops it)."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


def _raising(it: Iterator) -> Iterator:
    for item in it:
        if isinstance(item, _ErrorMarker):
            raise item.exc
        yield item


class Dataset:
    """Lazily-evaluated pipeline node; iterate to pull elements through."""

    def __init__(self, gen_fn: Callable[[], Iterator]):
        self._gen_fn = gen_fn

    # -- sources ---------------------------------------------------------------
    @staticmethod
    def from_tensor_slices(items: Sequence) -> "Dataset":
        items = list(items)
        return Dataset(lambda: iter(items))

    @staticmethod
    def list_files(storage, dirpath: str = ".", suffix: str = ".rrf") -> "Dataset":
        names = [n for n in storage.listdir(dirpath) if n.endswith(suffix)]
        if dirpath not in (".", ""):
            names = [f"{dirpath}/{n}" for n in names]
        return Dataset.from_tensor_slices(names)

    @staticmethod
    def range(n: int) -> "Dataset":
        return Dataset(lambda: iter(range(n)))

    # -- transformations -------------------------------------------------------
    def shuffle(self, buffer_size: int, seed: Optional[int] = None) -> "Dataset":
        upstream = self._gen_fn

        def gen():
            rng = random.Random(seed)
            buf: List[Any] = []
            for item in upstream():
                buf.append(item)
                if len(buf) >= buffer_size:
                    idx = rng.randrange(len(buf))
                    buf[idx], buf[-1] = buf[-1], buf[idx]
                    yield buf.pop()
            while buf:
                idx = rng.randrange(len(buf))
                buf[idx], buf[-1] = buf[-1], buf[idx]
                yield buf.pop()

        return Dataset(gen)

    def map(
        self,
        fn: Callable[[Any], Any],
        num_parallel_calls: int = 1,
        deterministic: bool = True,
    ) -> "Dataset":
        upstream = self._gen_fn
        fn_label = getattr(fn, "__name__", "map_fn")

        def safe_fn(item):
            # one decode-stage span per element; nested storage_read spans
            # (from fn's read_file call) attribute the I/O share of this time
            with trace.span(trace.STAGE_DECODE, fn_label):
                try:
                    return fn(item)
                except Exception as e:  # surfaced at the iterator (TF semantics)
                    return _ErrorMarker(e)

        if num_parallel_calls <= 1:
            def gen_serial():
                for item in upstream():
                    yield safe_fn(item)
            return Dataset(gen_serial)

        def gen_parallel():
            with ThreadPoolExecutor(max_workers=num_parallel_calls) as pool:
                src = upstream()
                window: List = []
                # prime the window
                for item in src:
                    window.append(pool.submit(safe_fn, item))
                    if len(window) >= num_parallel_calls:
                        break
                for item in src:
                    if deterministic:
                        fut = window.pop(0)
                    else:
                        # completion order: find first done, else oldest
                        done_i = next(
                            (i for i, f in enumerate(window) if f.done()), 0
                        )
                        fut = window.pop(done_i)
                    window.append(pool.submit(safe_fn, item))
                    yield fut.result()
                while window:
                    if deterministic:
                        fut = window.pop(0)
                    else:
                        done_i = next(
                            (i for i, f in enumerate(window) if f.done()), 0
                        )
                        fut = window.pop(done_i)
                    yield fut.result()

        return Dataset(gen_parallel)

    def ignore_errors(self) -> "Dataset":
        upstream = self._gen_fn

        def gen():
            for item in upstream():
                if isinstance(item, _ErrorMarker):
                    continue
                yield item

        return Dataset(gen)

    def batch(self, batch_size: int, drop_remainder: bool = True) -> "Dataset":
        upstream = self._gen_fn

        def _stack(elems: List[Any]):
            first = elems[0]
            if isinstance(first, tuple):
                return tuple(
                    _stack([e[i] for e in elems]) for i in range(len(first))
                )
            if isinstance(first, dict):
                return {k: _stack([e[k] for e in elems]) for k in first}
            return np.stack([np.asarray(e) for e in elems])

        def gen():
            buf: List[Any] = []
            for item in _raising(upstream()):
                buf.append(item)
                if len(buf) == batch_size:
                    yield _stack(buf)
                    buf = []
            if buf and not drop_remainder:
                yield _stack(buf)

        return Dataset(gen)

    def repeat(self, count: Optional[int] = None) -> "Dataset":
        upstream = self._gen_fn

        def gen():
            i = 0
            while count is None or i < count:
                yield from upstream()
                i += 1

        return Dataset(gen)

    def take(self, n: int) -> "Dataset":
        upstream = self._gen_fn

        def gen():
            it = upstream()
            for _ in range(n):
                try:
                    yield next(it)
                except StopIteration:
                    return

        return Dataset(gen)

    def cache(self) -> "Dataset":
        upstream = self._gen_fn
        memo: dict = {"items": None, "lock": threading.Lock()}

        def gen():
            with memo["lock"]:
                cached = memo["items"]
            if cached is not None:
                yield from cached
                return
            items = []
            for item in upstream():
                items.append(item)
                yield item
            with memo["lock"]:
                memo["items"] = items

        return Dataset(gen)

    def prefetch(self, buffer_size: int = 1) -> "Dataset":
        if buffer_size <= 0:
            return self
        upstream = self._gen_fn
        return Dataset(lambda: PrefetchIterator(upstream(), buffer_size))

    # -- sinks -------------------------------------------------------------------
    def __iter__(self) -> Iterator:
        return _raising(iter(self._gen_fn()))

    def as_numpy(self) -> List[Any]:
        return list(self)


def image_pipeline(
    storage,
    paths: Sequence[str],
    labels: Optional[Sequence[int]] = None,
    *,
    batch_size: int = 64,
    num_parallel_calls: int = 4,
    prefetch: int = 1,
    shuffle_buffer: int = 1024,
    out_hw: tuple = (224, 224),
    seed: int = 0,
    preprocess: bool = True,
    repeat: bool = False,
) -> Dataset:
    """The paper's full input pipeline (Fig. 2) over an image-file corpus."""
    from . import records

    if labels is not None:
        src = Dataset.from_tensor_slices(list(zip(paths, labels)))

        def load(item):
            path, label = item
            blob = storage.read_file(path)                      # tf.read_file
            payload = records.decode_single_record(blob)
            if preprocess:
                img = records.preprocess_image(payload, *out_hw)  # decode+resize
            else:
                img = np.frombuffer(payload, dtype=np.uint8)      # read-only mode
            return img, np.int32(label)
    else:
        src = Dataset.from_tensor_slices(list(paths))

        def load(path):
            blob = storage.read_file(path)
            payload = records.decode_single_record(blob)
            if preprocess:
                return records.preprocess_image(payload, *out_hw)
            return np.frombuffer(payload, dtype=np.uint8)

    ds = src.shuffle(shuffle_buffer, seed=seed)
    if repeat:
        ds = ds.repeat()
    ds = ds.map(load, num_parallel_calls=num_parallel_calls)
    ds = ds.ignore_errors()
    ds = ds.batch(batch_size, drop_remainder=True)
    if prefetch:
        ds = ds.prefetch(prefetch)
    return ds
