"""Darshan-style aggregate reports over collected spans.

Darshan (and tf-Darshan, arXiv:2008.04395) reduce a raw op log to per-module
aggregates — op counts, bytes moved, latency distributions — plus derived
observables.  Here the modules are pipeline *stages* and the key derived
observable is the compute/input-pipeline **overlap ratio**: the fraction of
compute wall-time during which the input pipeline was concurrently busy
(paper Fig. 6: with prefetching this approaches 1 and data-wait approaches 0).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .tracer import INPUT_PIPELINE_STAGES, STAGE_COMPUTE, SpanRecord


# ---------------------------------------------------------------------------
# Percentiles (self-contained: must be exact on empty/singleton series)
# ---------------------------------------------------------------------------
def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile; 0.0 on empty input.

    ``q`` is in [0, 100].  A singleton series returns its single value for
    every q — the degenerate cases tf-Darshan reports hit constantly (one
    checkpoint per run, one drain per checkpoint).
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    n = len(xs)
    if n == 0:
        return 0.0
    s = sorted(xs)
    if n == 1:
        return float(s[0])
    pos = (q / 100.0) * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


# ---------------------------------------------------------------------------
# Per-stage aggregation
# ---------------------------------------------------------------------------
@dataclass
class StageStats:
    stage: str
    ops: int
    bytes: int
    total_s: float
    mean_ms: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    max_ms: float

    @property
    def mb(self) -> float:
        return self.bytes / 1e6


def aggregate(spans: Iterable[SpanRecord]) -> Dict[str, StageStats]:
    """Reduce spans to per-stage Darshan-style counters, sorted by total time."""
    by_stage: Dict[str, List[SpanRecord]] = {}
    for r in spans:
        by_stage.setdefault(r.stage, []).append(r)
    out: Dict[str, StageStats] = {}
    for stage, recs in by_stage.items():
        durs_ms = [r.dur * 1e3 for r in recs]
        total = sum(r.dur for r in recs)
        out[stage] = StageStats(
            stage=stage,
            ops=len(recs),
            bytes=sum(r.nbytes for r in recs),
            total_s=total,
            mean_ms=(sum(durs_ms) / len(durs_ms)) if durs_ms else 0.0,
            p50_ms=percentile(durs_ms, 50),
            p95_ms=percentile(durs_ms, 95),
            p99_ms=percentile(durs_ms, 99),
            max_ms=max(durs_ms) if durs_ms else 0.0,
        )
    return dict(sorted(out.items(), key=lambda kv: -kv[1].total_s))


# ---------------------------------------------------------------------------
# Interval algebra for the overlap observable
# ---------------------------------------------------------------------------
def _union(intervals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Merge possibly-overlapping [t0, t1) intervals into a disjoint union."""
    if not intervals:
        return []
    intervals = sorted(intervals)
    merged = [intervals[0]]
    for t0, t1 in intervals[1:]:
        m0, m1 = merged[-1]
        if t0 <= m1:
            merged[-1] = (m0, max(m1, t1))
        else:
            merged.append((t0, t1))
    return merged


def _intersection_len(a: List[Tuple[float, float]],
                      b: List[Tuple[float, float]]) -> float:
    total = 0.0
    i = j = 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return total


def busy_intervals(spans: Iterable[SpanRecord],
                   stages: Sequence[str]) -> List[Tuple[float, float]]:
    """Disjoint union of the wall-clock intervals where any of ``stages``
    had at least one span in flight (across all threads)."""
    sel = [(r.t0, r.t0 + r.dur) for r in spans
           if r.stage in stages and r.dur > 0]
    return _union(sel)


def overlap_ratio(
    spans: Iterable[SpanRecord],
    fg_stages: Sequence[str] = (STAGE_COMPUTE,),
    bg_stages: Sequence[str] = INPUT_PIPELINE_STAGES,
) -> float:
    """Fraction of ``fg_stages`` busy-time during which ``bg_stages`` were
    also busy.  With fg=compute and bg=input-pipeline this is the paper's
    Fig. 6 claim made measurable: 1.0 means the input pipeline is fully
    hidden behind compute; 0.0 means they strictly serialize."""
    spans = list(spans)
    fg = busy_intervals(spans, fg_stages)
    fg_len = sum(t1 - t0 for t0, t1 in fg)
    if fg_len <= 0.0:
        return 0.0
    bg = busy_intervals(spans, bg_stages)
    return _intersection_len(fg, bg) / fg_len


# ---------------------------------------------------------------------------
# Markdown report
# ---------------------------------------------------------------------------
def to_markdown(spans: Iterable[SpanRecord], title: str = "I/O trace report",
                counters=None, metrics_series=None) -> str:
    """Render the Darshan-style summary as a markdown document.

    ``metrics_series`` attaches a sampled :mod:`repro.metrics` snapshot
    series (list of ``MetricsRegistry.collect()`` dicts, e.g.
    ``Sampler.points()``) as a gauge-timeline section below the span table —
    fig8's occupancy/backlog view alongside the per-stage latencies.
    """
    spans = list(spans)
    stats = aggregate(spans)
    lines = [f"# {title}", ""]
    if not spans:
        lines.append("_no spans recorded_")
        return "\n".join(lines) + "\n"

    wall = max(r.t0 + r.dur for r in spans) - min(r.t0 for r in spans)
    lines += [
        f"- spans: **{len(spans)}** across **{len(stats)}** stages, "
        f"**{len({r.tid for r in spans})}** threads",
        f"- wall clock covered: **{wall:.3f} s**",
    ]
    # overlap is only meaningful against nonzero compute busy-time: a
    # read-only run (fig5) or one with zero-duration compute spans would
    # otherwise print a misleading 0.00%
    compute_busy = sum(
        t1 - t0 for t0, t1 in busy_intervals(spans, (STAGE_COMPUTE,)))
    if compute_busy > 0.0:
        lines.append(
            f"- compute / input-pipeline overlap ratio: "
            f"**{overlap_ratio(spans):.2%}** "
            "(1.0 = I/O fully hidden behind compute)"
        )
    lines += [
        "",
        "| stage | ops | MB | total s | mean ms | p50 ms | p95 ms | p99 ms | max ms |",
        "|---|---:|---:|---:|---:|---:|---:|---:|---:|",
    ]
    for st in stats.values():
        lines.append(
            f"| {st.stage} | {st.ops} | {st.mb:.2f} | {st.total_s:.3f} "
            f"| {st.mean_ms:.2f} | {st.p50_ms:.2f} | {st.p95_ms:.2f} "
            f"| {st.p99_ms:.2f} | {st.max_ms:.2f} |"
        )
    if counters:
        names = sorted({c.name for c in counters})
        lines += ["", "## Counters", ""]
        for name in names:
            vals = [c.value for c in counters if c.name == name]
            lines.append(
                f"- `{name}`: {len(vals)} samples, min={min(vals):.1f} "
                f"p50={percentile(vals, 50):.1f} max={max(vals):.1f}"
            )
    if metrics_series:
        # late import: trace must stay importable without metrics
        from ..metrics.export import series_markdown

        lines += ["", "## Metrics timeline", ""]
        lines += series_markdown(metrics_series)
    return "\n".join(lines) + "\n"
