"""arch-id -> model functions (init / forward / prefill / decode / cache)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

from . import encdec, transformer


@dataclass(frozen=True)
class ModelFns:
    init_params: Callable
    param_logical: Callable
    forward: Callable          # train-style full forward -> (logits, aux)
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    is_encdec: bool = False


DECODER_ONLY = ModelFns(
    init_params=transformer.init_params,
    param_logical=transformer.param_logical,
    forward=transformer.forward,
    prefill=transformer.prefill,
    decode_step=transformer.decode_step,
    init_cache=transformer.init_cache,
)

ENC_DEC = ModelFns(
    init_params=encdec.init_params,
    param_logical=encdec.param_logical,
    forward=encdec.forward,
    prefill=encdec.prefill,
    decode_step=encdec.decode_step,
    init_cache=encdec.init_cache,
    is_encdec=True,
)


def model_fns(cfg) -> ModelFns:
    return ENC_DEC if cfg.family == "encdec" else DECODER_ONLY
