"""Fig. 15 (ours): goodput and time-to-recover under periodic preemption.

The preemption-safe checkpoint lifecycle, measured end-to-end.  Per slow
tier (hdd/ssd/optane/lustre), a training loop runs under the fused
:class:`~repro.core.recovery.CheckpointManager` (``engine="asyncbb"``:
snapshot-only blocking, optane stage, background drain) and is preempted
every ``steps_per_cycle`` steps with a graceful-shutdown budget
(:meth:`Trainer.preempt`): the newest in-flight save is promoted to its
fast-tier commit inside the deadline, older queued snapshots abandoned.
Each cycle then restarts — a fresh manager resumes from the best of both
tiers and repositions the seekable input iterator (O(1), no replay).

Emitted per tier:

* ``goodput_frac`` — useful compute time over compute + preemption
  overhead (final-save promotion + restart), the headline cost of a
  preemption cycle; a ratio, robust to box speed.
* ``recover_s`` / ``recovery_per_s`` — mean wall time from "new node"
  to training-ready (manager + restore + iterator seek), and its
  higher-is-better reciprocal for the CI regression gate.
* ``preempt_s`` — mean stop-path wall (final snapshot + promotion).
* ``deadline_met`` / ``resumed_at_preempted_step`` — the lifecycle
  contract: with a sane deadline every cycle commits the preempted step
  and every restart resumes exactly there.

Two hdd-only sections ride along:

* **hung-drain injection**: a drain stream wedges mid-save
  (:meth:`FaultyStorage.hang`); the watchdog must detect the stall
  within ``2x drain_stall_timeout``, abort the stream, re-queue its
  chunks, and the save must still commit (``drain_stalls``/
  ``drain_aborts`` reported).
* **fused-vs-bare overhead**: training-thread blocked time through the
  fused manager vs a bare :class:`AsyncBurstBufferCheckpointer` —
  the lifecycle layer must cost <= 1.1x blocked (1.3x in --smoke,
  where ms-scale snapshots make the ratio noisy).

Machine-readable ``BENCH_preemption.json``; gated leaves:
``goodput_frac`` and ``recovery_per_s``.

    PYTHONPATH=src python -m benchmarks.fig15_preemption [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import make_storage
from repro.core.async_burst_buffer import AsyncBurstBufferCheckpointer
from repro.core.dataset import Dataset, ResumableIterator
from repro.core.faults import FaultyStorage
from repro.core.recovery import CheckpointManager
from repro.train.trainer import Trainer

from .common import RESULTS_DIR, SCRATCH, emit

CKPT_TIME_SCALE = float(os.environ.get("REPRO_CKPT_TIME_SCALE", "1.0"))
TIERS = ("hdd", "ssd", "optane", "lustre")
PREFIX = "ck/m"
DEADLINE_S = 30.0
WATCHDOG_TIMEOUT_S = 0.2


def make_state(mb: float):
    rng = np.random.default_rng(0)
    n = int(mb * 1024 * 256)
    return {"w": rng.normal(size=(n,)).astype(np.float32),
            "step": np.int64(0)}


def make_data_iter():
    """Seekable input: restart repositions arithmetically, no replay."""
    return ResumableIterator(
        lambda ep, start=0: Dataset(
            lambda: (np.float32(i) for i in range(start, 1 << 30))))


def make_train_step(compute_s: float):
    def train_step(state, batch):
        time.sleep(compute_s)
        out = dict(state)
        out["step"] = np.int64(int(state["step"]) + 1)
        return out, {"loss": np.float32(batch)}
    return train_step


def preemption_cycles(make_mgr, state_mb, compute_s, n_cycles,
                      steps_per_cycle, ckpt_every, deadline_s=DEADLINE_S):
    """Run preempt/restart cycles; every restart must land exactly on the
    step the previous cycle's promotion committed."""
    steps_done = 0
    preempt_times, recover_times = [], []
    deadline_met = True
    resumed_ok = True
    last_committed = None
    for _ in range(n_cycles):
        t0 = time.monotonic()
        mgr = make_mgr()
        tr = Trainer(make_train_step(compute_s), make_state(state_mb),
                     make_data_iter(), checkpointer=mgr,
                     ckpt_every=ckpt_every, preempt_deadline_s=deadline_s)
        recover_times.append(time.monotonic() - t0)
        if last_committed is not None:
            resumed_ok &= tr.recovered_step == last_committed
        stop_at = (tr.recovered_step or 0) + steps_per_cycle

        def on_step(step, _m, _tr=tr, _stop=stop_at):
            if step >= _stop:
                _tr.preempt()
        tr.on_step = on_step
        tr.run(steps_per_cycle + 1)  # the +1 turn executes the stop path
        steps_done += len(tr.history)
        rep = tr.report()["preemption"]
        assert rep is not None
        deadline_met &= bool(rep["deadline_met"])
        last_committed = rep["committed_step"]
        preempt_times.append(rep["preempt_s"])
        # the node is gone: drains finish during scheduler downtime, off
        # the preemption critical path — not charged to goodput
        mgr.close()
        tr.close()
    compute_total = steps_done * compute_s
    overhead = sum(preempt_times) + sum(recover_times)
    return {
        "steps_done": steps_done,
        "goodput_frac": round(compute_total / (compute_total + overhead), 4),
        "preempt_s": round(float(np.mean(preempt_times)), 4),
        "recover_s": round(float(np.mean(recover_times)), 4),
        "recovery_per_s": round(1.0 / max(float(np.mean(recover_times)),
                                          1e-9), 3),
        "deadline_met": deadline_met,
        "resumed_at_preempted_step": resumed_ok,
    }


def hung_drain_section(root, state_mb):
    """Wedge one drain stream mid-save; the watchdog must absorb it."""
    slow = FaultyStorage(make_storage("hdd", os.path.join(root, "wd_slow"),
                                     time_scale=CKPT_TIME_SCALE))
    fast = make_storage("optane", os.path.join(root, "wd_fast"),
                        time_scale=CKPT_TIME_SCALE)
    mgr = CheckpointManager(slow, PREFIX, engine="asyncbb",
                            fast_storage=fast, keep_last=2,
                            drain_streams=2, drain_chunk=1 << 18,
                            drain_stall_timeout=WATCHDOG_TIMEOUT_S)
    slow.hang(on=".data-")  # one-shot: the re-queued chunk succeeds
    state = make_state(state_mb)
    t0 = time.monotonic()
    mgr.save(1, state)
    mgr.wait()
    wall = time.monotonic() - t0
    stalls, aborts = mgr.engine.drain_stalls, mgr.engine.drain_aborts
    committed = mgr.latest_valid() == 1 and 1 in mgr.all_steps()
    slow.heal()  # un-park the abandoned stream thread
    mgr.close()
    return {
        "drain_stalls": stalls,
        "drain_aborts": aborts,
        "save_committed": committed,
        "wall_s": round(wall, 4),
        "watchdog_timeout_s": WATCHDOG_TIMEOUT_S,
        # detection bound: stall absorbed within 2x timeout + the drain
        "detected_in_budget": stalls >= 1 and committed,
    }


def fused_overhead_section(root, state_mb, n_saves, reps=3):
    """Training-thread blocked time: fused manager vs bare asyncbb."""
    def blocked_with(make_ck, tag):
        best = None
        for r in range(reps):
            ck = make_ck(f"{tag}{r}")
            state = make_state(state_mb)
            for i in range(1, n_saves + 1):
                ck.save(i, state)
            ck.wait()
            total = sum(ck.blocked_s)
            ck.close()
            best = total if best is None else min(best, total)
        return best

    def tiers(tag):
        return (make_storage("optane", os.path.join(root, f"{tag}_fast"),
                             time_scale=CKPT_TIME_SCALE),
                make_storage("hdd", os.path.join(root, f"{tag}_slow"),
                             time_scale=CKPT_TIME_SCALE))

    def bare(tag):
        fast, slow = tiers(tag)
        return AsyncBurstBufferCheckpointer(fast, slow, PREFIX,
                                            drain_streams=4,
                                            drain_chunk=1 << 20)

    def fused(tag):
        fast, slow = tiers(tag)
        return CheckpointManager(slow, PREFIX, engine="asyncbb",
                                 fast_storage=fast, keep_last=3,
                                 drain_streams=4, drain_chunk=1 << 20)

    bare_s = blocked_with(bare, "bare")
    fused_s = blocked_with(fused, "fused")
    return {
        "bare_blocked_s": round(bare_s, 4),
        "fused_blocked_s": round(fused_s, 4),
        "blocked_ratio": round(fused_s / max(bare_s, 1e-9), 4),
    }


def run(state_mb=4.0, compute_s=0.02, n_cycles=3, steps_per_cycle=6,
        ckpt_every=2, n_overhead_saves=6, smoke=False,
        name="fig15_preemption", json_path=None) -> dict:
    rows = []
    tiers_out = {}
    with tempfile.TemporaryDirectory(dir=SCRATCH) as root:
        for tier in TIERS:
            slow = make_storage(tier, os.path.join(root, f"{tier}_slow"),
                                time_scale=CKPT_TIME_SCALE)
            fast = make_storage("optane", os.path.join(root, f"{tier}_fast"),
                                time_scale=CKPT_TIME_SCALE)

            def make_mgr(_slow=slow, _fast=fast):
                return CheckpointManager(_slow, PREFIX, engine="asyncbb",
                                         fast_storage=_fast, keep_last=3,
                                         drain_streams=4,
                                         drain_chunk=1 << 20)
            res = preemption_cycles(make_mgr, state_mb, compute_s,
                                    n_cycles, steps_per_cycle, ckpt_every)
            tiers_out[tier] = res
            rows.append(
                f"tier={tier},goodput_frac={res['goodput_frac']:.3f},"
                f"preempt_s={res['preempt_s']:.3f},"
                f"recover_s={res['recover_s']:.3f},"
                f"deadline_met={res['deadline_met']},"
                f"resumed_at_preempted_step="
                f"{res['resumed_at_preempted_step']}")

        watchdog = hung_drain_section(root, state_mb)
        rows.append(
            f"section=hung_drain,drain_stalls={watchdog['drain_stalls']},"
            f"drain_aborts={watchdog['drain_aborts']},"
            f"save_committed={watchdog['save_committed']},"
            f"wall_s={watchdog['wall_s']:.3f}")

        overhead = fused_overhead_section(root, state_mb, n_overhead_saves)
        rows.append(
            f"section=fused_overhead,bare_blocked_s="
            f"{overhead['bare_blocked_s']:.4f},"
            f"fused_blocked_s={overhead['fused_blocked_s']:.4f},"
            f"blocked_ratio={overhead['blocked_ratio']:.3f}")

    ratio_limit = 1.3 if smoke else 1.1
    ok_contract = all(t["deadline_met"] and t["resumed_at_preempted_step"]
                      for t in tiers_out.values())
    ok_watchdog = watchdog["detected_in_budget"]
    ok_overhead = overhead["blocked_ratio"] <= ratio_limit
    derived = (
        f"preemption contract (deadline met + resume at preempted step) on "
        f"all tiers = {ok_contract}; hung drain absorbed = {ok_watchdog} "
        f"(stalls={watchdog['drain_stalls']}); fused/bare blocked ratio = "
        f"{overhead['blocked_ratio']:.3f} (acceptance: <={ratio_limit}); "
        f"goodput_frac: " + ", ".join(
            f"{t}={tiers_out[t]['goodput_frac']:.3f}" for t in TIERS))
    emit(name, rows, derived)

    payload = {
        "benchmark": name,
        "config": {
            "state_mb": state_mb, "compute_s": compute_s,
            "n_cycles": n_cycles, "steps_per_cycle": steps_per_cycle,
            "ckpt_every": ckpt_every, "deadline_s": DEADLINE_S,
            "n_overhead_saves": n_overhead_saves,
            "time_scale": CKPT_TIME_SCALE, "tiers": list(TIERS),
            "engine": "asyncbb",
        },
        "tiers": tiers_out,
        "hung_drain": watchdog,
        "fused_overhead": overhead,
        "acceptance": {
            "preemption_contract": ok_contract,
            "hung_drain_absorbed": ok_watchdog,
            "fused_blocked_ratio_ok": ok_overhead,
            "fused_blocked_ratio_limit": ratio_limit,
        },
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_json = json_path or os.path.join(RESULTS_DIR, "BENCH_preemption.json")
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_json}")
    return payload


def run_smoke() -> dict:
    """Tiny-scale CI variant: same output shape, seconds of runtime."""
    return run(state_mb=1.0, compute_s=0.01, n_cycles=2, steps_per_cycle=4,
               n_overhead_saves=4, smoke=True)


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    payload = run_smoke() if smoke else run()
    acc = payload["acceptance"]
    ok = all(acc[k] for k in ("preemption_contract", "hung_drain_absorbed",
                              "fused_blocked_ratio_ok"))
    print(f"# preemption_contract={acc['preemption_contract']} "
          f"hung_drain_absorbed={acc['hung_drain_absorbed']} "
          f"fused_blocked_ratio_ok={acc['fused_blocked_ratio_ok']}")
    if not ok:
        sys.exit(1)
