"""Blocked (flash) causal attention — Pallas TPU kernel.

Beyond-paper perf layer: the jnp chunked-attention baseline materializes a
(q_chunk, kv_chunk) logits block in HBM-visible buffers between scan steps;
this kernel keeps the whole online-softmax state in VMEM.

Grid: (batch*heads, Sq / BQ).  Each step loops over KV blocks up to the
causal frontier with ``jax.lax.fori_loop``, carrying (acc, m, l) in VMEM.
Block sizes: BQ x BK = 512 x 512 on hd<=128 keeps q/k/v/acc tiles
(4 x 512 x 128 x 4B = 1 MiB) comfortably inside the ~16 MiB VMEM budget.

The ops.py wrapper handles GQA by broadcasting KV heads and flattens
(B, H) into the leading grid dim.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale, bq, bk, causal):
    # q_ref: (bq, hd); k_ref/v_ref: (Skv, hd) full rows for this (b,h)
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale
    Skv = k_ref.shape[0]
    hd = q.shape[-1]

    n_kv = Skv // bk
    if causal:
        # only blocks whose start <= last q position
        last_q = (qi + 1) * bq - 1
        n_live = jnp.minimum(n_kv, (last_q // bk) + 1)
    else:
        n_live = n_kv

    def body(ki, carry):
        acc, m, l = carry
        k = k_ref[pl.ds(ki * bk, bk), :].astype(jnp.float32)
        v = v_ref[pl.ds(ki * bk, bk), :].astype(jnp.float32)
        s = q @ k.T                                       # (bq, bk)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1, keepdims=True)
        acc_new = acc * alpha + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,          # (BH, Sq, hd)
    k: jax.Array,          # (BH, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, "seq must divide block size"
    sm_scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, bq=bq, bk=bk, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(BH, Sq // bq),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Skv, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Skv, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
