"""Decoder-only LM covering the dense / moe / ssm / hybrid families.

One module, four block layouts:

* dense / moe   — scan over ``n_layers`` of [attn + (mlp|moe)]
* ssm (mamba2)  — scan over ``n_layers`` of [mamba]
* hybrid (jamba)— scan over ``n_layers//attn_period`` *periods*; each period
                  is 1 attention block + (attn_period-1) mamba blocks, with
                  the FFN alternating dense-MLP / MoE per ``moe_period``.

All step functions are cache-aware:
  forward  (train)                 tokens (B,S)   -> logits (B,S,V)
  prefill                          tokens (B,S)   -> (last-token logits, cache)
  decode   (one token w/ KV cache) token  (B,1)   -> (logits, cache)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import mamba as mamba_lib
from .layers import (
    apply_mrope,
    apply_rope,
    chunked_attention,
    decode_attention,
    embed,
    moe_block,
    rms_norm,
    swiglu_mlp,
    unembed,
)

Array = jax.Array


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _embed_scale(cfg) -> Optional[float]:
    return math.sqrt(cfg.d_model) if "gemma" in cfg.name else None


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------
def _norm_init(rng, shape, dtype):
    return jnp.zeros(shape, dtype)


def _dense_init(rng, shape, dtype, fan_in_axes=(0,)):
    fan_in = 1
    for a in fan_in_axes:
        fan_in *= shape[a]
    return (jax.random.normal(rng, shape, jnp.float32) / math.sqrt(fan_in)).astype(dtype)


def attn_param_shapes(cfg) -> Dict[str, tuple]:
    # padded head counts: clean 16-way TP (see ModelConfig.padded_heads)
    D, H, Hkv, hd = cfg.d_model, cfg.padded_heads, cfg.padded_kv_heads, cfg.head_dim
    shapes = dict(
        wq=(D, H, hd), wk=(D, Hkv, hd), wv=(D, Hkv, hd), wo=(H, hd, D),
    )
    if cfg.qk_norm:
        shapes.update(q_norm=(hd,), k_norm=(hd,))
    return shapes


def attn_param_logical(cfg) -> Dict[str, tuple]:
    log = dict(
        wq=("d_model_w", "heads", "head_dim"),
        wk=("d_model_w", "kv_heads", "head_dim"),
        wv=("d_model_w", "kv_heads", "head_dim"),
        wo=("heads", "head_dim", "d_model_w"),
    )
    if cfg.qk_norm:
        log.update(q_norm=(None,), k_norm=(None,))
    return log


def mlp_param_shapes(cfg) -> Dict[str, tuple]:
    return dict(
        wi_gate=(cfg.d_model, cfg.d_ff),
        wi_up=(cfg.d_model, cfg.d_ff),
        wo=(cfg.d_ff, cfg.d_model),
    )


def mlp_param_logical(cfg) -> Dict[str, tuple]:
    return dict(
        wi_gate=("d_model_w", "d_ff"),
        wi_up=("d_model_w", "d_ff"),
        wo=("d_ff", "d_model_w"),
    )


def moe_param_shapes(cfg) -> Dict[str, tuple]:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    return dict(
        router=(D, E),
        wi_gate=(E, D, F), wi_up=(E, D, F), wo=(E, F, D),
    )


def moe_param_logical(cfg) -> Dict[str, tuple]:
    return dict(
        router=("d_model_w", None),
        wi_gate=("experts", "d_model_w", "d_ff"),
        wi_up=("experts", "d_model_w", "d_ff"),
        wo=("experts", "d_ff", "d_model_w"),
    )


def _init_group(rng, shapes: Dict[str, tuple], dtype, stack: tuple = ()) -> Dict[str, Array]:
    out = {}
    keys = jax.random.split(rng, len(shapes))
    for (name, shape), key in zip(sorted(shapes.items()), keys):
        full = tuple(stack) + tuple(shape)
        if name.endswith("norm") or name in ("q_norm", "k_norm"):
            out[name] = jnp.zeros(full, dtype)
        else:
            fan_in_axes = (len(stack),) if len(shape) >= 2 else (0,)
            # contraction dim(s): everything but the last axis for >=2D
            fi = 1
            for a in range(len(stack), len(full) - 1):
                fi *= full[a]
            out[name] = (
                jax.random.normal(key, full, jnp.float32) / math.sqrt(max(fi, 1))
            ).astype(dtype)
    return out


def _stack_logical(logical: Dict[str, tuple], n_stack: int) -> Dict[str, tuple]:
    return {k: tuple(["stack"] * n_stack) + tuple(v) for k, v in logical.items()}


def init_params(rng, cfg) -> Dict[str, Any]:
    """Initialize the full parameter pytree (stacked for scan)."""
    dt = _dtype(cfg)
    k_embed, k_blocks, k_head = jax.random.split(rng, 3)
    V, D, L = cfg.padded_vocab, cfg.d_model, cfg.n_layers
    params: Dict[str, Any] = {
        "embed": _dense_init(k_embed, (V, D), dt, fan_in_axes=(1,)),
        "final_norm": jnp.zeros((D,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _dense_init(k_head, (V, D), dt, fan_in_axes=(1,))

    if cfg.family in ("dense", "moe"):
        blocks: Dict[str, Any] = {
            "ln1": jnp.zeros((L, D), dt),
            "ln2": jnp.zeros((L, D), dt),
            "attn": _init_group(jax.random.fold_in(k_blocks, 0),
                                attn_param_shapes(cfg), dt, (L,)),
        }
        if cfg.is_moe:
            blocks["moe"] = _init_group(jax.random.fold_in(k_blocks, 1),
                                        moe_param_shapes(cfg), dt, (L,))
        else:
            blocks["mlp"] = _init_group(jax.random.fold_in(k_blocks, 1),
                                        mlp_param_shapes(cfg), dt, (L,))
        params["blocks"] = blocks
    elif cfg.family == "ssm":
        # one random draw broadcast across layers (init speed; per-layer
        # randomness is irrelevant to the systems experiments here)
        base = mamba_lib.init_mamba_params(jax.random.fold_in(k_blocks, 0), cfg, dt)
        mam = {k: jnp.broadcast_to(v, (L,) + v.shape).copy() for k, v in base.items()}
        params["blocks"] = {"ln1": jnp.zeros((L, D), dt), "mamba": mam}
    elif cfg.family == "hybrid":
        P = L // cfg.attn_period
        inner = cfg.attn_period
        n_moe = sum(1 for i in range(inner)
                    if (i % cfg.moe_period == cfg.moe_period - 1))
        n_mlp = inner - n_moe
        base_mamba = mamba_lib.init_mamba_params(jax.random.fold_in(k_blocks, 0), cfg, dt)
        blocks = {
            "attn_ln": jnp.zeros((P, D), dt),
            "attn": _init_group(jax.random.fold_in(k_blocks, 1),
                                attn_param_shapes(cfg), dt, (P,)),
            "mamba_ln": jnp.zeros((P, inner - 1, D), dt),
            "mamba": {k: jnp.broadcast_to(v, (P, inner - 1) + v.shape).copy()
                      for k, v in base_mamba.items()},
            "ffn_ln": jnp.zeros((P, inner, D), dt),
            "mlp": _init_group(jax.random.fold_in(k_blocks, 2),
                               mlp_param_shapes(cfg), dt, (P, n_mlp)),
            "moe": _init_group(jax.random.fold_in(k_blocks, 3),
                               moe_param_shapes(cfg), dt, (P, n_moe)),
        }
        params["blocks"] = blocks
    else:
        raise ValueError(f"family {cfg.family} not handled here (encdec lives in encdec.py)")
    return params


def param_logical(cfg) -> Dict[str, Any]:
    """Pytree (matching init_params) of logical-dims tuples."""
    log: Dict[str, Any] = {
        "embed": ("vocab", "d_model_w"),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        log["unembed"] = ("vocab", "d_model_w")
    if cfg.family in ("dense", "moe"):
        blocks = {
            "ln1": ("stack", None), "ln2": ("stack", None),
            "attn": _stack_logical(attn_param_logical(cfg), 1),
        }
        if cfg.is_moe:
            blocks["moe"] = _stack_logical(moe_param_logical(cfg), 1)
        else:
            blocks["mlp"] = _stack_logical(mlp_param_logical(cfg), 1)
        log["blocks"] = blocks
    elif cfg.family == "ssm":
        log["blocks"] = {
            "ln1": ("stack", None),
            "mamba": _stack_logical(mamba_lib.mamba_param_logical(cfg), 1),
        }
    elif cfg.family == "hybrid":
        log["blocks"] = {
            "attn_ln": ("stack", None),
            "attn": _stack_logical(attn_param_logical(cfg), 1),
            "mamba_ln": ("stack", "stack", None),
            "mamba": _stack_logical(mamba_lib.mamba_param_logical(cfg), 2),
            "ffn_ln": ("stack", "stack", None),
            "mlp": _stack_logical(mlp_param_logical(cfg), 2),
            "moe": _stack_logical(moe_param_logical(cfg), 2),
        }
    return log


# ---------------------------------------------------------------------------
# Attention block (shared by forward / prefill / decode)
# ---------------------------------------------------------------------------
def _project_qkv(p, x, cfg, positions, ctx):
    """x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,Hkv,hd) with rope + qk_norm."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if ctx is not None:
        q = ctx.constrain(q, "batch", "seq", "heads", "head_dim")
        k = ctx.constrain(k, "batch", "seq", "kv_heads", "head_dim")
        v = ctx.constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _layer_window(cfg, layer_idx, seq_len: int):
    """Per-layer attention window as a traced scalar (or None = full)."""
    if cfg.local_global_period > 0:
        is_global = (layer_idx % cfg.local_global_period) == (
            cfg.local_global_period - 1
        )
        return jnp.where(is_global, jnp.int32(2 ** 30), jnp.int32(cfg.window))
    if cfg.window is not None:
        return jnp.int32(cfg.window)
    return None


def _attn_block(p, x, cfg, ctx, positions, layer_idx, *, q_chunk, kv_chunk):
    q, k, v = _project_qkv(p, x, cfg, positions, ctx)
    window = _layer_window(cfg, layer_idx, x.shape[1])
    out = chunked_attention(
        q, k, v, causal=True, window=window,
        q_chunk=q_chunk, kv_chunk=kv_chunk, ctx=ctx,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _ffn(blocks_slice, x, cfg, ctx, use_moe: bool, which: str = "moe"):
    if use_moe:
        m = blocks_slice[which]
        y, aux = moe_block(
            x, m["router"], m["wi_gate"], m["wi_up"], m["wo"],
            top_k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
            chunk=cfg.moe_chunk, ctx=ctx,
        )
        return y, aux
    m = blocks_slice["mlp"]
    return swiglu_mlp(x, m["wi_gate"], m["wi_up"], m["wo"], ctx=ctx), jnp.float32(0)


# ---------------------------------------------------------------------------
# Forward (training)
# ---------------------------------------------------------------------------
def forward(
    params: Dict[str, Any],
    tokens: Array,                  # (B, S) int32
    cfg,
    ctx=None,
    *,
    positions: Optional[Array] = None,
    remat: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[Array, Array]:
    """Returns (logits (B,S,V), moe_aux_loss)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, B, S))
    x = embed(tokens, params["embed"], ctx, scale=_embed_scale(cfg))

    if cfg.family in ("dense", "moe"):
        def layer(x, xs):
            blk, idx = xs
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            x = x + _attn_block(blk["attn"], h, cfg, ctx, positions, idx,
                                q_chunk=q_chunk, kv_chunk=kv_chunk)
            h = rms_norm(x, blk["ln2"], cfg.norm_eps)
            y, aux = _ffn(blk, h, cfg, ctx, cfg.is_moe)
            x = x + y
            if ctx is not None:
                x = ctx.constrain(x, "batch", "res_seq", "d_model")
            return x, aux

        f = jax.checkpoint(layer) if remat else layer
        x, auxes = lax.scan(f, x, (params["blocks"], jnp.arange(cfg.n_layers)))
        aux = auxes.sum()
    elif cfg.family == "ssm":
        def layer(x, xs):
            blk, idx = xs
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            y, _state = mamba_lib.mamba_forward(blk["mamba"], h, cfg, ctx=ctx)
            x = x + y
            if ctx is not None:
                x = ctx.constrain(x, "batch", "res_seq", "d_model")
            return x, jnp.float32(0)

        f = jax.checkpoint(layer) if remat else layer
        x, auxes = lax.scan(f, x, (params["blocks"], jnp.arange(cfg.n_layers)))
        aux = auxes.sum()
    elif cfg.family == "hybrid":
        inner = cfg.attn_period

        def period(x, xs):
            blk, pidx = xs
            aux_total = jnp.float32(0)
            i_mlp = i_moe = 0

            def ckpt(f, *args):
                # nested remat: one sub-block's internals live at a time
                # during the period's backward sweep
                return (jax.checkpoint(f) if remat else f)(*args)

            for i in range(inner):
                gidx = pidx * inner + i
                if i == 0:
                    def attn_sub(x):
                        h = rms_norm(x, blk["attn_ln"], cfg.norm_eps)
                        return x + _attn_block(
                            blk["attn"], h, cfg, ctx, positions, gidx,
                            q_chunk=q_chunk, kv_chunk=kv_chunk)
                    x = ckpt(attn_sub, x)
                else:
                    mp = {k: v[i - 1] for k, v in blk["mamba"].items()}
                    ln = blk["mamba_ln"][i - 1]

                    def mamba_sub(x, mp=mp, ln=ln):
                        h = rms_norm(x, ln, cfg.norm_eps)
                        y, _ = mamba_lib.mamba_forward(mp, h, cfg, ctx=ctx)
                        out = x + y
                        if ctx is not None:
                            out = ctx.constrain(out, "batch", "res_seq",
                                                "d_model")
                        return out
                    x = ckpt(mamba_sub, x)
                use_moe = (i % cfg.moe_period) == (cfg.moe_period - 1)
                ln = blk["ffn_ln"][i]
                if use_moe:
                    sub = {"moe": {k: v[i_moe] for k, v in blk["moe"].items()}}
                    i_moe += 1
                else:
                    sub = {"mlp": {k: v[i_mlp] for k, v in blk["mlp"].items()}}
                    i_mlp += 1

                def ffn_sub(x, sub=sub, ln=ln, use_moe=use_moe):
                    h = rms_norm(x, ln, cfg.norm_eps)
                    y, aux = _ffn(sub, h, cfg, ctx, use_moe)
                    return x + y, aux
                y_aux = ckpt(ffn_sub, x)
                x, aux = y_aux
                aux_total = aux_total + aux
            if ctx is not None:
                x = ctx.constrain(x, "batch", "res_seq", "d_model")
            return x, aux_total

        f = jax.checkpoint(period) if remat else period
        P = cfg.n_layers // inner
        x, auxes = lax.scan(f, x, (params["blocks"], jnp.arange(P)))
        aux = auxes.sum()
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, table, ctx)
    return logits, aux


# ---------------------------------------------------------------------------
# Cache init / prefill / decode
# ---------------------------------------------------------------------------
def _ring_len(cfg, max_len: int) -> int:
    """All-SWA archs (mixtral) never attend beyond ``window`` — the decode
    cache is a ring buffer of window slots instead of the full sequence
    (long_500k: 120 GB -> 0.9 GB of KV; EXPERIMENTS.md §Perf iteration 4)."""
    if cfg.window is not None and cfg.local_global_period == 0:
        return min(max_len, cfg.window)
    return max_len


def init_cache(cfg, batch: int, max_len: int, ctx=None) -> Dict[str, Any]:
    dt = _dtype(cfg)
    Hkv, hd = cfg.padded_kv_heads, cfg.head_dim
    max_len = _ring_len(cfg, max_len)

    def kv(n_stack):
        shape = (n_stack, batch, max_len, Hkv, hd)
        arr = jnp.zeros(shape, dt)
        if ctx is not None:
            arr = ctx.constrain(arr, "stack", "batch", "kv_seq", "kv_heads", "head_dim")
        return arr

    if cfg.family in ("dense", "moe"):
        return dict(k=kv(cfg.n_layers), v=kv(cfg.n_layers), pos=jnp.int32(0))
    if cfg.family == "ssm":
        base = mamba_lib.init_mamba_cache(cfg, batch, dt)
        return dict(
            state=jnp.zeros((cfg.n_layers,) + base["state"].shape, jnp.float32),
            conv=jnp.zeros((cfg.n_layers,) + base["conv"].shape, dt),
            pos=jnp.int32(0),
        )
    if cfg.family == "hybrid":
        P = cfg.n_layers // cfg.attn_period
        inner = cfg.attn_period
        base = mamba_lib.init_mamba_cache(cfg, batch, dt)
        return dict(
            k=kv(P), v=kv(P),
            state=jnp.zeros((P, inner - 1) + base["state"].shape, jnp.float32),
            conv=jnp.zeros((P, inner - 1) + base["conv"].shape, dt),
            pos=jnp.int32(0),
        )
    raise ValueError(cfg.family)


def _fit_cache(x: Array, max_len: int, dtype) -> Array:
    """Pad (or ring-trim to the last ``max_len`` positions) along axis 1."""
    S = x.shape[1]
    if S > max_len:
        return x[:, S - max_len:].astype(dtype)
    if S < max_len:
        x = jnp.pad(x, [(0, 0), (0, max_len - S), (0, 0), (0, 0)])
    return x.astype(dtype)


def prefill(
    params, tokens: Array, cache: Dict[str, Any], cfg, ctx=None,
    *, positions: Optional[Array] = None, q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[Array, Dict[str, Any]]:
    """Run the prompt through the model, filling the cache.
    Returns (logits for the last position (B,V), updated cache)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, B, S))
    x = embed(tokens, params["embed"], ctx, scale=_embed_scale(cfg))

    if cfg.family in ("dense", "moe"):
        def layer(x, xs):
            blk, idx = xs
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            q, k, v = _project_qkv(blk["attn"], h, cfg, positions, ctx)
            window = _layer_window(cfg, idx, S)
            o = chunked_attention(q, k, v, causal=True, window=window,
                                  q_chunk=q_chunk, kv_chunk=kv_chunk, ctx=ctx)
            x = x + jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"])
            h = rms_norm(x, blk["ln2"], cfg.norm_eps)
            y, _ = _ffn(blk, h, cfg, ctx, cfg.is_moe)
            x = x + y
            if ctx is not None:
                x = ctx.constrain(x, "batch", "res_seq", "d_model")
            # cache entries padded (or ring-trimmed) to the cache length
            max_len = cache["k"].shape[2]
            return x, (_fit_cache(k, max_len, _dtype(cfg)),
                       _fit_cache(v, max_len, _dtype(cfg)))

        x, (ks, vs) = lax.scan(layer, x, (params["blocks"], jnp.arange(cfg.n_layers)))
        new_cache = dict(k=ks, v=vs, pos=jnp.int32(S))
    elif cfg.family == "ssm":
        def layer(x, xs):
            blk, idx = xs
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            y, (state, conv) = mamba_lib.mamba_forward(
                blk["mamba"], h, cfg, ctx=ctx, return_cache=True
            )
            x = x + y
            if ctx is not None:
                x = ctx.constrain(x, "batch", "res_seq", "d_model")
            return x, (state, conv)

        x, (states, convs) = lax.scan(layer, x, (params["blocks"], jnp.arange(cfg.n_layers)))
        new_cache = dict(state=states, conv=convs.astype(_dtype(cfg)), pos=jnp.int32(S))
    elif cfg.family == "hybrid":
        inner = cfg.attn_period
        max_len = cache["k"].shape[2]

        def period(x, xs):
            blk, pidx = xs
            states, convs = [], []
            k_out = v_out = None
            i_mlp = i_moe = 0
            for i in range(inner):
                gidx = pidx * inner + i
                if i == 0:
                    h = rms_norm(x, blk["attn_ln"], cfg.norm_eps)
                    q, k, v = _project_qkv(blk["attn"], h, cfg, positions, ctx)
                    window = _layer_window(cfg, gidx, S)
                    o = chunked_attention(q, k, v, causal=True, window=window,
                                          q_chunk=q_chunk, kv_chunk=kv_chunk,
                                          ctx=ctx)
                    x = x + jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"])
                    k_out = _fit_cache(k, max_len, _dtype(cfg))
                    v_out = _fit_cache(v, max_len, _dtype(cfg))
                else:
                    h = rms_norm(x, blk["mamba_ln"][i - 1], cfg.norm_eps)
                    mp = {kk: vv[i - 1] for kk, vv in blk["mamba"].items()}
                    y, (st, cv) = mamba_lib.mamba_forward(
                        mp, h, cfg, ctx=ctx, return_cache=True
                    )
                    x = x + y
                    states.append(st)
                    convs.append(cv)
                use_moe = (i % cfg.moe_period) == (cfg.moe_period - 1)
                h = rms_norm(x, blk["ffn_ln"][i], cfg.norm_eps)
                if use_moe:
                    sub = {"moe": {kk: vv[i_moe] for kk, vv in blk["moe"].items()}}
                    y, _ = _ffn(sub, h, cfg, ctx, True)
                    i_moe += 1
                else:
                    sub = {"mlp": {kk: vv[i_mlp] for kk, vv in blk["mlp"].items()}}
                    y, _ = _ffn(sub, h, cfg, ctx, False)
                    i_mlp += 1
                x = x + y
            if ctx is not None:
                x = ctx.constrain(x, "batch", "res_seq", "d_model")
            return x, (k_out, v_out, jnp.stack(states), jnp.stack(convs))

        P = cfg.n_layers // inner
        x, (ks, vs, states, convs) = lax.scan(
            period, x, (params["blocks"], jnp.arange(P))
        )
        new_cache = dict(k=ks, v=vs, state=states,
                         conv=convs.astype(_dtype(cfg)), pos=jnp.int32(S))
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, table, ctx)[:, 0]
    return logits, new_cache


def decode_step(
    params, token: Array, cache: Dict[str, Any], cfg, ctx=None,
    *, positions: Optional[Array] = None,
) -> Tuple[Array, Dict[str, Any]]:
    """One token with cache. token: (B,1) -> logits (B,V)."""
    B = token.shape[0]
    pos = cache["pos"]
    if positions is None:
        positions = jnp.full((B, 1), pos, dtype=jnp.int32)
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, B, 1))
    x = embed(token, params["embed"], ctx, scale=_embed_scale(cfg))

    if cfg.family in ("dense", "moe"):
        def layer(x, xs):
            blk, k_cache, v_cache, idx = xs
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            q, k, v = _project_qkv(blk["attn"], h, cfg, positions, ctx)
            L_cache = k_cache.shape[1]
            ring = (cfg.window is not None and cfg.local_global_period == 0
                    and L_cache == cfg.window)
            slot = pos % L_cache if ring else pos
            k_cache = lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
            v_cache = lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
            if ring:
                # window is enforced by construction; only startup slots
                # beyond pos are invalid
                o = decode_attention(q, k_cache, v_cache,
                                     jnp.minimum(pos + 1, L_cache), ctx=ctx)
            else:
                window = _layer_window(cfg, idx, L_cache)
                o = decode_attention(q, k_cache, v_cache, pos + 1,
                                     window=window, ctx=ctx)
            x = x + jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"])
            h = rms_norm(x, blk["ln2"], cfg.norm_eps)
            y, _ = _ffn(blk, h, cfg, ctx, cfg.is_moe)
            return x + y, (k_cache, v_cache)

        x, (ks, vs) = lax.scan(
            layer, x,
            (params["blocks"], cache["k"], cache["v"], jnp.arange(cfg.n_layers)),
        )
        new_cache = dict(k=ks, v=vs, pos=pos + 1)
    elif cfg.family == "ssm":
        def layer(x, xs):
            blk, st, cv, idx = xs
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            y, nc = mamba_lib.mamba_decode(
                blk["mamba"], h, dict(state=st, conv=cv), cfg
            )
            return x + y, (nc["state"], nc["conv"])

        x, (states, convs) = lax.scan(
            layer, x,
            (params["blocks"], cache["state"], cache["conv"], jnp.arange(cfg.n_layers)),
        )
        new_cache = dict(state=states, conv=convs, pos=pos + 1)
    elif cfg.family == "hybrid":
        inner = cfg.attn_period

        def period(x, xs):
            blk, k_cache, v_cache, sts, cvs, pidx = xs
            new_sts, new_cvs = [], []
            i_mlp = i_moe = 0
            for i in range(inner):
                gidx = pidx * inner + i
                if i == 0:
                    h = rms_norm(x, blk["attn_ln"], cfg.norm_eps)
                    q, k, v = _project_qkv(blk["attn"], h, cfg, positions, ctx)
                    k_cache = lax.dynamic_update_slice(
                        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
                    v_cache = lax.dynamic_update_slice(
                        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
                    window = _layer_window(cfg, gidx, k_cache.shape[1])
                    o = decode_attention(q, k_cache, v_cache, pos + 1,
                                         window=window, ctx=ctx)
                    x = x + jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"])
                else:
                    h = rms_norm(x, blk["mamba_ln"][i - 1], cfg.norm_eps)
                    mp = {kk: vv[i - 1] for kk, vv in blk["mamba"].items()}
                    y, nc = mamba_lib.mamba_decode(
                        mp, h, dict(state=sts[i - 1], conv=cvs[i - 1]), cfg
                    )
                    x = x + y
                    new_sts.append(nc["state"])
                    new_cvs.append(nc["conv"])
                use_moe = (i % cfg.moe_period) == (cfg.moe_period - 1)
                h = rms_norm(x, blk["ffn_ln"][i], cfg.norm_eps)
                if use_moe:
                    sub = {"moe": {kk: vv[i_moe] for kk, vv in blk["moe"].items()}}
                    y, _ = _ffn(sub, h, cfg, ctx, True)
                    i_moe += 1
                else:
                    sub = {"mlp": {kk: vv[i_mlp] for kk, vv in blk["mlp"].items()}}
                    y, _ = _ffn(sub, h, cfg, ctx, False)
                    i_mlp += 1
                x = x + y
            return x, (k_cache, v_cache, jnp.stack(new_sts), jnp.stack(new_cvs))

        P = cfg.n_layers // inner
        x, (ks, vs, states, convs) = lax.scan(
            period, x,
            (params["blocks"], cache["k"], cache["v"], cache["state"],
             cache["conv"], jnp.arange(P)),
        )
        new_cache = dict(k=ks, v=vs, state=states, conv=convs, pos=pos + 1)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(x, table, ctx)[:, 0]
    return logits, new_cache
