"""Encoder-decoder LM (seamless-m4t-medium backbone).

The speech/text frontend is a STUB per the assignment: the encoder consumes
*precomputed frame embeddings* (B, T_src, d_model) — ``input_specs()``
provides them — and the decoder is a standard causal LM with cross-attention
over the encoder output.

Step functions:
  forward (train)  (frames, tokens) -> logits (B, S_dec, V)
  prefill          encode + run decoder prompt, build (self KV, cross KV)
  decode_step      one decoder token
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import (
    apply_rope,
    chunked_attention,
    decode_attention,
    embed,
    rms_norm,
    swiglu_mlp,
    unembed,
)
from .transformer import (
    _dtype,
    _init_group,
    attn_param_logical,
    attn_param_shapes,
    mlp_param_logical,
    mlp_param_shapes,
    _stack_logical,
)

Array = jax.Array


def init_params(rng, cfg) -> Dict[str, Any]:
    dt = _dtype(cfg)
    D, Le, Ld, V = cfg.d_model, cfg.enc_layers, cfg.n_layers, cfg.padded_vocab
    k_embed, k_enc, k_dec = jax.random.split(rng, 3)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (V, D), jnp.float32) / math.sqrt(D)).astype(dt),
        "final_norm": jnp.zeros((D,), dt),
        "enc_final_norm": jnp.zeros((D,), dt),
        "encoder": {
            "ln1": jnp.zeros((Le, D), dt),
            "ln2": jnp.zeros((Le, D), dt),
            "attn": _init_group(jax.random.fold_in(k_enc, 0),
                                attn_param_shapes(cfg), dt, (Le,)),
            "mlp": _init_group(jax.random.fold_in(k_enc, 1),
                               mlp_param_shapes(cfg), dt, (Le,)),
        },
        "decoder": {
            "ln1": jnp.zeros((Ld, D), dt),
            "ln_x": jnp.zeros((Ld, D), dt),
            "ln2": jnp.zeros((Ld, D), dt),
            "attn": _init_group(jax.random.fold_in(k_dec, 0),
                                attn_param_shapes(cfg), dt, (Ld,)),
            "xattn": _init_group(jax.random.fold_in(k_dec, 1),
                                 attn_param_shapes(cfg), dt, (Ld,)),
            "mlp": _init_group(jax.random.fold_in(k_dec, 2),
                               mlp_param_shapes(cfg), dt, (Ld,)),
        },
    }
    return params


def param_logical(cfg) -> Dict[str, Any]:
    enc = {
        "ln1": ("stack", None), "ln2": ("stack", None),
        "attn": _stack_logical(attn_param_logical(cfg), 1),
        "mlp": _stack_logical(mlp_param_logical(cfg), 1),
    }
    dec = {
        "ln1": ("stack", None), "ln_x": ("stack", None), "ln2": ("stack", None),
        "attn": _stack_logical(attn_param_logical(cfg), 1),
        "xattn": _stack_logical(attn_param_logical(cfg), 1),
        "mlp": _stack_logical(mlp_param_logical(cfg), 1),
    }
    return {
        "embed": ("vocab", "d_model_w"),
        "final_norm": (None,),
        "enc_final_norm": (None,),
        "encoder": enc,
        "decoder": dec,
    }


def _qkv(p, x, cfg, positions, ctx, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if ctx is not None:
        q = ctx.constrain(q, "batch", "seq", "heads", "head_dim")
        k = ctx.constrain(k, "batch", "seq", "kv_heads", "head_dim")
        v = ctx.constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def encode(params, frames: Array, cfg, ctx=None,
           *, q_chunk: int = 1024, kv_chunk: int = 1024,
           remat: bool = True) -> Array:
    """frames: (B, T_src, D) stub embeddings -> encoder output (B, T_src, D)."""
    B, T, D = frames.shape
    positions = jnp.arange(T)[None, :]
    x = frames.astype(_dtype(cfg))
    if ctx is not None:
        x = ctx.constrain(x, "batch", "res_seq", "d_model")

    def layer(x, blk):
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _qkv(blk["attn"], h, cfg, positions, ctx)
        o = chunked_attention(q, k, v, causal=False,
                              q_chunk=q_chunk, kv_chunk=kv_chunk, ctx=ctx)
        x = x + jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"])
        h = rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + swiglu_mlp(h, blk["mlp"]["wi_gate"], blk["mlp"]["wi_up"],
                           blk["mlp"]["wo"], ctx=ctx)
        if ctx is not None:
            x = ctx.constrain(x, "batch", "res_seq", "d_model")
        return x, None

    f = jax.checkpoint(layer) if remat else layer
    x, _ = lax.scan(f, x, params["encoder"])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _decoder_stack(params, x, enc_out, cfg, ctx, positions,
                   *, q_chunk, kv_chunk, remat):
    """Training decoder: full causal self-attn + cross-attn over enc_out."""
    def layer(x, blk):
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _qkv(blk["attn"], h, cfg, positions, ctx)
        o = chunked_attention(q, k, v, causal=True,
                              q_chunk=q_chunk, kv_chunk=kv_chunk, ctx=ctx)
        x = x + jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"])
        # cross attention (queries from decoder, keys/values from encoder)
        h = rms_norm(x, blk["ln_x"], cfg.norm_eps)
        xq = jnp.einsum("bsd,dhk->bshk", h, blk["xattn"]["wq"])
        xk = jnp.einsum("btd,dhk->bthk", enc_out, blk["xattn"]["wk"])
        xv = jnp.einsum("btd,dhk->bthk", enc_out, blk["xattn"]["wv"])
        o = chunked_attention(xq, xk, xv, causal=False,
                              q_chunk=q_chunk, kv_chunk=kv_chunk, ctx=ctx)
        x = x + jnp.einsum("bshk,hkd->bsd", o, blk["xattn"]["wo"])
        h = rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + swiglu_mlp(h, blk["mlp"]["wi_gate"], blk["mlp"]["wi_up"],
                           blk["mlp"]["wo"], ctx=ctx)
        if ctx is not None:
            x = ctx.constrain(x, "batch", "res_seq", "d_model")
        return x, None

    f = jax.checkpoint(layer) if remat else layer
    x, _ = lax.scan(f, x, params["decoder"])
    return x


def forward(params, frames: Array, tokens: Array, cfg, ctx=None,
            *, remat: bool = True, q_chunk: int = 1024, kv_chunk: int = 1024
            ) -> Tuple[Array, Array]:
    """Training step: returns (logits (B,S_dec,V), aux=0)."""
    B, S = tokens.shape
    enc_out = encode(params, frames, cfg, ctx,
                     q_chunk=q_chunk, kv_chunk=kv_chunk, remat=remat)
    positions = jnp.arange(S)[None, :]
    x = embed(tokens, params["embed"], ctx)
    x = _decoder_stack(params, x, enc_out, cfg, ctx, positions,
                       q_chunk=q_chunk, kv_chunk=kv_chunk, remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"], ctx)
    return logits, jnp.float32(0)


def init_cache(cfg, batch: int, max_len: int, enc_len: int, ctx=None) -> Dict[str, Any]:
    dt = _dtype(cfg)
    Ld, Hkv, hd = cfg.n_layers, cfg.padded_kv_heads, cfg.head_dim

    def c(shape, logical):
        arr = jnp.zeros(shape, dt)
        if ctx is not None:
            arr = ctx.constrain(arr, *logical)
        return arr

    return dict(
        k=c((Ld, batch, max_len, Hkv, hd),
            ("stack", "batch", "kv_seq", "kv_heads", "head_dim")),
        v=c((Ld, batch, max_len, Hkv, hd),
            ("stack", "batch", "kv_seq", "kv_heads", "head_dim")),
        xk=c((Ld, batch, enc_len, Hkv, hd),
             ("stack", "batch", "enc_seq", "kv_heads", "head_dim")),
        xv=c((Ld, batch, enc_len, Hkv, hd),
             ("stack", "batch", "enc_seq", "kv_heads", "head_dim")),
        pos=jnp.int32(0),
    )


def prefill(params, frames: Array, tokens: Array, cache: Dict[str, Any],
            cfg, ctx=None, *, q_chunk: int = 1024, kv_chunk: int = 1024
            ) -> Tuple[Array, Dict[str, Any]]:
    """Encode source frames + run the decoder prompt, filling both caches."""
    B, S = tokens.shape
    enc_out = encode(params, frames, cfg, ctx, q_chunk=q_chunk, kv_chunk=kv_chunk,
                     remat=False)
    positions = jnp.arange(S)[None, :]
    x = embed(tokens, params["embed"], ctx)
    max_len = cache["k"].shape[2]

    def layer(x, blk):
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _qkv(blk["attn"], h, cfg, positions, ctx)
        o = chunked_attention(q, k, v, causal=True,
                              q_chunk=q_chunk, kv_chunk=kv_chunk, ctx=ctx)
        x = x + jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"])
        h = rms_norm(x, blk["ln_x"], cfg.norm_eps)
        xq = jnp.einsum("bsd,dhk->bshk", h, blk["xattn"]["wq"])
        xk = jnp.einsum("btd,dhk->bthk", enc_out, blk["xattn"]["wk"])
        xv = jnp.einsum("btd,dhk->bthk", enc_out, blk["xattn"]["wv"])
        o = chunked_attention(xq, xk, xv, causal=False,
                              q_chunk=q_chunk, kv_chunk=kv_chunk, ctx=ctx)
        x = x + jnp.einsum("bshk,hkd->bsd", o, blk["xattn"]["wo"])
        h = rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + swiglu_mlp(h, blk["mlp"]["wi_gate"], blk["mlp"]["wi_up"],
                           blk["mlp"]["wo"], ctx=ctx)
        if ctx is not None:
            x = ctx.constrain(x, "batch", "res_seq", "d_model")
        pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
        return x, (jnp.pad(k, pad).astype(_dtype(cfg)),
                   jnp.pad(v, pad).astype(_dtype(cfg)),
                   xk.astype(_dtype(cfg)), xv.astype(_dtype(cfg)))

    x, (ks, vs, xks, xvs) = lax.scan(layer, x, params["decoder"])
    new_cache = dict(k=ks, v=vs, xk=xks, xv=xvs, pos=jnp.int32(S))
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"], ctx)[:, 0]
    return logits, new_cache


def decode_step(params, token: Array, cache: Dict[str, Any], cfg, ctx=None
                ) -> Tuple[Array, Dict[str, Any]]:
    B = token.shape[0]
    pos = cache["pos"]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    x = embed(token, params["embed"], ctx)
    enc_len = cache["xk"].shape[2]

    def layer(x, xs):
        blk, k_cache, v_cache, xk, xv = xs
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _qkv(blk["attn"], h, cfg, positions, ctx)
        k_cache = lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, pos, 0, 0))
        v_cache = lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, pos, 0, 0))
        o = decode_attention(q, k_cache, v_cache, pos + 1, ctx=ctx)
        x = x + jnp.einsum("bshk,hkd->bsd", o, blk["attn"]["wo"])
        h = rms_norm(x, blk["ln_x"], cfg.norm_eps)
        xq = jnp.einsum("bsd,dhk->bshk", h, blk["xattn"]["wq"])
        o = decode_attention(xq, xk, xv, jnp.int32(enc_len), ctx=ctx)
        x = x + jnp.einsum("bshk,hkd->bsd", o, blk["xattn"]["wo"])
        h = rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + swiglu_mlp(h, blk["mlp"]["wi_gate"], blk["mlp"]["wi_up"],
                           blk["mlp"]["wo"], ctx=ctx)
        return x, (k_cache, v_cache)

    x, (ks, vs) = lax.scan(
        layer, x,
        (params["decoder"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
    )
    new_cache = dict(k=ks, v=vs, xk=cache["xk"], xv=cache["xv"], pos=pos + 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"], ctx)[:, 0]
    return logits, new_cache
