"""Batched serving example: prefill + decode loop with a KV cache.

    PYTHONPATH=src python examples/serve.py [--arch qwen3-4b] [--batch 4]

Serves a smoke-scale model: batches of prompts are prefilled, then decoded
token by token (greedy).  The same prefill/decode step functions lower to
the production pod meshes in repro.launch.dryrun.
"""
import argparse, sys, time
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models.registry import model_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].smoke()
    fns = model_fns(cfg)
    params = fns.init_params(jax.random.PRNGKey(0), cfg)
    B, P, G = args.batch, args.prompt_len, args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.padded_vocab, dtype=jnp.int32)

    if fns.is_encdec:
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (B, 8, cfg.d_model)).astype(jnp.bfloat16)
        cache = fns.init_cache(cfg, B, P + G, 8)
        prefill = jax.jit(lambda p, f, t, c: fns.prefill(p, f, t, c, cfg))
        logits, cache = prefill(params, frames, prompts, cache)
    else:
        cache = fns.init_cache(cfg, B, P + G)
        prefill = jax.jit(lambda p, t, c: fns.prefill(p, t, c, cfg))
        logits, cache = prefill(params, prompts, cache)
    decode = jax.jit(lambda p, t, c: fns.decode_step(p, t, c, cfg))

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.monotonic()
    for _ in range(G - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.monotonic() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={P} generated={gen.shape[1]}")
    print(f"decode throughput: {B*(G-1)/dt:.1f} tok/s (CPU, smoke scale)")
    print("first sequence:", gen[0].tolist())


if __name__ == "__main__":
    main()
