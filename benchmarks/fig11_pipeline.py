"""Fig. 11 (ours): legacy per-file ingestion vs the vectorized read engine.

Same images, same simulated tier, three pipelines:

* ``legacy``     — seed path: one single-image ``.rrf`` per element,
  per-element map -> ignore_errors -> batch (per-image seek + copy chain);
* ``vectorized`` — same per-file corpus through the fused ``map_and_batch``
  (zero-copy decode, LUT resize into the batch buffer);
* ``sharded``    — multi-record shards streamed by ``interleave`` (one
  sequential read per shard) + fused map_and_batch.

Emits the usual CSV rows plus machine-readable ``BENCH_pipeline.json``
(samples/s and bytes/s per thread count per pipeline) so CI accumulates a
perf trajectory.  Acceptance: sharded >= 2x legacy samples/s at the sweep's
top thread count, and bandwidth monotone in threads.

    PYTHONPATH=src python -m benchmarks.fig11_pipeline [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

sys.path.insert(0, "src")

from repro import metrics
from repro.core import make_storage, records
from repro.core.microbench import run_microbench, run_sharded_microbench, \
    thread_scaling_sweep

from .common import RESULTS_DIR, SCRATCH, emit

# Like fig4: real-time pacing (time_scale=1.0), so the modelled device —
# not this 1-core box's Python cost — dominates and thread scaling is the
# device's.  hdd's 8 ms seek per file is exactly the per-image tax the
# sharded layout amortizes.
TIME_SCALE = 1.0


def run(tier="hdd", n_images=128, images_per_shard=16, mean_hw=(96, 96),
        out_hw=(32, 32), thread_counts=(1, 2, 4, 8), batch_size=32,
        repeats=3, name="fig11_pipeline", json_path=None,
        metrics_jsonl=None) -> dict:
    # live telemetry rides along: a Sampler snapshots the registry (reader
    # pool occupancy, per-tier storage latency sketches, pipeline rates)
    # into a JSONL time series CI uploads as an artifact
    os.makedirs(RESULTS_DIR, exist_ok=True)
    metrics_jsonl = metrics_jsonl or os.path.join(
        RESULTS_DIR, "metrics_pipeline.jsonl")
    metrics.start()
    sampler = metrics.Sampler(interval_s=0.2, jsonl_path=metrics_jsonl)
    sampler.start()
    try:
        return _run_sweep(tier, n_images, images_per_shard, mean_hw, out_hw,
                          thread_counts, batch_size, repeats, name, json_path)
    finally:
        sampler.stop()
        metrics.stop()
        print(f"# wrote {metrics_jsonl} ({len(sampler.points())} samples)")


def _run_sweep(tier, n_images, images_per_shard, mean_hw, out_hw,
               thread_counts, batch_size, repeats, name, json_path) -> dict:
    with tempfile.TemporaryDirectory(dir=SCRATCH) as tmp:
        st = make_storage(tier, os.path.join(tmp, tier),
                          time_scale=TIME_SCALE)
        file_paths, _ = records.write_image_dataset(
            st, n_images, mean_hw=mean_hw, seed=0, prefix="img")
        shard_paths, _ = records.write_sharded_image_dataset(
            st, n_images, images_per_shard, mean_hw=mean_hw, seed=0,
            prefix="shard")
        st.drop_caches()

        sweeps = {
            "legacy": thread_scaling_sweep(
                st, file_paths, thread_counts=thread_counts, repeats=repeats,
                batch_size=batch_size, out_hw=out_hw, pipeline="legacy"),
            "vectorized": thread_scaling_sweep(
                st, file_paths, thread_counts=thread_counts, repeats=repeats,
                batch_size=batch_size, out_hw=out_hw, pipeline="vectorized"),
            "sharded": thread_scaling_sweep(
                st, shard_paths, thread_counts=thread_counts, repeats=repeats,
                batch_size=batch_size, out_hw=out_hw,
                bench=run_sharded_microbench),
        }

    rows, result = [], {}
    for pipeline, runs in sweeps.items():
        per_threads = {}
        for r in runs:
            per_threads[str(r.threads)] = {
                "samples_per_s": round(r.images_per_s, 2),
                "bytes_per_s": round(r.total_bytes / r.seconds, 1),
            }
            rows.append(
                f"{tier},pipeline={pipeline},threads={r.threads},"
                f"img_s={r.images_per_s:.1f},mb_s={r.mb_per_s:.2f}")
        result[pipeline] = per_threads

    top = str(max(thread_counts))
    speedup = (result["sharded"][top]["samples_per_s"]
               / result["legacy"][top]["samples_per_s"])

    def monotone(pipeline):
        bw = [result[pipeline][str(t)]["bytes_per_s"] for t in thread_counts]
        return all(b2 >= b1 * 0.95 for b1, b2 in zip(bw, bw[1:]))

    # fig4/fig5 trend preservation is a per-file-pipeline property: with
    # n_images files, threads monotonically hide per-file seeks.  The
    # sharded engine has only n_images/images_per_shard streams and is
    # near-saturated from 1 thread — its curve is reported, not gated.
    mono = {p: monotone(p) for p in result}
    derived = (f"sharded-vs-legacy speedup @{top}T = {speedup:.2f}x "
               f"(target >=2x); bandwidth monotone in threads: "
               f"legacy={mono['legacy']} vectorized={mono['vectorized']} "
               f"sharded(saturated)={mono['sharded']}")
    emit(name, rows, derived)

    payload = {
        "benchmark": name,
        "tier": tier,
        "config": {
            "n_images": n_images, "images_per_shard": images_per_shard,
            "mean_hw": list(mean_hw), "out_hw": list(out_hw),
            "batch_size": batch_size, "time_scale": TIME_SCALE,
            "thread_counts": list(thread_counts), "repeats": repeats,
        },
        "pipelines": result,
        "speedup_sharded_vs_legacy": round(speedup, 3),
        "bandwidth_monotone": mono,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_json = json_path or os.path.join(RESULTS_DIR, "BENCH_pipeline.json")
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_json}")
    return payload


def run_smoke() -> dict:
    """Tiny-scale CI variant: same shape of output, seconds of runtime."""
    return run(n_images=32, images_per_shard=8, mean_hw=(48, 48),
               out_hw=(16, 16), thread_counts=(1, 2), batch_size=8,
               repeats=1)


if __name__ == "__main__":
    payload = run_smoke() if "--smoke" in sys.argv else run()
    ok = payload["speedup_sharded_vs_legacy"] >= (
        1.2 if "--smoke" in sys.argv else 2.0)
    print(f"# speedup={payload['speedup_sharded_vs_legacy']}x ok={ok}")
    if not ok:
        sys.exit(1)
