"""Roofline terms from a compiled dry-run artifact (no real hardware).

Per (arch x shape x mesh) cell we derive three times-per-step:

    compute    = HLO_FLOPs_per_device / peak_FLOPs            (197 TF bf16)
    memory     = HLO_bytes_per_device / HBM_bw                (819 GB/s)
    collective = collective_bytes_per_device / link_bw        (~50 GB/s ICI)

``cost_analysis()`` already reports per-device FLOPs/bytes on a partitioned
module.  Collective bytes are parsed from ``compiled.as_text()`` (post-SPMD
HLO): for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute we take the per-device buffer size and apply the standard
ring factors.  Groups that span pods are classified as DCN traffic and
reported separately (the 'pod' axis crosses the data-center network).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# TPU v5e-class constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
DCN_BW = 6.25e9              # B/s / chip across pods (assumed, reported only)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(?P<var>%\S+)\s*=\s*(?P<shape>\(?[a-z0-9]+\[[^\]]*\][^ ]*\)?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_RG_EXPLICIT_RE = re.compile(r"replica_groups=\{\{(.*?)\}\}")
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[(?P<dims>[\d,]+)\]<=\[(?P<reshape>[\d,]+)\]"
    r"(?:T\((?P<perm>[\d,]+)\))?"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (sums tuple elements)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_groups(line: str) -> Optional[np.ndarray]:
    """Replica groups as an array (num_groups, group_size), or None."""
    m = _RG_IOTA_RE.search(line)
    if m:
        dims = [int(x) for x in m.group("dims").split(",")]
        reshape = [int(x) for x in m.group("reshape").split(",")]
        ids = np.arange(int(np.prod(reshape)))
        if len(reshape) > 1:
            ids = ids.reshape(reshape)
            if m.group("perm"):
                perm = [int(x) for x in m.group("perm").split(",")]
                ids = ids.transpose(perm)
        return ids.reshape(dims)
    m = _RG_EXPLICIT_RE.search(line)
    if m:
        groups = [
            [int(x) for x in g.split(",") if x.strip()]
            for g in m.group(1).split("},{")
        ]
        return np.asarray(groups)
    return None


@dataclass
class CollectiveStats:
    op: str
    count: int = 0
    bytes_moved: int = 0     # per-device, ring-factor applied
    dcn_bytes: int = 0


@dataclass
class RooflineReport:
    arch: str = ""
    shape: str = ""
    mesh: str = ""
    chips: int = 256
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collective_bytes: float = 0.0       # ICI per device
    dcn_collective_bytes: float = 0.0   # DCN per device
    collectives: Dict[str, Dict] = field(default_factory=dict)
    model_flops: float = 0.0            # 6*N*D (or 6*N_active*D)
    memory_per_device: Optional[Dict] = None

    # -- the three terms (seconds per step) --------------------------------
    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW + self.dcn_collective_bytes / DCN_BW

    @property
    def bottleneck(self) -> str:
        terms = dict(compute=self.t_compute, memory=self.t_memory,
                     collective=self.t_collective)
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """max of the three terms (perfect overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): <1 means pad/redundant work,
        >1 means e.g. remat did NOT inflate HLO (HLO counts the backward)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline-implied step time."""
        t = self.step_time_lower_bound
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def to_dict(self) -> Dict:
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh, chips=self.chips,
            flops_per_device=self.flops_per_device,
            bytes_per_device=self.bytes_per_device,
            collective_bytes=self.collective_bytes,
            dcn_collective_bytes=self.dcn_collective_bytes,
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, bottleneck=self.bottleneck,
            model_flops=self.model_flops,
            useful_flops_ratio=self.useful_flops_ratio,
            mfu=self.mfu,
            collectives=self.collectives,
            memory_per_device=self.memory_per_device,
        )


def parse_collectives(hlo_text: str, devices_per_pod: int) -> Dict[str, CollectiveStats]:
    """Scan post-SPMD HLO for collectives; returns stats per op kind.

    Bytes are per-participating-device with ring factors:
      all-gather:      out * (g-1)/g
      reduce-scatter:  in  * (g-1)/g ≈ out * (g-1)
      all-reduce:      buf * 2(g-1)/g
      all-to-all:      buf * (g-1)/g
      collective-permute: buf
    """
    stats: Dict[str, CollectiveStats] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if f"{op}-done" in line:
            continue
        nbytes = _shape_bytes(m.group("shape"))
        groups = _parse_groups(line)
        g = int(groups.shape[-1]) if groups is not None else 1
        if op == "all-gather":
            moved = nbytes * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            moved = nbytes * (g - 1)           # nbytes is the (small) output
        elif op == "all-reduce":
            moved = nbytes * 2 * (g - 1) / max(g, 1)
        elif op == "all-to-all":
            moved = nbytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            moved = nbytes
        is_dcn = False
        if groups is not None and devices_per_pod > 0:
            pods = groups // devices_per_pod
            is_dcn = bool((pods != pods[..., :1]).any())
        s = stats.setdefault(op, CollectiveStats(op))
        s.count += 1
        if is_dcn:
            s.dcn_bytes += int(moved)
        else:
            s.bytes_moved += int(moved)
    return stats


def analyze_compiled(
    compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
    devices_per_pod: int, model_flops: float,
) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        model_flops=model_flops,
    )
    stats = parse_collectives(compiled.as_text(), devices_per_pod)
    rep.collective_bytes = float(sum(s.bytes_moved for s in stats.values()))
    rep.dcn_collective_bytes = float(sum(s.dcn_bytes for s in stats.values()))
    rep.collectives = {
        k: dict(count=v.count, ici_bytes=v.bytes_moved, dcn_bytes=v.dcn_bytes)
        for k, v in stats.items()
    }
    try:
        ma = compiled.memory_analysis()
        rep.memory_per_device = dict(
            argument=int(ma.argument_size_in_bytes),
            output=int(ma.output_size_in_bytes),
            temp=int(ma.temp_size_in_bytes),
            peak_estimate=int(ma.argument_size_in_bytes + ma.temp_size_in_bytes),
        )
    except Exception:
        rep.memory_per_device = None
    return rep


def model_flops_for(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS per step: 6*N*D train, 2*N*D forward-only (N=active)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
