"""repro.trace subsystem: collector, nesting, fast path, export, report."""
import json
import threading
import time
import tracemalloc

import pytest

from repro import trace
from repro.trace import report as trace_report
from repro.trace.tracer import NULL_SPAN, SpanRecord, Tracer


@pytest.fixture(autouse=True)
def _no_global_tracer():
    """Each test starts and ends with tracing uninstalled."""
    trace.set_tracer(None)
    yield
    trace.set_tracer(None)


def mkspan(stage, t0, dur, tid=1, nbytes=0, name=""):
    return SpanRecord(stage=stage, name=name, tid=tid, thread=f"t{tid}",
                      t0=t0, dur=dur, nbytes=nbytes)


# ---------------------------------------------------------------------------
# collector
# ---------------------------------------------------------------------------
class TestTracer:
    def test_span_records_stage_bytes_duration(self):
        tr = Tracer()
        with tr.span("storage_read", "f.bin") as sp:
            sp.set_bytes(123)
        (r,) = tr.spans()
        assert r.stage == "storage_read"
        assert r.name == "f.bin"
        assert r.nbytes == 123
        assert r.dur >= 0.0
        assert r.tid == threading.get_ident()

    def test_nesting_across_threads(self):
        """Each thread's inner span must lie inside its own outer span, and
        spans must carry the recording thread's id."""
        tr = Tracer()

        def work(i):
            with tr.span("outer", f"outer-{i}"):
                time.sleep(0.002)
                with tr.span("inner", f"inner-{i}"):
                    time.sleep(0.002)
                time.sleep(0.002)

        threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        spans = tr.spans()
        assert len(spans) == 8
        by_tid = {}
        for r in spans:
            by_tid.setdefault(r.tid, {})[r.stage] = r
        assert len(by_tid) == 4
        for tid, pair in by_tid.items():
            outer, inner = pair["outer"], pair["inner"]
            # proper containment: inner starts after and ends before outer
            assert outer.t0 <= inner.t0
            assert inner.t0 + inner.dur <= outer.t0 + outer.dur + 1e-9
            assert outer.name.split("-")[1] == inner.name.split("-")[1]

    def test_reset_clears_all_threads(self):
        tr = Tracer()
        with tr.span("a"):
            pass
        t = threading.Thread(target=lambda: tr.span("b").__enter__().__exit__(None, None, None))
        t.start()
        t.join()
        assert len(tr.spans()) == 2
        tr.reset()
        assert tr.spans() == []
        assert tr.counters() == []

    def test_counters(self):
        tr = Tracer()
        tr.count("depth", 1)
        tr.count("depth", 3)
        vals = [c.value for c in tr.counters()]
        assert vals == [1.0, 3.0]

    def test_module_level_span_routes_to_global(self):
        tr = trace.start()
        with trace.span("x", "y", 7):
            pass
        trace.count("c", 2)
        trace.stop()
        assert len(tr.spans()) == 1
        assert tr.spans()[0].nbytes == 7
        assert len(tr.counters()) == 1
        # after stop() the hot path is null again
        assert trace.span("x") is NULL_SPAN


class TestDisabledFastPath:
    def test_null_singleton(self):
        assert trace.get_tracer() is None
        assert trace.span("storage_read", "p") is NULL_SPAN
        # disabled tracer (installed but off) also short-circuits
        t = Tracer(enabled=False)
        trace.set_tracer(t)
        assert trace.span("storage_read", "p") is NULL_SPAN
        assert t.span("storage_read") is NULL_SPAN
        assert t.spans() == []

    def test_no_allocations_per_op_when_disabled(self):
        """The disabled path must not allocate: 10k span enters/exits leave
        no per-op garbage behind (shared singleton, no kwargs)."""
        def burn(n):
            for _ in range(n):
                with trace.span("storage_read", "path"):
                    pass
                trace.count("gauge", 1.0)
                trace.instant("storage_read", "path", 10)

        burn(100)  # warm up interned ints etc.
        tracemalloc.start()
        burn(10_000)
        _current, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        # a per-op allocation of even one 56-byte object would show ~560 KB
        assert peak < 16_384, f"disabled tracing allocated {peak} bytes"


# ---------------------------------------------------------------------------
# percentiles / aggregation / overlap
# ---------------------------------------------------------------------------
class TestPercentile:
    def test_empty_series(self):
        assert trace.percentile([], 50) == 0.0
        assert trace.percentile([], 99) == 0.0

    def test_singleton_series(self):
        for q in (0, 50, 95, 99, 100):
            assert trace.percentile([4.5], q) == 4.5

    def test_interpolation(self):
        xs = [0.0, 10.0]
        assert trace.percentile(xs, 50) == 5.0
        assert trace.percentile(list(range(101)), 95) == 95.0

    def test_bad_q(self):
        with pytest.raises(ValueError):
            trace.percentile([1.0], 101)
        with pytest.raises(ValueError):
            trace.percentile([1.0], -1)

    def test_unsorted_input(self):
        assert trace.percentile([9.0, 1.0, 5.0], 50) == 5.0


class TestAggregate:
    def test_per_stage_rollup(self):
        spans = [
            mkspan("read", 0.0, 0.010, nbytes=100),
            mkspan("read", 0.1, 0.030, nbytes=300),
            mkspan("write", 0.2, 0.050, nbytes=1000),
        ]
        stats = trace.aggregate(spans)
        assert stats["read"].ops == 2
        assert stats["read"].bytes == 400
        assert stats["read"].p50_ms == pytest.approx(20.0)
        assert stats["write"].ops == 1
        assert stats["write"].p99_ms == pytest.approx(50.0)
        # sorted by descending total time
        assert list(stats) == ["write", "read"]

    def test_empty(self):
        assert trace.aggregate([]) == {}


class TestOverlap:
    def test_partial_overlap(self):
        spans = [
            mkspan("compute", 0.0, 1.0, tid=1),
            mkspan("decode", 0.2, 0.3, tid=2),
            mkspan("prefetch", 0.6, 0.2, tid=2),
        ]
        ov = trace.overlap_ratio(spans)
        assert ov == pytest.approx(0.5)  # 0.3 + 0.2 of 1.0s compute

    def test_no_compute(self):
        assert trace.overlap_ratio([mkspan("decode", 0, 1)]) == 0.0

    def test_disjoint(self):
        spans = [
            mkspan("compute", 0.0, 1.0),
            mkspan("decode", 2.0, 1.0),
        ]
        assert trace.overlap_ratio(spans) == 0.0

    def test_union_merges_concurrent_bg(self):
        # two overlapping decodes on different threads must not double count
        spans = [
            mkspan("compute", 0.0, 1.0, tid=1),
            mkspan("decode", 0.0, 0.6, tid=2),
            mkspan("decode", 0.3, 0.4, tid=3),
        ]
        assert trace.overlap_ratio(spans) == pytest.approx(0.7)

    def test_storage_read_not_in_default_bg(self):
        """Checkpoint/drain reads must not masquerade as input-pipeline
        activity: a bare storage_read overlapping compute contributes 0."""
        spans = [
            mkspan("compute", 0.0, 1.0, tid=1),
            mkspan("storage_read", 0.0, 1.0, tid=2),  # e.g. a drain read
        ]
        assert trace.overlap_ratio(spans) == 0.0
        # but explicit bg selection still works
        assert trace.overlap_ratio(
            spans, bg_stages=("storage_read",)) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------
class TestChromeExport:
    def test_schema(self):
        tr = Tracer()
        with tr.span("storage_read", "f.bin") as sp:
            sp.set_bytes(64)
        tr.count("depth", 2)
        obj = trace.to_chrome_trace(tr.spans(), tr.counters(),
                                    process_name="p")
        assert set(obj) == {"traceEvents", "displayTimeUnit"}
        phases = {e["ph"] for e in obj["traceEvents"]}
        assert {"M", "X", "C"} <= phases
        x = next(e for e in obj["traceEvents"] if e["ph"] == "X")
        assert x["cat"] == "storage_read"
        assert x["name"] == "f.bin"
        assert x["args"]["bytes"] == 64
        assert x["ts"] >= 0 and x["dur"] >= 0  # microseconds
        json.dumps(obj)  # must be serializable

    def test_round_trip(self):
        spans = [
            mkspan("storage_read", 0.5, 0.25, tid=11, nbytes=4096, name="a"),
            mkspan("decode", 0.75, 0.1, tid=12, nbytes=0, name="load"),
            SpanRecord(stage="compute", name="step", tid=11, thread="t11",
                       t0=1.0, dur=0.5, nbytes=0, args={"step": 3}),
        ]
        counters = [trace.CounterRecord("depth", 0.6, 2.0, 11)]
        blob = json.dumps(trace.to_chrome_trace(spans, counters))
        back_spans, back_counters = trace.from_chrome_trace(blob)
        assert len(back_spans) == len(spans)
        for a, b in zip(sorted(spans, key=lambda r: r.t0), back_spans):
            assert b.stage == a.stage
            assert b.name == a.name
            assert b.tid == a.tid
            assert b.thread == a.thread
            assert b.t0 == pytest.approx(a.t0)
            assert b.dur == pytest.approx(a.dur)
            assert b.nbytes == a.nbytes
        assert back_spans[-1].args == {"step": 3}
        (c,) = back_counters
        assert (c.name, c.value) == ("depth", 2.0)
        assert c.t == pytest.approx(0.6)

    def test_dump_to_file(self, tmp_path):
        tr = Tracer()
        with tr.span("storage_write", "x"):
            pass
        path = tmp_path / "trace.json"
        trace.dump_chrome_trace(tr, str(path))
        loaded_spans, _ = trace.from_chrome_trace(path.read_text())
        assert loaded_spans[0].stage == "storage_write"


# ---------------------------------------------------------------------------
# markdown report
# ---------------------------------------------------------------------------
class TestMarkdown:
    def test_empty(self):
        md = trace.to_markdown([])
        assert "no spans" in md

    def test_stages_and_overlap_present(self):
        spans = [
            mkspan("compute", 0.0, 1.0, tid=1),
            mkspan("storage_read", 0.2, 0.5, tid=2, nbytes=2_000_000),
        ]
        md = trace.to_markdown(spans, title="T")
        assert "# T" in md
        assert "storage_read" in md
        assert "overlap ratio" in md
        assert "2.00" in md  # MB column


# ---------------------------------------------------------------------------
# end-to-end: instrumented core layers
# ---------------------------------------------------------------------------
class TestInstrumentation:
    def test_storage_pipeline_checkpoint_spans(self, tmp_storage):
        import numpy as np

        from repro.core import Dataset
        from repro.core.checkpoint import CheckpointSaver

        tr = trace.start()
        try:
            tmp_storage.write_file("a.bin", b"z" * 2048)
            loaded = (
                Dataset.from_tensor_slices(["a.bin"])
                .map(tmp_storage.read_file, num_parallel_calls=2)
                .prefetch(1)
                .as_numpy()
            )
            assert len(loaded[0]) == 2048
            saver = CheckpointSaver(tmp_storage, "ckpt/m", sync=False)
            saver.save(1, {"w": np.zeros(8, np.float32)})
            saver.restore_pytree({"w": np.zeros(8, np.float32)})
        finally:
            trace.stop()
        stages = {r.stage for r in tr.spans()}
        assert trace.STAGE_STORAGE_READ in stages
        assert trace.STAGE_STORAGE_WRITE in stages
        assert trace.STAGE_DECODE in stages
        assert trace.STAGE_PREFETCH in stages
        assert trace.STAGE_CKPT_WRITE in stages
        assert trace.STAGE_CKPT_RESTORE in stages
        # read bytes attributed
        reads = [r for r in tr.spans() if r.stage == trace.STAGE_STORAGE_READ]
        assert any(r.nbytes == 2048 for r in reads)
        # prefetch buffer gauge sampled
        assert any(c.name == "prefetch_buffer" for c in tr.counters())

    def test_burst_buffer_drain_span(self, fast_slow_storage):
        import numpy as np

        from repro.core.burst_buffer import BurstBufferCheckpointer

        fast, slow = fast_slow_storage
        tr = trace.start()
        try:
            bb = BurstBufferCheckpointer(fast, slow, "ckpt/m", sync=False)
            bb.save(1, {"w": np.ones(256, np.float32)})
            bb.wait()
            bb.close()
        finally:
            trace.stop()
        drains = [r for r in tr.spans() if r.stage == trace.STAGE_DRAIN]
        assert len(drains) == 1
        assert drains[0].nbytes > 0
        assert "drain:ckpt/m-1" in drains[0].name

    def test_untraced_by_default(self, tmp_storage):
        tmp_storage.write_file("b.bin", b"q")
        tmp_storage.read_file("b.bin")  # no global tracer: must not raise
        assert trace.get_tracer() is None
