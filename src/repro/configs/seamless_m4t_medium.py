"""seamless-m4t-medium — enc-dec multimodal (speech/text) backbone.
[arXiv:2308.11596; hf] 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.
The speech frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, T_src, d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,            # decoder layers
    enc_layers=12,          # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    modality_stub=True,
    modality_seq=1024,      # stub speech-frame sequence fed to the encoder
    source="arXiv:2308.11596; hf",
)
