"""dstat-like I/O activity tracing (paper §IV-B, Fig. 8/10).

The paper traces disk activity with ``dstat`` at 1 Hz and plots MB read/written
per second.  :class:`IOTracer` reproduces that: every byte moved through a
:class:`repro.core.storage.Storage` is recorded into per-interval buckets and
can be dumped as a dstat-style CSV timeline.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class _Bucket:
    read_bytes: int = 0
    write_bytes: int = 0
    read_ops: int = 0
    write_ops: int = 0


class IOTracer:
    """Thread-safe per-interval I/O byte counter (dstat analogue)."""

    def __init__(self, interval_s: float = 1.0):
        self.interval_s = float(interval_s)
        self._lock = threading.Lock()
        self._buckets: Dict[int, _Bucket] = {}
        self._t0 = time.monotonic()
        self.events: List[tuple] = []  # (t, kind, nbytes, tag) raw log
        self.keep_events = False

    def reset(self) -> None:
        with self._lock:
            self._buckets.clear()
            self.events.clear()
            self._t0 = time.monotonic()

    def record(self, kind: str, nbytes: int, tag: str = "") -> None:
        t = time.monotonic() - self._t0
        idx = int(t / self.interval_s)
        with self._lock:
            b = self._buckets.setdefault(idx, _Bucket())
            if kind == "read":
                b.read_bytes += nbytes
                b.read_ops += 1
            else:
                b.write_bytes += nbytes
                b.write_ops += 1
            if self.keep_events:
                self.events.append((t, kind, nbytes, tag))

    # -- reporting ---------------------------------------------------------
    def timeline(self) -> List[dict]:
        """Dense per-interval rows from t=0 to the last active interval."""
        with self._lock:
            if not self._buckets:
                return []
            last = max(self._buckets)
            rows = []
            for i in range(last + 1):
                b = self._buckets.get(i, _Bucket())
                rows.append(
                    dict(
                        t=i * self.interval_s,
                        read_mb=b.read_bytes / 1e6,
                        write_mb=b.write_bytes / 1e6,
                        read_ops=b.read_ops,
                        write_ops=b.write_ops,
                    )
                )
            return rows

    def totals(self) -> dict:
        with self._lock:
            return dict(
                read_bytes=sum(b.read_bytes for b in self._buckets.values()),
                write_bytes=sum(b.write_bytes for b in self._buckets.values()),
                read_ops=sum(b.read_ops for b in self._buckets.values()),
                write_ops=sum(b.write_ops for b in self._buckets.values()),
            )

    def to_csv(self) -> str:
        rows = self.timeline()
        out = ["t_s,read_mb_s,write_mb_s,read_ops,write_ops"]
        for r in rows:
            out.append(
                f"{r['t']:.1f},{r['read_mb']:.3f},{r['write_mb']:.3f},"
                f"{r['read_ops']},{r['write_ops']}"
            )
        return "\n".join(out)


@dataclass
class StepTimer:
    """Per-step wall-clock decomposition used by the trainer's straggler
    monitor: how long each step spent waiting on data vs. computing."""

    data_wait_s: List[float] = field(default_factory=list)
    compute_s: List[float] = field(default_factory=list)
    checkpoint_s: List[float] = field(default_factory=list)

    def summary(self) -> dict:
        import numpy as np

        def stat(xs):
            if not xs:
                return dict(mean=0.0, p50=0.0, p95=0.0, max=0.0, total=0.0)
            a = np.asarray(xs)
            return dict(
                mean=float(a.mean()),
                p50=float(np.percentile(a, 50)),
                p95=float(np.percentile(a, 95)),
                max=float(a.max()),
                total=float(a.sum()),
            )

        return dict(
            data_wait=stat(self.data_wait_s),
            compute=stat(self.compute_s),
            checkpoint=stat(self.checkpoint_s),
        )
