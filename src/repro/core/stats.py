"""dstat-like I/O activity tracing (paper §IV-B, Fig. 8/10).

The paper traces disk activity with ``dstat`` at 1 Hz and plots MB read/written
per second.  :class:`IOTracer` reproduces that view as an adapter over the
fine-grained :mod:`repro.trace` machinery: the per-interval buckets are
folded incrementally (bounded memory, like dstat itself), and setting
``keep_events`` additionally lands every ``record()`` as an instant event in
a private :class:`repro.trace.Tracer` — exposing the raw per-op log to the
span/export tooling.  Callers that want per-operation spans everywhere
should use :mod:`repro.trace` directly.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from .. import trace as _trace

_KIND_STAGE = {
    "read": _trace.STAGE_STORAGE_READ,
    "write": _trace.STAGE_STORAGE_WRITE,
}
_STAGE_KIND = {v: k for k, v in _KIND_STAGE.items()}


@dataclass
class _Bucket:
    read_bytes: int = 0
    write_bytes: int = 0
    read_ops: int = 0
    write_ops: int = 0


class IOTracer:
    """Thread-safe per-interval I/O byte counter (dstat analogue).

    Buckets are folded incrementally in ``record()`` so memory stays
    O(run length / interval), independent of op count.  With
    ``keep_events`` set, each op is also recorded as an instant event in
    the private :class:`repro.trace.Tracer` exposed as :attr:`collector`
    (per-op log for export/report tooling — unbounded, hence opt-in).
    """

    def __init__(self, interval_s: float = 1.0):
        self.interval_s = float(interval_s)
        self.keep_events = False
        self._lock = threading.Lock()
        self._buckets: Dict[int, _Bucket] = {}
        self._collector = _trace.Tracer(enabled=True)
        self._t0 = time.monotonic()

    @property
    def collector(self) -> "_trace.Tracer":
        """Raw per-op span collector (populated when ``keep_events``)."""
        return self._collector

    def reset(self) -> None:
        with self._lock:
            self._buckets.clear()
            self._t0 = time.monotonic()
        self._collector.reset()

    def record(self, kind: str, nbytes: int, tag: str = "") -> None:
        stage = _KIND_STAGE.get(kind)
        if stage is None:
            raise ValueError(
                f"unknown I/O kind {kind!r}; expected 'read' or 'write'"
            )
        t = time.monotonic() - self._t0
        idx = int(t / self.interval_s)
        with self._lock:
            b = self._buckets.setdefault(idx, _Bucket())
            if kind == "read":
                b.read_bytes += nbytes
                b.read_ops += 1
            else:
                b.write_bytes += nbytes
                b.write_ops += 1
        if self.keep_events:
            self._collector.instant(stage, tag, nbytes, t=t)

    # -- raw log (API compat: populated only when keep_events is set) -------
    @property
    def events(self) -> List[tuple]:
        """(t, kind, nbytes, tag) rows, empty unless ``keep_events``."""
        return [
            (r.t0, _STAGE_KIND.get(r.stage, r.stage), r.nbytes, r.name)
            for r in self._collector.spans()
        ]

    # -- reporting ---------------------------------------------------------
    def timeline(self) -> List[dict]:
        """Dense per-interval rows from t=0 to the last active interval."""
        with self._lock:
            if not self._buckets:
                return []
            last = max(self._buckets)
            rows = []
            for i in range(last + 1):
                b = self._buckets.get(i, _Bucket())
                rows.append(
                    dict(
                        t=i * self.interval_s,
                        read_mb=b.read_bytes / 1e6,
                        write_mb=b.write_bytes / 1e6,
                        read_ops=b.read_ops,
                        write_ops=b.write_ops,
                    )
                )
            return rows

    def totals(self) -> dict:
        with self._lock:
            return dict(
                read_bytes=sum(b.read_bytes for b in self._buckets.values()),
                write_bytes=sum(b.write_bytes for b in self._buckets.values()),
                read_ops=sum(b.read_ops for b in self._buckets.values()),
                write_ops=sum(b.write_ops for b in self._buckets.values()),
            )

    def to_csv(self) -> str:
        rows = self.timeline()
        out = ["t_s,read_mb_s,write_mb_s,read_ops,write_ops"]
        for r in rows:
            out.append(
                f"{r['t']:.1f},{r['read_mb']:.3f},{r['write_mb']:.3f},"
                f"{r['read_ops']},{r['write_ops']}"
            )
        return "\n".join(out)


@dataclass
class StepTimer:
    """Per-step wall-clock decomposition used by the trainer's straggler
    monitor: how long each step spent waiting on data vs. computing."""

    data_wait_s: List[float] = field(default_factory=list)
    compute_s: List[float] = field(default_factory=list)
    checkpoint_s: List[float] = field(default_factory=list)

    def summary(self) -> dict:
        import numpy as np

        def stat(xs):
            if not xs:
                return dict(mean=0.0, p50=0.0, p95=0.0, max=0.0, total=0.0)
            a = np.asarray(xs)
            return dict(
                mean=float(a.mean()),
                p50=float(np.percentile(a, 50)),
                p95=float(np.percentile(a, 95)),
                max=float(a.max()),
                total=float(a.sum()),
            )

        return dict(
            data_wait=stat(self.data_wait_s),
            compute=stat(self.compute_s),
            checkpoint=stat(self.checkpoint_s),
        )
