"""Fig. 7 analogue: mini-app training throughput vs batch size (8 threads)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.dataset import image_pipeline
from repro.models import alexnet as A

from .common import BenchEnv, emit
from .fig6_prefetch import ACFG, make_train_step


def run() -> None:
    env = BenchEnv(tiers=("ssd",), n_images=192, mean_hw=(48, 48))
    st = env.storages["ssd"]
    paths, labels = env.corpora["ssd"]
    step = make_train_step()
    params = A.init_params(jax.random.PRNGKey(0), ACFG)
    rows = []
    n_images = 96
    for batch in (8, 16, 32, 64):
        for pf in (0, 1):
            ds = image_pipeline(
                st, paths, labels, batch_size=batch, num_parallel_calls=8,
                prefetch=pf, out_hw=(ACFG.in_hw, ACFG.in_hw), repeat=True)
            it = iter(ds)
            imgs, lbls = next(it)
            params, _ = step(params, jnp.asarray(imgs), jnp.asarray(lbls))
            t0 = time.monotonic()
            for _ in range(n_images // batch):
                imgs, lbls = next(it)
                p, loss = step(params, jnp.asarray(imgs), jnp.asarray(lbls))
                loss.block_until_ready()
            t = time.monotonic() - t0
            rows.append(f"batch={batch},prefetch={pf},runtime_s={t:.2f},"
                        f"img_s={n_images / t:.1f}")
    emit("fig7_batchsize", rows,
         "paper: runtime decreases with batch size (better accel utilization)")
    env.close()


if __name__ == "__main__":
    run()
