"""Async snapshot checkpointing: training blocks for the snapshot only.

The paper's burst buffer (§III-C, Fig. 9/10) hides the *slow-tier* cost of a
checkpoint behind a fast tier, but training still blocks for the full
fast-tier write.  Its prefetcher result (§IV: complete compute/input overlap)
points at the stronger play, which this module implements for the write path:

1. **Snapshot** (blocking, :func:`repro.core.checkpoint.flatten_pytree` with
   ``copy=True``): the pytree is materialized in host memory — device arrays
   via ``jax.device_get``, numpy leaves by copy.  This is memory-bandwidth
   bound (GB/s), not storage-bound (MB/s), so the training thread resumes
   after milliseconds.
2. **Write** (background): a dedicated writer thread runs the normal
   sharded, atomic :meth:`CheckpointSaver.save_flat` — with the N data
   shards themselves written concurrently on the saver's ``io_threads``
   pool (the write-side analogue of the paper's 2.3x/7.8x read
   thread-scaling).

``save()`` returns an :class:`AsyncSaveHandle` (future-like: ``done()`` /
``result()`` / ``exception()``).  The commit protocol is unchanged — data,
index and meta land before the ``checkpoint`` marker — so a crash at any
point leaves the previous checkpoint restorable (see ``tests/test_faults.py``
for the fault-injected proof).

``max_pending`` bounds host-memory use: a ``save()`` issued while that many
snapshots are still being written blocks until a slot frees (the blocked
time is honestly recorded in ``blocked_s``).

Every phase is trace-attributed (``STAGE_CKPT_SNAPSHOT`` on the training
thread, ``STAGE_CKPT_WRITE`` on the writer thread), so a
:mod:`repro.trace` report shows checkpoint writes overlapping compute —
see ``benchmarks/fig10_async_ckpt.py``.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from typing import Any, Callable, List, Optional

from .. import metrics, trace
from .checkpoint import CheckpointSaver, PreemptionReport, SaveResult, \
    flatten_pytree


class AsyncSaveHandle:
    """Future-like handle for one in-flight checkpoint save.

    Error bookkeeping distinguishes two degrees of "the caller knows":

    * *observed* — the caller saw the error through :meth:`result` or
      :meth:`exception`.  ``close()`` then stays quiet, but a draining
      ``wait()`` still raises it (wait's contract: surface every failed
      save it drains, exactly once).
    * *reported* — ``wait()``/``close()`` raised it.  Nothing re-raises it
      afterwards.

    So one failure is raised by at most one drain call and never silently
    dropped: an error nobody observed is re-raised by ``close()``.
    """

    def __init__(self, step: int, future, snapshot_s: float,
                 metrics_flag: bool = False):
        self.step = step
        self.snapshot_s = snapshot_s
        self._future = future
        self._observed = False   # seen via result()/exception()
        self._reported = False   # raised by wait()/close()
        # save-time metrics.enabled() flag: a preempt() that cancels this
        # handle decrements the pending_saves gauge iff it was incremented
        self._metrics_flag = metrics_flag

    def done(self) -> bool:
        return self._future.done()

    def cancelled(self) -> bool:
        """True if a ``preempt()`` cancelled this save before it touched
        storage (the snapshot was abandoned — nothing landed, no error)."""
        return self._future.cancelled()

    def result(self, timeout: Optional[float] = None) -> SaveResult:
        """Block until the background write commits; re-raises its error."""
        try:
            return self._future.result(timeout)
        except BaseException:
            self._observed = True
            raise

    def exception(self, timeout: Optional[float] = None):
        e = self._future.exception(timeout)
        if e is not None:
            self._observed = True
        return e

    def _unreported_error(self):
        """Settled-with-error and never seen by anyone (no blocking, no
        marking) — what ``close()`` must surface."""
        if not self._future.done() or self._reported or self._observed \
                or self._future.cancelled():
            return None
        return self._future.exception()

    def _drain_error(self):
        """Blocking: the error ``wait()`` owes the caller (not yet raised
        by a drain call), marking it reported."""
        if self._future.cancelled():  # abandoned by preempt(): no error owed
            return None
        e = self._future.exception()
        if e is None or self._reported:
            return None
        self._reported = True
        return e

    def __repr__(self) -> str:  # pragma: no cover
        state = "done" if self.done() else "pending"
        return f"AsyncSaveHandle(step={self.step}, {state})"


def _any_error_delivered(handles) -> bool:
    """True if some failed save in ``handles`` was already seen by the
    caller (observed via the handle, or raised by a drain call)."""
    return any(
        (h._observed or h._reported)
        and h._future.done() and not h._future.cancelled()
        and h._future.exception() is not None
        for h in handles
    )


def _cancel_and_promote(handles, sema, prefix: str,
                        deadline_s: Optional[float], t0: float):
    """Shared preemption core for the async engines: cancel every queued
    (not-yet-started) save except the newest, then wait for the newest to
    commit within what remains of the deadline.

    Returns ``(abandoned_steps, deadline_met)``.  A successfully cancelled
    save never ran its writer, so its backpressure slot and pending-saves
    gauge entry are released here (symmetric with the save-time acquire).
    The newest save is *promoted*: it gets the whole remaining budget; on
    timeout it is reported abandoned but left running — if it settles after
    the process survives anyway, the step is durable as normal."""
    abandoned: List[int] = []
    live = [h for h in handles if not h.done()]
    newest = live[-1] if live else None
    for h in live[:-1]:
        if h._future.cancel():
            abandoned.append(h.step)
            sema.release()
            if h._metrics_flag:
                metrics.add_gauge("ckpt.pending_saves", -1, ckpt=prefix)
    deadline_met = True
    if newest is not None:
        remaining = None
        if deadline_s is not None:
            remaining = max(0.0, deadline_s - (time.monotonic() - t0))
        try:
            e = newest._future.exception(remaining)
        except FutureTimeout:
            abandoned.append(newest.step)
            deadline_met = False
        else:
            if e is not None:
                # failed, not slow: the step is not durable.  The error
                # itself still surfaces through the handle/wait()/close()
                # contract — preempt() only records the abandonment.
                abandoned.append(newest.step)
    return sorted(abandoned), deadline_met


class AsyncCheckpointer:
    """Checkpointer whose ``save()`` blocks only for the host snapshot.

    Same construction surface as :class:`DirectCheckpointer` plus
    ``io_threads`` (shard-write parallelism) and ``max_pending``
    (host-memory backpressure).  ``save()`` returns an
    :class:`AsyncSaveHandle`; call :meth:`wait` to drain and surface any
    background write error.
    """

    def __init__(
        self,
        storage,
        prefix: str = "ckpt/model",
        *,
        keep: int = 5,
        n_shards: int = 1,
        sync: bool = True,
        quantize=None,
        io_threads: Optional[int] = None,
        max_pending: int = 2,
    ):
        self.saver = CheckpointSaver(
            storage, prefix, keep=keep, n_shards=n_shards, sync=sync,
            quantize=quantize, io_threads=io_threads,
        )
        self.prefix = prefix
        self.blocked_s: List[float] = []
        self._handles: List[AsyncSaveHandle] = []
        self._preempted = False
        #: Lifecycle hook (used by the fused CheckpointManager): called with
        #: the step number on the writer thread after the step committed.
        self.on_committed: Optional[Callable[[int], None]] = None
        self._sema = threading.BoundedSemaphore(max(1, max_pending))
        # One writer thread: checkpoints commit in submission order, so the
        # marker's `latest` is always the newest fully-landed step.
        self._executor: Optional[ThreadPoolExecutor] = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="ckpt-writer"
        )

    # -- producer (training thread) -----------------------------------------
    def save(self, step: int, tree: Any,
             extra_meta: Optional[dict] = None) -> AsyncSaveHandle:
        if self._executor is None:
            raise RuntimeError("AsyncCheckpointer is closed")
        if self._preempted:
            raise RuntimeError("save() on a preempted AsyncCheckpointer")
        m = metrics.enabled()
        t0 = time.monotonic()
        self._sema.acquire()  # backpressure: at most max_pending snapshots
        try:
            t_snap = time.monotonic()
            with trace.span(trace.STAGE_CKPT_SNAPSHOT,
                            f"snapshot:{self.prefix}-{step}") as sp:
                flat, treedef = flatten_pytree(tree, copy=True)
                sp.set_bytes(sum(a.nbytes for a in flat.values()))
            if m:
                metrics.observe("ckpt.snapshot_s",
                                time.monotonic() - t_snap, ckpt=self.prefix)
            fut = self._executor.submit(self._write, step, flat, extra_meta,
                                        treedef, m)
            if m:
                metrics.add_gauge("ckpt.pending_saves", 1, ckpt=self.prefix)
        except BaseException:
            self._sema.release()
            raise
        blocked = time.monotonic() - t0
        self.blocked_s.append(blocked)
        if m:
            metrics.observe("ckpt.blocked_s", blocked, ckpt=self.prefix)
        handle = AsyncSaveHandle(step, fut, blocked, metrics_flag=m)
        # keep only unsettled and failed-but-not-yet-drain-reported handles:
        # the list must not grow with run length
        self._handles = [
            h for h in self._handles
            if not h.done()
            or (not h._future.cancelled() and not h._reported
                and h._future.exception() is not None)
        ]
        self._handles.append(handle)
        return handle

    # -- writer thread -------------------------------------------------------
    def _write(self, step: int, flat, extra_meta, treedef,
               m: bool) -> SaveResult:
        t0 = time.monotonic()
        try:
            res = self.saver.save_flat(step, flat, extra_meta, treedef=treedef)
            if metrics.enabled():
                metrics.observe("ckpt.write_s", time.monotonic() - t0,
                                ckpt=self.prefix)
                metrics.inc("ckpt.saves", 1, ckpt=self.prefix)
            if self.on_committed is not None:
                # commit hook: the fused manager runs deferred retention/GC
                # here, on the (single) writer thread, after the marker moved
                self.on_committed(step)
            return res
        finally:
            self._sema.release()
            if m:  # symmetric with the save-time increment: the gauge must
                   # never go negative when metrics toggles mid-run
                metrics.add_gauge("ckpt.pending_saves", -1, ckpt=self.prefix)

    # -- consumer-side API ----------------------------------------------------
    def wait(self) -> None:
        """Block until every issued save has committed; raise the first
        background error (interface parity with the burst buffer).  Settled
        handles are dropped — an error is reported once, not re-raised by
        every later ``wait()``."""
        handles, self._handles = self._handles, []
        errors = []
        for h in handles:
            e = h._drain_error()  # blocks until this save settles
            if e is not None:
                errors.append(e)
        if errors:
            raise errors[0]

    def pending(self) -> int:
        return sum(1 for h in self._handles if not h.done())

    def preempt(self, deadline_s: Optional[float] = None) -> PreemptionReport:
        """Graceful shutdown within a budget: stop accepting saves, cancel
        queued-but-unstarted writes except the newest, and wait up to
        ``deadline_s`` (``None`` = forever) for that newest write to
        commit.  Returns what was promoted vs abandoned."""
        t0 = time.monotonic()
        self._preempted = True
        abandoned, met = _cancel_and_promote(
            list(self._handles), self._sema, self.prefix, deadline_s, t0)
        return PreemptionReport(self.latest_step(), abandoned, deadline_s,
                                time.monotonic() - t0, met)

    def close(self, wait: bool = True) -> None:
        """Shut the writer down; surface (not silently drop) a background
        error that nobody ever saw.

        If any failure was already delivered (a handle's ``result()`` /
        ``exception()``, or a ``wait()`` raise), close stays quiet: with a
        sticky device fault every in-flight save fails the same way, and
        re-raising the tail of that cascade at teardown helps no one.  Only
        the never-delivered case is raised here."""
        if self._executor is not None:
            self._executor.shutdown(wait=wait)
            self._executor = None
        handles, self._handles = self._handles, []
        if _any_error_delivered(handles):
            return
        errors = [e for e in (h._unreported_error() for h in handles)
                  if e is not None]
        if errors:
            raise errors[0]

    # -- restore / introspection (delegate to the saver) ----------------------
    def restore_pytree(self, skeleton: Any, step: Optional[int] = None) -> Any:
        return self.saver.restore_pytree(skeleton, step)

    def restore_sharded(self, skeleton, shardings, step=None):
        return self.saver.restore_sharded(skeleton, shardings, step)

    def latest_step(self) -> Optional[int]:
        return self.saver.latest_step()
