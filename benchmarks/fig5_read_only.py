"""Fig. 5 analogue: pipeline with ONLY tf.read() (no decode/resize) —
isolates preprocessing cost from raw I/O.  The read-only loader is shared
by both pipeline generations (the vectorized engine only changes decode/
batch), so one sweep covers both."""
from __future__ import annotations

from . import fig4_threads


def run() -> None:
    fig4_threads.run(preprocess=False, name="fig5_read_only")


if __name__ == "__main__":
    run()
