"""AlexNet — the paper's mini-application network (§III-B).
5 conv + 3 maxpool + 3 FC, ReLU; 224x224x3 inputs, 102 classes
(Caltech-101 + background). ``SMOKE`` is the CPU-sized variant used in
tests and the quick benchmarks."""
from dataclasses import dataclass


@dataclass(frozen=True)
class AlexNetConfig:
    name: str = "alexnet"
    in_hw: int = 224
    channels: int = 3
    n_classes: int = 102
    filters: tuple = (64, 192, 384, 256, 256)
    fc: tuple = (4096, 4096)
    lr: float = 1e-4


CONFIG = AlexNetConfig()
SMOKE = AlexNetConfig(name="alexnet-smoke", in_hw=64, filters=(16, 32, 48, 32, 32), fc=(256, 256))
