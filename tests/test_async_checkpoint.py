"""Async snapshot checkpointing: blocked time, overlap, ordering, trainer.

Acceptance criteria covered here:

* ``AsyncCheckpointer.save()`` blocking time ≈ snapshot time only — on
  simulated hdd the training-thread blocked seconds are ≤ 20% of
  ``DirectCheckpointer``'s;
* parallel shard write/restore with ``n_shards=4`` beats serial on a
  simulated tier (the token-bucket model: per-stream bandwidth < aggregate);
* checkpoint-write spans overlap compute spans in the trace.
"""
import os
import tempfile
import time

import numpy as np
import pytest

from repro import trace
from repro.core.async_checkpoint import AsyncCheckpointer, AsyncSaveHandle
from repro.core.burst_buffer import DirectCheckpointer
from repro.core.checkpoint import CheckpointSaver
from repro.core.storage import SimulatedStorage, TIERS

SCRATCH = "/dev/shm" if os.path.isdir("/dev/shm") else None


def state(mb=4, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(mb * 1024 * 256,)).astype(np.float32),
        "step": np.int32(seed),
    }


def layered_state(n_layers=4, mb_each=2, seed=0):
    """n_layers equal-size tensors: tensors are assigned to shards whole, so
    shard-level parallelism only shows with several comparable leaves."""
    rng = np.random.default_rng(seed)
    return {
        f"layer{i}": rng.normal(size=(mb_each * 1024 * 256,)).astype(np.float32)
        for i in range(n_layers)
    }


@pytest.fixture()
def hdd_pair():
    """Two independent simulated hdd tiers (direct vs async must not share
    a token bucket)."""
    with tempfile.TemporaryDirectory(dir=SCRATCH) as d1, \
            tempfile.TemporaryDirectory(dir=SCRATCH) as d2:
        yield (SimulatedStorage(d1, TIERS["hdd"], time_scale=2.0),
               SimulatedStorage(d2, TIERS["hdd"], time_scale=2.0))


class TestAsyncBasics:
    def test_roundtrip_and_handle(self, tmp_storage):
        t = state(1)
        ac = AsyncCheckpointer(tmp_storage, "ckpt/m", n_shards=3)
        h = ac.save(7, t)
        assert isinstance(h, AsyncSaveHandle) and h.step == 7
        r = h.result()
        assert r.step == 7 and r.n_bytes > 0
        assert h.done() and h.exception() is None
        out = ac.restore_pytree(t)
        np.testing.assert_array_equal(out["w"], t["w"])
        assert ac.latest_step() == 7
        ac.wait()
        ac.close()

    def test_saves_commit_in_order(self, tmp_storage):
        t = state(1)
        ac = AsyncCheckpointer(tmp_storage, "ckpt/m", keep=10)
        handles = [ac.save(s, t) for s in (1, 2, 3, 4)]
        ac.wait()
        assert ac.latest_step() == 4
        assert ac.saver.all_steps() == [1, 2, 3, 4]
        assert all(h.done() for h in handles)
        ac.close()

    def test_snapshot_isolates_mutation(self, tmp_storage):
        """The background writer must see the values at save() time, not
        later in-place mutations (numpy leaves are copied)."""
        t = state(1)
        before = t["w"].copy()
        ac = AsyncCheckpointer(tmp_storage, "ckpt/m")
        h = ac.save(1, t)
        t["w"] += 1.0  # training "continues" and mutates in place
        h.result()
        out = ac.restore_pytree(t)
        np.testing.assert_array_equal(out["w"], before)
        ac.close()

    def test_closed_checkpointer_rejects_saves(self, tmp_storage):
        ac = AsyncCheckpointer(tmp_storage, "ckpt/m")
        ac.close()
        with pytest.raises(RuntimeError):
            ac.save(1, state(1))


class TestBlockedTime:
    def test_async_blocks_le_20pct_of_direct_on_hdd(self, hdd_pair):
        """The acceptance criterion: blocked ≈ snapshot, not the hdd write."""
        direct_st, async_st = hdd_pair
        t = state(4)
        direct = DirectCheckpointer(direct_st, "d/m")
        direct.save(1, t)

        ac = AsyncCheckpointer(async_st, "a/m")
        ac.save(1, t)
        ac.wait()
        ac.close()
        assert ac.blocked_s[0] <= 0.2 * direct.blocked_s[0], (
            f"async blocked {ac.blocked_s[0]:.3f}s vs "
            f"direct {direct.blocked_s[0]:.3f}s")

    def test_write_overlaps_compute_in_trace(self, hdd_pair):
        _, async_st = hdd_pair
        t = state(4)
        tracer = trace.start()
        try:
            ac = AsyncCheckpointer(async_st, "a/m")
            ac.save(1, t)
            # training continues while the writer drains to "hdd"
            deadline = time.monotonic() + 2.0
            while ac.pending() and time.monotonic() < deadline:
                with trace.span(trace.STAGE_COMPUTE, "train_step"):
                    time.sleep(0.01)
            ac.wait()
            ac.close()
        finally:
            trace.stop()
        spans = tracer.spans()
        stages = {s.stage for s in spans}
        assert trace.STAGE_CKPT_SNAPSHOT in stages
        assert trace.STAGE_CKPT_WRITE in stages
        ov = trace.overlap_ratio(
            spans, fg_stages=(trace.STAGE_CKPT_WRITE,),
            bg_stages=(trace.STAGE_COMPUTE,))
        assert ov > 0.5, f"checkpoint write barely overlaps compute: {ov:.2%}"


class TestParallelShardIO:
    """Parallel shard I/O beats serial under the token-bucket model.

    On the simulated lustre tier a single stream gets 135 MB/s (write) /
    260 MB/s (read) while the aggregate allows 991 / 1968 MB/s — so 4
    concurrent shard streams must finish measurably faster than 4 serial
    ones (the write-side analogue of the paper's Fig. 4/5 scaling).
    """

    @pytest.fixture()
    def lustre(self):
        with tempfile.TemporaryDirectory(dir=SCRATCH) as d:
            yield SimulatedStorage(d, TIERS["lustre"], time_scale=4.0)

    def test_parallel_shard_write_beats_serial(self, lustre):
        t = layered_state(4, 2)
        serial = CheckpointSaver(lustre, "ser/m", n_shards=4, io_threads=1)
        parallel = CheckpointSaver(lustre, "par/m", n_shards=4, io_threads=4)
        t0 = time.monotonic()
        serial.save(1, t)
        serial_s = time.monotonic() - t0
        t0 = time.monotonic()
        parallel.save(1, t)
        parallel_s = time.monotonic() - t0
        assert parallel_s < serial_s * 0.75, (
            f"parallel {parallel_s:.3f}s !< serial {serial_s:.3f}s * 0.75")

    def test_parallel_shard_restore_beats_serial(self, lustre):
        t = layered_state(4, 2)
        CheckpointSaver(lustre, "ckpt/m", n_shards=4).save(1, t)
        serial = CheckpointSaver(lustre, "ckpt/m", n_shards=4, io_threads=1)
        parallel = CheckpointSaver(lustre, "ckpt/m", n_shards=4, io_threads=4)
        t0 = time.monotonic()
        serial.restore_pytree(t)
        serial_s = time.monotonic() - t0
        t0 = time.monotonic()
        out = parallel.restore_pytree(t)
        parallel_s = time.monotonic() - t0
        np.testing.assert_array_equal(out["layer0"], t["layer0"])
        assert parallel_s < serial_s * 0.75, (
            f"parallel {parallel_s:.3f}s !< serial {serial_s:.3f}s * 0.75")


class TestTrainerIntegration:
    def _trainer(self, checkpointer, n=6):
        from repro.train.trainer import Trainer

        def train_step(st, batch):
            return {**st, "step": st["step"] + 1}, {"loss": 0.0}

        data = iter([np.zeros(2, np.float32)] * 64)
        return Trainer(
            train_step, {"w": np.ones(1024, np.float32), "step": np.int32(0)},
            data, checkpointer=checkpointer, ckpt_every=2, resume=False,
        )

    def test_step_loop_never_blocks_past_snapshot(self, hdd_pair):
        _, async_st = hdd_pair
        ac = AsyncCheckpointer(async_st, "ckpt/m")
        tr = self._trainer(ac)
        tr.run(5)
        # saves happened (steps 2 and 4) but the loop only paid snapshot time
        assert len(ac.blocked_s) == 2
        assert all(b < 0.05 for b in tr.timer.checkpoint_s), (
            tr.timer.checkpoint_s)
        tr.wait_for_checkpoints()
        assert tr.report()["pending_async_saves"] == 0
        assert ac.latest_step() == 4
        ac.close()

    def test_preemption_save_is_durable(self, tmp_storage):
        ac = AsyncCheckpointer(tmp_storage, "ckpt/m")
        tr = self._trainer(ac)
        tr.run(2)
        tr.request_stop()
        tr.run(3)  # stops at the first boundary, blocking on the final save
        assert ac.latest_step() == tr.step
        ac.close()

    def test_background_error_reraised_at_step_boundary(self, tmp_storage):
        from repro.core.faults import FaultInjected, FaultyStorage

        faulty = FaultyStorage(tmp_storage)
        ac = AsyncCheckpointer(faulty, "ckpt/m")
        tr = self._trainer(ac)
        faulty.fail_after(0)
        with pytest.raises(FaultInjected):
            tr.run(20)  # save at step 2 fails in background; next save reaps
        ac.close()
