"""Fault injection for storage: crash/fail mid-I/O, deterministically.

The checkpoint stack *documents* atomicity ("a crash mid-save leaves the
previous checkpoint restorable") — this module is how the test suite
*proves* it.  :class:`FaultyStorage` wraps any :class:`Storage` and injects
failures at exact, reproducible points:

* ``fail_after(k)`` — the (k+1)-th matching operation (and, because a
  failed device stays failed, every one after it) raises
  :class:`FaultInjected`.  ``k=0`` fails the first op.
* ``fail_on(substring)`` — ops whose path contains ``substring`` fail
  (e.g. arm on ``"checkpoint"`` to kill exactly the commit-marker write).
* ``torn_write(frac, n_ops=k)`` — the tripping write is **torn**: a
  ``frac`` prefix of its buffer really lands on the inner storage before
  the device dies.  Clean op-boundary crashes (the two modes above) never
  leave a half-written file; real power loss does — this mode proves the
  commit protocol tolerates partially-landed data *and* partially-landed
  markers (which is why markers must move by atomic rename, not rewrite).
* ``transient(n_ops=k, rate=p, on=substring)`` — **non-sticky** faults: the
  device is flaky, not dead.  The next ``k`` matching ops fail (then the
  device works again), and/or each matching op fails independently with
  probability ``p`` (seeded, reproducible).  The op raises *before* any
  bytes move, so a retry is always safe.  This is the model
  :class:`repro.core.retry.RetryingStorage` exists to absorb.
* ``hang(n_ops=k | on=substring, duration=t | forever)`` — the matching op
  **blocks** instead of failing: the calling thread stalls inside the
  device for ``duration`` seconds (``None`` = forever, until
  :meth:`release_hung` / :meth:`heal`), then the op proceeds normally and
  its bytes land.  This is the stuck-op model (a slow-tier write wedged in
  the kernel / network stack) that drain watchdogs must detect: unlike
  every mode above, nothing raises — the op just never returns.  One-shot
  by default (``repeat=True`` hangs every matching op while armed);
  ``hung_ops`` counts trips and ``hung_now`` is the number of threads
  currently stalled.
* ``reordered_fsync()`` — the device acknowledges writes into a volatile
  cache and is free to persist them out of order: only a ``sync=True``
  write (or ``fsync_dir``) is a durability **barrier** that flushes
  everything issued before it.  :meth:`crash` then simulates power loss —
  un-barriered writes are rolled back, except (``keep="last"``) the most
  recently issued one, which happened to hit the medium first.  This is
  the model under which an unsynced commit marker can become durable
  *before* the data it commits — the classic torn protocol a clean
  op-boundary crash can never exhibit.

``ops`` selects which operation kinds count/trip ("write" covers
``write_file``/``append_file``, "read" covers ``read_file``/``read_range``;
metadata ops are never failed — a crashed *device* is modelled by sticky
write+read failure, not by breaking ``exists``/``listdir`` which restore
paths legitimately probe).  The injected exception is raised *before* the
inner operation runs, so a tripped write leaves the target file untouched —
exactly a process killed between syscalls.

Example — prove a save killed mid-write keeps the previous step::

    faulty = FaultyStorage(storage)
    saver = CheckpointSaver(faulty, "ckpt/m")
    saver.save(1, tree)
    faulty.fail_after(1)                    # 2nd write of the next save dies
    with pytest.raises(FaultInjected):
        saver.save(2, tree)
    faulty.heal()
    assert saver.latest_step() == 1         # marker never moved
"""
from __future__ import annotations

import random
import threading
from typing import Dict, List, Optional, Sequence

from .. import metrics
from .storage import Storage


class FaultInjected(OSError):
    """The error :class:`FaultyStorage` raises at its trigger point."""


class TransientFault(FaultInjected):
    """A non-sticky injected error: the op failed but the device is alive.

    Raised before the inner op runs (no bytes moved), so retrying the same
    call is always safe — the contract ``RetryingStorage`` relies on."""


_WRITE_OPS = ("write_file", "append_file", "write_range")
_READ_OPS = ("read_file", "read_range")


class FaultyStorage(Storage):
    """Transparent :class:`Storage` wrapper with arm-able failure points."""

    def __init__(self, inner: Storage, *, sticky: bool = True):
        self.inner = inner
        self.name = f"faulty({getattr(inner, 'name', '?')})"
        self.sticky = sticky
        self._lock = threading.Lock()
        self._fail_after: Optional[int] = None
        self._fail_substring: Optional[str] = None
        self._torn_frac: Optional[float] = None
        self._ops: Sequence[str] = _WRITE_OPS
        self._count = 0
        self._tripped = False
        self.op_log: List[tuple] = []  # (op, path, nbytes) of every attempt
        # transient (non-sticky) fault state
        self._transient_left = 0
        self._transient_rate = 0.0
        self._transient_on: Optional[str] = None
        self._transient_ops: Sequence[str] = ()
        self._transient_rng = random.Random(0)
        self.transients_injected = 0
        # stuck-op (hang) fault state
        self._hang_armed = False
        self._hang_after = 0
        self._hang_on: Optional[str] = None
        self._hang_ops: Sequence[str] = ()
        self._hang_count = 0
        self._hang_duration: Optional[float] = None
        self._hang_repeat = False
        self._hang_release = threading.Event()
        self.hung_ops = 0   # total ops that tripped a hang
        self.hung_now = 0   # threads currently stalled inside the device
        # reordered-fsync journaling: volatile (un-barriered) writes since
        # the last sync=True write / fsync_dir, with pre-images for rollback
        self._journal_mode = False
        self._journal: List[str] = []           # issue order of volatile writes
        self._pre_state: Dict[str, Optional[bytes]] = {}  # path -> pre-image

    # -- arming ---------------------------------------------------------------
    def fail_after(self, n_ops: int, ops: Sequence[str] = ("write",)) -> "FaultyStorage":
        """Let ``n_ops`` matching ops through, then fail."""
        with self._lock:
            self._fail_after = int(n_ops)
            self._ops = self._expand(ops)
            self._count = 0
            self._tripped = False
        return self

    def fail_on(self, substring: str, ops: Sequence[str] = ("write",)) -> "FaultyStorage":
        """Fail matching ops whose path contains ``substring``."""
        with self._lock:
            self._fail_substring = substring
            self._ops = self._expand(ops)
            self._tripped = False
        return self

    def torn_write(self, frac: float, n_ops: int = 0,
                   ops: Sequence[str] = ("write",),
                   on: Optional[str] = None) -> "FaultyStorage":
        """Arm a torn write: after ``n_ops`` matching ops — or, with
        ``on=substring``, at the first write whose path matches — the write
        lands only a ``frac`` prefix of its buffer on the inner storage,
        then the device dies (sticky clean failure afterwards)."""
        if not 0.0 <= frac < 1.0:
            raise ValueError(f"torn fraction must be in [0, 1), got {frac}")
        with self._lock:
            self._torn_frac = float(frac)
            if on is not None:
                self._fail_substring = on
                self._fail_after = None
            else:
                self._fail_after = int(n_ops)
            self._ops = self._expand(ops)
            self._count = 0
            self._tripped = False
        return self

    def transient(self, n_ops: int = 0, rate: float = 0.0,
                  on: Optional[str] = None, ops: Sequence[str] = ("read",),
                  seed: int = 0) -> "FaultyStorage":
        """Arm **non-sticky** transient faults (a flaky device, not a dead
        one): the next ``n_ops`` matching ops fail and then the device works
        again, and/or each matching op fails independently with probability
        ``rate`` (seeded, so a given run is reproducible).  ``on=substring``
        restricts faults to ops whose path matches.  The fault fires before
        the inner op runs, so no bytes land and a retry of the same call is
        safe."""
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"transient rate must be in [0, 1], got {rate}")
        with self._lock:
            self._transient_left = int(n_ops)
            self._transient_rate = float(rate)
            self._transient_on = on
            self._transient_ops = self._expand(ops)
            self._transient_rng = random.Random(seed)
        return self

    def hang(self, n_ops: int = 0, on: Optional[str] = None,
             ops: Sequence[str] = ("write",),
             duration: Optional[float] = None,
             repeat: bool = False) -> "FaultyStorage":
        """Arm a **stuck op**: after ``n_ops`` matching ops — or, with
        ``on=substring``, at the first matching op whose path contains the
        substring — the op blocks for ``duration`` seconds (``None`` =
        forever, until :meth:`release_hung` or :meth:`heal`), then proceeds
        normally (the bytes land; the device was wedged, not dead).  The
        hang is one-shot unless ``repeat=True``."""
        if duration is not None and duration < 0:
            raise ValueError(f"hang duration must be >= 0, got {duration}")
        with self._lock:
            self._hang_armed = True
            self._hang_after = int(n_ops)
            self._hang_on = on
            self._hang_ops = self._expand(ops)
            self._hang_count = 0
            self._hang_duration = duration
            self._hang_repeat = bool(repeat)
            self._hang_release = threading.Event()
        return self

    def release_hung(self) -> "FaultyStorage":
        """Un-wedge: every thread currently stalled in a hung op resumes
        (and completes its op).  The arming itself is untouched — pair with
        :meth:`heal` to also disarm."""
        with self._lock:
            self._hang_release.set()
        return self

    def reordered_fsync(self) -> "FaultyStorage":
        """Arm the volatile-cache durability model: un-barriered writes are
        journaled (with pre-images) and survive only until :meth:`crash`;
        a ``sync=True`` write or ``fsync_dir`` is a barrier that makes
        everything issued before it durable."""
        with self._lock:
            self._journal_mode = True
            self._journal = []
            self._pre_state = {}
        return self

    def crash(self, keep: str = "last") -> List[str]:
        """Simulate power loss under ``reordered_fsync``: roll back volatile
        writes to their pre-images.  ``keep="last"`` spares the most
        recently issued volatile write (durability reordering: the newest
        cache line hit the medium first — exactly the adversary an unsynced
        commit marker loses to); ``keep="none"`` drops them all.  Returns
        the rolled-back paths; the journal restarts (device rebooted)."""
        with self._lock:
            if not self._journal_mode:
                raise RuntimeError("crash() requires reordered_fsync() armed")
            journal, pre = self._journal, self._pre_state
            self._journal, self._pre_state = [], {}
        survivors = {journal[-1]} if (keep == "last" and journal) else set()
        lost: List[str] = []
        for path, before in pre.items():
            if path in survivors:
                continue
            if before is None:
                self.inner.remove(path)
            else:
                self.inner.write_file(path, before)
            lost.append(path)
        return lost

    def heal(self) -> "FaultyStorage":
        """Disarm: the device works again (tests assert recovery after)."""
        with self._lock:
            self._fail_after = None
            self._fail_substring = None
            self._torn_frac = None
            self._count = 0
            self._tripped = False
            self._transient_left = 0
            self._transient_rate = 0.0
            self._transient_on = None
            self._transient_ops = ()
            self._hang_armed = False
            self._hang_release.set()  # un-wedge any thread still stalled
        return self

    @staticmethod
    def _expand(ops: Sequence[str]) -> Sequence[str]:
        out: List[str] = []
        for o in ops:
            if o == "write":
                out.extend(_WRITE_OPS)
            elif o == "read":
                out.extend(_READ_OPS)
            else:
                out.append(o)
        return tuple(out)

    # -- trigger --------------------------------------------------------------
    def _check(self, op: str, path: str, nbytes: int = 0) -> Optional[float]:
        """Count the op; raise on a clean trip.  Returns the torn fraction
        when the trip should land a partial buffer first (the caller does
        the prefix write, then raises) — ``None`` means proceed normally."""
        self._maybe_hang(op, path)
        with self._lock:
            self.op_log.append((op, path, nbytes))
            # transient (non-sticky) faults first: a flaky device, checked
            # independently of the sticky arming below
            if op in self._transient_ops and (
                    self._transient_on is None or self._transient_on in path):
                trip = False
                if self._transient_left > 0:
                    self._transient_left -= 1
                    trip = True
                elif (self._transient_rate > 0.0
                      and self._transient_rng.random() < self._transient_rate):
                    trip = True
                if trip:
                    self.transients_injected += 1
                    metrics.inc("storage.faults_injected", 1, op=op)
                    raise TransientFault(
                        f"injected transient fault on {op}({path!r})")
            if op not in self._ops:
                return None
            if self._tripped and self.sticky:
                metrics.inc("storage.faults_injected", 1, op=op)
                raise FaultInjected(f"injected fault (sticky) on {op}({path!r})")
            if self._fail_substring is not None and self._fail_substring in path:
                self._tripped = True
                metrics.inc("storage.faults_injected", 1, op=op)
                if self._torn_frac is not None and op in _WRITE_OPS:
                    return self._torn_frac
                raise FaultInjected(
                    f"injected fault on {op}({path!r}) matching "
                    f"{self._fail_substring!r}")
            if self._fail_after is not None:
                if self._count >= self._fail_after:
                    self._tripped = True
                    metrics.inc("storage.faults_injected", 1, op=op)
                    if self._torn_frac is not None and op in _WRITE_OPS:
                        return self._torn_frac
                    raise FaultInjected(
                        f"injected fault on {op}({path!r}) after "
                        f"{self._count} ops")
                self._count += 1
            return None

    def _maybe_hang(self, op: str, path: str) -> None:
        """Stall the calling thread if the armed hang matches this op.

        The decision is taken under the lock; the wait itself must not hold
        it (other threads keep doing I/O while one is wedged)."""
        with self._lock:
            if not self._hang_armed or op not in self._hang_ops:
                return
            if self._hang_on is not None:
                if self._hang_on not in path:
                    return
            elif self._hang_count < self._hang_after:
                self._hang_count += 1
                return
            if not self._hang_repeat:
                self._hang_armed = False
            self.hung_ops += 1
            self.hung_now += 1
            release = self._hang_release
            duration = self._hang_duration
        metrics.inc("storage.hangs_injected", 1, op=op)
        try:
            release.wait(timeout=duration)
        finally:
            with self._lock:
                self.hung_now -= 1

    # -- reordered-fsync journaling -------------------------------------------
    def _pre_write(self, path: str, sync: bool) -> None:
        """Capture the pre-image of a volatile write (before it applies)."""
        with self._lock:
            if not self._journal_mode or sync or path in self._pre_state:
                return
        # read outside the lock; a pre-image raced by another first-touch
        # write of the same path is the same bytes either way
        before = self.inner.read_file(path) if self.inner.exists(path) else None
        with self._lock:
            if self._journal_mode and path not in self._pre_state:
                self._pre_state[path] = before

    def _post_write(self, path: str, sync: bool) -> None:
        """Journal a volatile write; a sync write is a barrier that makes
        everything issued before it durable (syncfs semantics — the model
        the checkpoint protocol's §III-C fsync discipline assumes)."""
        with self._lock:
            if not self._journal_mode:
                return
            if sync:
                self._journal = []
                self._pre_state = {}
            else:
                self._journal.append(path)

    # -- delegated I/O ---------------------------------------------------------
    def read_file(self, path: str) -> bytes:
        self._check("read_file", path)
        return self.inner.read_file(path)

    def read_range(self, path: str, offset: int, length: int) -> bytes:
        self._check("read_range", path, length)
        return self.inner.read_range(path, offset, length)

    def _tear(self, op: str, path: str, n_landed: int, n_total: int) -> None:
        raise FaultInjected(
            f"torn {op}({path!r}): {n_landed}/{n_total} bytes landed, "
            "then the device died")

    def write_file(self, path: str, data: bytes, sync: bool = False) -> None:
        frac = self._check("write_file", path, len(data))
        if frac is not None:
            n = int(len(data) * frac)
            self._pre_write(path, False)
            self.inner.write_file(path, bytes(data)[:n], sync=False)
            self._post_write(path, False)
            self._tear("write_file", path, n, len(data))
        self._pre_write(path, sync)
        self.inner.write_file(path, data, sync=sync)
        self._post_write(path, sync)

    def append_file(self, path: str, data: bytes, sync: bool = False) -> None:
        frac = self._check("append_file", path, len(data))
        if frac is not None:
            n = int(len(data) * frac)
            self._pre_write(path, False)
            self.inner.append_file(path, bytes(data)[:n], sync=False)
            self._post_write(path, False)
            self._tear("append_file", path, n, len(data))
        self._pre_write(path, sync)
        self.inner.append_file(path, data, sync=sync)
        self._post_write(path, sync)

    def write_range(self, path: str, offset: int, data: bytes,
                    sync: bool = False) -> None:
        frac = self._check("write_range", path, len(data))
        if frac is not None:
            n = int(len(data) * frac)
            self._pre_write(path, False)
            self.inner.write_range(path, offset, bytes(data)[:n], sync=False)
            self._post_write(path, False)
            self._tear("write_range", path, n, len(data))
        self._pre_write(path, sync)
        self.inner.write_range(path, offset, data, sync=sync)
        self._post_write(path, sync)

    def fsync_dir(self, path: str) -> None:
        self.inner.fsync_dir(path)
        with self._lock:  # syncfs barrier: everything issued is now durable
            if self._journal_mode:
                self._journal = []
                self._pre_state = {}

    # -- delegated namespace (never failed) ------------------------------------
    def listdir(self, path: str) -> List[str]:
        return self.inner.listdir(path)

    def exists(self, path: str) -> bool:
        return self.inner.exists(path)

    def makedirs(self, path: str) -> None:
        self.inner.makedirs(path)

    def remove(self, path: str) -> None:
        self.inner.remove(path)

    def rename(self, src: str, dst: str) -> None:
        # rename is metadata (never failed), but renaming a *volatile* file
        # must not launder its volatility: dst inherits it, rolling back to
        # dst's own pre-image on crash (the old marker, for the tmp+rename
        # commit idiom).
        with self._lock:
            volatile = self._journal_mode and src in self._pre_state
        before = None
        if volatile and self.inner.exists(dst):
            before = self.inner.read_file(dst)
        self.inner.rename(src, dst)
        if volatile:
            with self._lock:
                if self._journal_mode and src in self._pre_state:
                    self._pre_state.pop(src)
                    self._pre_state.setdefault(dst, before)
                    self._journal = [dst if p == src else p
                                     for p in self._journal]

    def size(self, path: str) -> int:
        return self.inner.size(path)

    def drop_caches(self) -> None:
        self.inner.drop_caches()
