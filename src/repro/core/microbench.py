"""STREAM-like TensorFlow-I/O micro-benchmark (paper §III-A, Fig. 4/5).

Measures raw ingestion bandwidth of the input pipeline: read files from a
storage tier, optionally decode+resize, batch, and pull batches through the
iterator as fast as possible (no compute phase).  Reports images/s and MB/s
as the paper does, under a strong-scaling sweep of reader threads.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from . import records
from .dataset import Dataset


@dataclass
class MicrobenchResult:
    storage: str
    threads: int
    preprocess: bool
    n_images: int
    total_bytes: int
    seconds: float

    @property
    def images_per_s(self) -> float:
        return self.n_images / self.seconds

    @property
    def mb_per_s(self) -> float:
        return self.total_bytes / 1e6 / self.seconds

    def row(self) -> str:
        return (
            f"{self.storage},{self.threads},{int(self.preprocess)},"
            f"{self.n_images},{self.images_per_s:.2f},{self.mb_per_s:.2f}"
        )


def run_microbench(
    storage,
    paths: Sequence[str],
    *,
    threads: int = 1,
    batch_size: int = 64,
    preprocess: bool = True,
    out_hw: tuple = (64, 64),
    seed: int = 0,
    n_batches: Optional[int] = None,
) -> MicrobenchResult:
    """One micro-benchmark run: consume the corpus through the pipeline."""
    sizes = {}

    def load(path):
        blob = storage.read_file(path)  # tf.read_file()
        sizes[path] = len(blob)
        if not preprocess:
            return np.int64(len(blob))  # read-only pipeline (paper Fig. 5)
        payload = records.decode_single_record(blob)
        return records.preprocess_image(payload, *out_hw)

    ds = (
        Dataset.from_tensor_slices(list(paths))
        .shuffle(len(paths), seed=seed)
        .map(load, num_parallel_calls=threads)
        .ignore_errors()
        .batch(batch_size, drop_remainder=True)
    )

    n_images = 0
    t0 = time.monotonic()
    it = iter(ds)
    consumed_batches = 0
    for batch in it:
        first = batch[0] if isinstance(batch, tuple) else batch
        n_images += len(first)
        consumed_batches += 1
        if n_batches is not None and consumed_batches >= n_batches:
            break
    seconds = time.monotonic() - t0

    return MicrobenchResult(
        storage=getattr(storage, "name", "?"),
        threads=threads,
        preprocess=preprocess,
        n_images=n_images,
        total_bytes=sum(sizes.values()),
        seconds=seconds,
    )


def thread_scaling_sweep(
    storage,
    paths: Sequence[str],
    *,
    thread_counts: Sequence[int] = (1, 2, 4, 8),
    repeats: int = 3,
    warmup: bool = True,
    **kw,
) -> List[MicrobenchResult]:
    """Paper's strong-scaling protocol: warm-up run discarded, median kept."""
    out: List[MicrobenchResult] = []
    for t in thread_counts:
        runs = []
        n = repeats + (1 if warmup else 0)
        for i in range(n):
            r = run_microbench(storage, paths, threads=t, **kw)
            if warmup and i == 0:
                continue
            runs.append(r)
        runs.sort(key=lambda r: r.seconds)
        out.append(runs[len(runs) // 2])
    return out
