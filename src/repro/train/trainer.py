"""Training loop: input pipeline + checkpointing + fault tolerance.

Integrates the paper's pieces end-to-end:

* data comes through the :mod:`repro.core.dataset` pipeline (parallel map +
  prefetch) and optionally :func:`prefetch_to_device`;
* checkpoints go through a Direct- or BurstBuffer-checkpointer every
  ``ckpt_every`` steps (the paper's protocol: §IV-C);
* **restart**: on construction the trainer restores the newest checkpoint
  if one exists (crash/preemption recovery);
* **preemption**: SIGTERM triggers checkpoint-and-stop at the next step
  boundary;
* **straggler monitor**: per-step data-wait vs compute-time is recorded
  (paper Fig. 6: when prefetch works, data-wait ≈ 0); a sustained data-wait
  fraction above ``straggler_threshold`` is surfaced in ``report()``.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import numpy as np

from .. import trace
from ..core.stats import StepTimer


class Trainer:
    def __init__(
        self,
        train_step: Callable,                  # (state, batch) -> (state, metrics)
        state: Dict[str, Any],
        data_iter: Iterable,
        *,
        checkpointer=None,                     # Direct/BurstBuffer checkpointer
        ckpt_every: int = 0,
        resume: bool = True,
        straggler_threshold: float = 0.2,
        install_sigterm: bool = False,
        on_step: Optional[Callable[[int, Dict], None]] = None,
    ):
        self.train_step = train_step
        self.state = state
        self.data_iter = iter(data_iter)
        self.checkpointer = checkpointer
        self.ckpt_every = ckpt_every
        self.timer = StepTimer()
        self.straggler_threshold = straggler_threshold
        self.on_step = on_step
        self.history: List[Dict] = []
        self._stop_requested = False
        if install_sigterm:
            signal.signal(signal.SIGTERM, self._handle_sigterm)
        if resume and checkpointer is not None:
            latest = checkpointer.latest_step()
            if latest is not None:
                self.state = checkpointer.restore_pytree(self.state)
                # step counter lives in the state itself

    def _handle_sigterm(self, signum, frame):  # pragma: no cover
        self._stop_requested = True

    def request_stop(self) -> None:
        """Graceful-preemption hook (same path as SIGTERM)."""
        self._stop_requested = True

    @property
    def step(self) -> int:
        return int(jax.device_get(self.state["step"]))

    def run(self, n_steps: int) -> List[Dict]:
        for _ in range(n_steps):
            t0 = time.monotonic()
            with trace.span(trace.STAGE_DATA_WAIT, "next_batch"):
                try:
                    batch = next(self.data_iter)
                except StopIteration:
                    break
            t1 = time.monotonic()
            with trace.span(trace.STAGE_COMPUTE, "train_step"):
                self.state, metrics = self.train_step(self.state, batch)
                metrics = {k: float(jax.device_get(v)) for k, v in metrics.items()}
            t2 = time.monotonic()
            self.timer.data_wait_s.append(t1 - t0)
            self.timer.compute_s.append(t2 - t1)
            step = self.step
            metrics["step"] = step
            self.history.append(metrics)
            if self.on_step:
                self.on_step(step, metrics)

            if self.checkpointer is not None and self.ckpt_every and (
                step % self.ckpt_every == 0
            ):
                t3 = time.monotonic()
                self.checkpointer.save(step, self.state)
                self.timer.checkpoint_s.append(time.monotonic() - t3)

            if self._stop_requested:
                if self.checkpointer is not None:
                    t3 = time.monotonic()
                    self.checkpointer.save(step, self.state)
                    self.timer.checkpoint_s.append(time.monotonic() - t3)
                break
        return self.history

    # -- diagnostics ---------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        s = self.timer.summary()
        compute = max(s["compute"]["total"], 1e-9)
        data_frac = s["data_wait"]["total"] / (s["data_wait"]["total"] + compute)
        return dict(
            steps=len(self.timer.compute_s),
            data_wait_frac=data_frac,
            straggler_suspect=data_frac > self.straggler_threshold,
            timer=s,
            blocked_ckpt_s=(
                list(self.checkpointer.blocked_s)
                if self.checkpointer is not None and
                hasattr(self.checkpointer, "blocked_s") else []
            ),
        )
