"""Tiered block read-cache + readahead for the storage stack (paper §III-A).

The paper's repeated-epoch characterization (Fig. 5) hinges on whether reads
are served warm from memory or cold from the device, and tf-Darshan
(arXiv:2008.04395) attributes most per-op DL read time to exactly those
small cold POSIX reads.  The interleave engine re-reads every shard every
epoch, so without a cache the hdd/lustre tiers never leave the cold-read
regime.  This module adds the missing memory-hierarchy level:

* :class:`BlockCache` — an LRU over ``(path, block_index)`` keys with a
  **hard byte budget**.  Blocks are immutable ``bytes`` objects, so a hit
  is served zero-copy (the cached object itself, or a ``memoryview`` slice
  for sub-block ranges).  Concurrent readers of the same *missing* block
  share one in-flight future (**single-flight dedup**) instead of issuing
  duplicate storage reads — under a 16-way racing cold epoch the device
  sees each block exactly once.  An optional **spill tier** evicts DRAM
  blocks to a fast storage (the burst buffer's read-side analogue of
  §III-C): eviction writes the block into a slot of one spill arena file
  (``write_range``), and a later miss probes the arena (``read_range``)
  before falling back to the slow tier — a DRAM → fast → slow hierarchy.
* :class:`CachingStorage` — a transparent :class:`Storage` wrapper (same
  shape as :class:`~repro.core.retry.RetryingStorage`) that serves
  ``read_file``/``read_range`` through the cache block-by-block and
  invalidates on every mutation (write/append/write_range/rename/remove).
  It composes *under* ``RetryingStorage`` (a loader failure drops the
  flight, so the retry above re-drives the cache) and *over*
  ``FaultyStorage``/``SimulatedStorage``/``NativeStorage``.
* :class:`ReadaheadScheduler` — walks the shard stream ahead of the
  interleave cursor (``sharded_image_pipeline(readahead=...)`` buffers a
  few upcoming shard paths) and prefetches their blocks onto the shared
  :class:`~repro.core.readerpool.ReaderPool` under a **window cap** — the
  same in-flight discipline every pipeline stage uses, so readahead never
  inflates a sweep's concurrency.  Prefetch loads share the cache's
  single-flight futures with foreground reads: a consumer arriving at a
  block being prefetched waits on that future instead of re-reading.

Consistency model: the cache assumes it sits on the *only* mutation path —
writes through :class:`CachingStorage` invalidate precisely; writes that
bypass it (another process, the inner storage handle) are invisible, like
an OS page cache without coherence traffic.  Invalidation is generation-
based: a write bumps the path's generation, and an in-flight load started
before the write refuses to publish its (possibly stale) block.

Observability (house style — one ``metrics.enabled()`` check per op, no
allocation when disabled): ``cache.{hits,misses,evictions,spills,
spill_hits,single_flight_waits,readahead_blocks}`` counters,
``cache.{hit_bytes,miss_bytes,spilled_bytes}`` byte counters, polled
``cache.{occupancy_bytes,hit_ratio,spill_occupancy_bytes}`` gauges
(unregistered on :meth:`BlockCache.close`, like ``ReaderPool``), a
``cache.lookup_s`` latency sketch, and ``cache``-stage trace spans on every
miss fill / spill read / spill write.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Tuple

from .. import metrics, trace
from .readerpool import reader_pool
from .storage import Storage

_counter = itertools.count()


class BlockCache:
    """Byte-budgeted LRU of file blocks with single-flight miss loading.

    ``capacity_bytes`` is a hard ceiling on DRAM occupancy — eviction runs
    before a new block is published, never after.  A block larger than the
    whole budget is served but not cached.  With ``spill_storage`` set,
    evicted blocks land in fixed-size slots of one arena file on that
    (fast) tier, bounded by ``spill_capacity_bytes`` (default ``4x`` the
    DRAM budget) with its own LRU slot reuse.
    """

    def __init__(self, capacity_bytes: int, *, block_size: int = 1 << 20,
                 spill_storage: Optional[Storage] = None,
                 spill_capacity_bytes: Optional[int] = None,
                 spill_path: str = "cache/spill.arena",
                 name: Optional[str] = None):
        if capacity_bytes <= 0:
            raise ValueError(f"capacity_bytes must be > 0, got {capacity_bytes}")
        if block_size <= 0:
            raise ValueError(f"block_size must be > 0, got {block_size}")
        self.capacity = int(capacity_bytes)
        self.block_size = int(block_size)
        self.name = name or f"cache-{next(_counter)}"
        self._lock = threading.Lock()
        self._blocks: "OrderedDict[Tuple[str, int], bytes]" = OrderedDict()
        self._bytes = 0
        self._inflight: Dict[Tuple[str, int], Future] = {}
        self._gen: Dict[str, int] = {}  # path -> write generation
        self._closed = False
        # spill tier (optional): one arena file of block_size-wide slots
        self._spill = spill_storage
        self._spill_cap = int(spill_capacity_bytes
                              if spill_capacity_bytes is not None
                              else 4 * self.capacity)
        self._spill_path = spill_path
        self._spill_index: "OrderedDict[Tuple[str, int], Tuple[int, int]]" = \
            OrderedDict()               # key -> (slot, length)
        self._spill_bytes = 0
        self._free_slots: List[int] = []
        self._next_slot = 0
        self._pins: Dict[int, int] = {}  # slot -> readers/writers mid-I/O
        # attribute mirrors of the live counters (metrics-disabled runs)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.spills = 0
        self.spill_hits = 0
        self.single_flight_waits = 0
        self.hit_bytes = 0
        self.miss_bytes = 0
        metrics.register_gauge("cache.occupancy_bytes",
                               lambda: self._bytes, cache=self.name)
        metrics.register_gauge("cache.hit_ratio", self.hit_ratio,
                               cache=self.name)
        if self._spill is not None:
            metrics.register_gauge("cache.spill_occupancy_bytes",
                                   lambda: self._spill_bytes, cache=self.name)

    # -- introspection -------------------------------------------------------
    def hit_ratio(self) -> float:
        lookups = self.hits + self.misses
        return self.hits / lookups if lookups else 0.0

    @property
    def occupancy_bytes(self) -> int:
        return self._bytes

    @property
    def spill_occupancy_bytes(self) -> int:
        return self._spill_bytes

    def stats(self) -> dict:
        """Point snapshot of the counters (for benchmarks/tests)."""
        with self._lock:
            return dict(
                hits=self.hits, misses=self.misses,
                evictions=self.evictions, spills=self.spills,
                spill_hits=self.spill_hits,
                single_flight_waits=self.single_flight_waits,
                hit_bytes=self.hit_bytes, miss_bytes=self.miss_bytes,
                occupancy_bytes=self._bytes,
                spill_occupancy_bytes=self._spill_bytes,
                blocks=len(self._blocks), spill_blocks=len(self._spill_index),
                hit_ratio=self.hit_ratio(),
            )

    # -- lookup --------------------------------------------------------------
    def get_block(self, path: str, index: int,
                  loader: Callable[[], bytes]) -> bytes:
        """Return block ``index`` of ``path``, loading via ``loader`` on a
        miss.  Concurrent callers for the same missing block share one
        loader call (single-flight); a loader failure propagates to every
        waiter and drops the flight so the next call retries."""
        m = metrics.enabled()
        t0 = time.monotonic() if m else 0.0
        key = (path, index)
        fut: Optional[Future] = None
        leader = False
        gen = 0
        with self._lock:
            if self._closed:
                raise RuntimeError("get_block() on a closed BlockCache")
            blk = self._blocks.get(key)
            if blk is not None:
                self._blocks.move_to_end(key)
                self.hits += 1
                self.hit_bytes += len(blk)
            else:
                self.misses += 1
                fut = self._inflight.get(key)
                if fut is not None:
                    self.single_flight_waits += 1
                else:
                    fut = Future()
                    gen = self._gen.get(path, 0)
                    self._inflight[key] = fut
                    leader = True
        if blk is not None:
            if m:
                metrics.inc("cache.hits", 1, cache=self.name)
                metrics.inc("cache.hit_bytes", len(blk), cache=self.name)
                metrics.observe("cache.lookup_s", time.monotonic() - t0,
                                cache=self.name)
            return blk
        if m:
            metrics.inc("cache.misses", 1, cache=self.name)
            if not leader:
                metrics.inc("cache.single_flight_waits", 1, cache=self.name)
        if leader:
            self._fill(key, fut, gen, loader)
        data = fut.result()
        if m:
            metrics.observe("cache.lookup_s", time.monotonic() - t0,
                            cache=self.name)
        return data

    # -- miss path (leader only) ---------------------------------------------
    def _fill(self, key: Tuple[str, int], fut: Future, gen: int,
              loader: Callable[[], bytes]) -> None:
        try:
            data = self._load(key, loader)
        except BaseException as e:
            with self._lock:
                if self._inflight.get(key) is fut:
                    del self._inflight[key]
            fut.set_exception(e)
            return
        spill_jobs: List[Tuple[Tuple[str, int], bytes]] = []
        with self._lock:
            if self._inflight.get(key) is fut:
                del self._inflight[key]
            fresh = (not self._closed
                     and self._gen.get(key[0], 0) == gen)
            if fresh and len(data) <= self.capacity:
                self._blocks[key] = data
                self._bytes += len(data)
                while self._bytes > self.capacity:
                    k2, b2 = self._blocks.popitem(last=False)
                    self._bytes -= len(b2)
                    self.evictions += 1
                    spill_jobs.append((k2, b2))
            self.miss_bytes += len(data)
        fut.set_result(data)
        if spill_jobs and metrics.enabled():
            metrics.inc("cache.evictions", len(spill_jobs), cache=self.name)
        for k2, b2 in spill_jobs:
            self._spill_block(k2, b2)

    def _load(self, key: Tuple[str, int],
              loader: Callable[[], bytes]) -> bytes:
        """Fetch a block: spill-arena probe first, then the slow tier."""
        path, _index = key
        if self._spill is not None:
            slot_ent = None
            with self._lock:
                ent = self._spill_index.get(key)
                if ent is not None:
                    self._spill_index.move_to_end(key)
                    slot_ent = ent
                    self._pin_locked(ent[0])
            if slot_ent is not None:
                slot, length = slot_ent
                try:
                    with trace.span(trace.STAGE_CACHE,
                                    f"spill_read:{path}") as sp:
                        data = bytes(self._spill.read_range(
                            self._spill_path, slot * self.block_size, length))
                        sp.set_bytes(len(data))
                finally:
                    with self._lock:
                        self._unpin_locked(slot)
                self.spill_hits += 1
                if metrics.enabled():
                    metrics.inc("cache.spill_hits", 1, cache=self.name)
                return data
        with trace.span(trace.STAGE_CACHE, f"fill:{path}") as sp:
            data = loader()
            if type(data) is not bytes:
                data = bytes(data)
            sp.set_bytes(len(data))
        return data

    # -- spill tier ----------------------------------------------------------
    def _pin_locked(self, slot: int) -> None:
        self._pins[slot] = self._pins.get(slot, 0) + 1

    def _unpin_locked(self, slot: int) -> None:
        n = self._pins.get(slot, 0) - 1
        if n <= 0:
            self._pins.pop(slot, None)
        else:
            self._pins[slot] = n

    def _alloc_slot_locked(self) -> Optional[int]:
        """A free arena slot: the free list, fresh arena growth under the
        spill budget, or the LRU spill entry's slot.  Pinned slots (a reader
        or writer is mid-I/O on them) are never reused."""
        for i, s in enumerate(self._free_slots):
            if s not in self._pins:
                return self._free_slots.pop(i)
        if (self._next_slot + 1) * self.block_size <= self._spill_cap:
            s = self._next_slot
            self._next_slot += 1
            return s
        for k in self._spill_index:
            slot, length = self._spill_index[k]
            if slot not in self._pins:
                del self._spill_index[k]
                self._spill_bytes -= length
                return slot
        return None

    def _spill_block(self, key: Tuple[str, int], data: bytes) -> None:
        """Demote an evicted DRAM block into the spill arena (best-effort:
        a spill failure just drops the block — the slow tier still has it)."""
        if self._spill is None or len(data) > self.block_size:
            return
        path = key[0]
        with self._lock:
            if self._closed:
                return
            if key in self._spill_index:          # inclusive tiers: already
                self._spill_index.move_to_end(key)  # resident in the arena
                return
            gen = self._gen.get(path, 0)
            slot = self._alloc_slot_locked()
            if slot is None:
                return
            self._pin_locked(slot)                # pin through the write
        try:
            with trace.span(trace.STAGE_CACHE, f"spill_write:{path}") as sp:
                self._spill.write_range(self._spill_path,
                                        slot * self.block_size, data)
                sp.set_bytes(len(data))
        except Exception:
            with self._lock:
                self._unpin_locked(slot)
                self._free_slots.append(slot)
            return
        with self._lock:
            self._unpin_locked(slot)
            if self._gen.get(path, 0) == gen and not self._closed:
                self._spill_index[key] = (slot, len(data))
                self._spill_bytes += len(data)
                self.spills += 1
            else:
                self._free_slots.append(slot)
        if metrics.enabled():
            metrics.inc("cache.spills", 1, cache=self.name)
            metrics.inc("cache.spilled_bytes", len(data), cache=self.name)

    # -- invalidation --------------------------------------------------------
    def invalidate(self, path: str, prefix: bool = False) -> None:
        """Drop every cached/spilled block of ``path`` (or, with
        ``prefix=True``, of any path under it) and bump its generation so
        in-flight loads started before the mutation never publish."""
        def match(p: str) -> bool:
            return p == path or (prefix and p.startswith(path + "/"))

        with self._lock:
            touched = {k[0] for k in self._blocks if match(k[0])}
            touched |= {k[0] for k in self._spill_index if match(k[0])}
            touched |= {k[0] for k in self._inflight if match(k[0])}
            touched.add(path)
            for p in touched:
                self._gen[p] = self._gen.get(p, 0) + 1
            for k in [k for k in self._blocks if match(k[0])]:
                blk = self._blocks.pop(k)
                self._bytes -= len(blk)
            for k in [k for k in self._spill_index if match(k[0])]:
                slot, length = self._spill_index.pop(k)
                self._spill_bytes -= length
                self._free_slots.append(slot)

    def clear(self) -> None:
        """Drop everything (``drop_caches`` analogue)."""
        with self._lock:
            paths = {k[0] for k in self._blocks}
            paths |= {k[0] for k in self._spill_index}
            paths |= {k[0] for k in self._inflight}
            for p in paths:
                self._gen[p] = self._gen.get(p, 0) + 1
            self._blocks.clear()
            self._bytes = 0
            for slot, _length in self._spill_index.values():
                self._free_slots.append(slot)
            self._spill_index.clear()
            self._spill_bytes = 0

    def close(self) -> None:
        """Unregister the gauges and drop all state (idempotent)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.clear()
        metrics.unregister_gauge("cache.occupancy_bytes", cache=self.name)
        metrics.unregister_gauge("cache.hit_ratio", cache=self.name)
        if self._spill is not None:
            metrics.unregister_gauge("cache.spill_occupancy_bytes",
                                     cache=self.name)
            try:
                if self._spill.exists(self._spill_path):
                    self._spill.remove(self._spill_path)
            except OSError:
                pass

    def __enter__(self) -> "BlockCache":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class CachingStorage(Storage):
    """Transparent :class:`Storage` wrapper serving reads from a
    :class:`BlockCache`.

    Reads split into aligned blocks keyed ``(path, block)``; a range within
    one block returns a zero-copy ``memoryview`` of the cached bytes, a
    single-block file returns the cached ``bytes`` object itself, and only
    multi-block assembly copies (once, into a fresh ``bytearray``).  Every
    mutating op writes through to the inner storage *first*, then
    invalidates — so a concurrent load that raced the write can never
    publish stale data under the new generation.

    File sizes are memoized per path (block math needs them on every read)
    and invalidated together with the data blocks.
    """

    def __init__(self, inner: Storage, cache: BlockCache):
        self.inner = inner
        self.cache = cache
        self.name = f"cached({getattr(inner, 'name', '?')})"
        self._sizes: Dict[str, int] = {}
        self._sizes_lock = threading.Lock()

    # -- block plumbing ------------------------------------------------------
    def _file_size(self, path: str) -> int:
        with self._sizes_lock:
            s = self._sizes.get(path)
        if s is None:
            s = self.inner.size(path)
            with self._sizes_lock:
                self._sizes[path] = s
        return s

    def _block(self, path: str, index: int) -> bytes:
        bs = self.cache.block_size
        return self.cache.get_block(
            path, index,
            lambda: self.inner.read_range(path, index * bs, bs))

    def prefetch_block(self, path: str, index: int) -> None:
        """Warm one block (readahead entry point); shares the single-flight
        future with any concurrent foreground read of the same block."""
        self._block(path, index)

    def n_blocks(self, path: str) -> int:
        size = self._file_size(path)
        bs = self.cache.block_size
        return max(1, (size + bs - 1) // bs)

    # -- reads ---------------------------------------------------------------
    def read_file(self, path: str) -> bytes:
        size = self._file_size(path)
        bs = self.cache.block_size
        if size <= bs:
            return self._block(path, 0)   # the cached object itself: 0-copy
        out = bytearray(size)
        pos = 0
        for i in range((size + bs - 1) // bs):
            blk = self._block(path, i)
            out[pos:pos + len(blk)] = blk
            pos += len(blk)
        return bytes(out)

    def read_range(self, path: str, offset: int, length: int) -> bytes:
        size = self._file_size(path)
        end = min(offset + length, size)
        if offset >= end:
            return b""
        bs = self.cache.block_size
        first, last = offset // bs, (end - 1) // bs
        if first == last:
            blk = self._block(path, first)
            return memoryview(blk)[offset - first * bs: end - first * bs]
        out = bytearray(end - offset)
        pos = 0
        for i in range(first, last + 1):
            blk = self._block(path, i)
            lo = offset - i * bs if i == first else 0
            hi = end - i * bs if i == last else len(blk)
            out[pos:pos + hi - lo] = memoryview(blk)[lo:hi]
            pos += hi - lo
        return bytes(out)

    # -- writes (write-through + invalidate) ---------------------------------
    def _invalidate(self, path: str, prefix: bool = False) -> None:
        self.cache.invalidate(path, prefix=prefix)
        with self._sizes_lock:
            if prefix:
                for p in [p for p in self._sizes
                          if p == path or p.startswith(path + "/")]:
                    del self._sizes[p]
            else:
                self._sizes.pop(path, None)

    def write_file(self, path: str, data: bytes, sync: bool = False) -> None:
        self.inner.write_file(path, data, sync=sync)
        self._invalidate(path)

    def append_file(self, path: str, data: bytes, sync: bool = False) -> None:
        self.inner.append_file(path, data, sync=sync)
        self._invalidate(path)

    def write_range(self, path: str, offset: int, data: bytes,
                    sync: bool = False) -> None:
        self.inner.write_range(path, offset, data, sync=sync)
        self._invalidate(path)

    def fsync_dir(self, path: str) -> None:
        self.inner.fsync_dir(path)

    # -- namespace -----------------------------------------------------------
    def listdir(self, path: str) -> List[str]:
        return self.inner.listdir(path)

    def exists(self, path: str) -> bool:
        return self.inner.exists(path)

    def makedirs(self, path: str) -> None:
        self.inner.makedirs(path)

    def remove(self, path: str) -> None:
        self.inner.remove(path)
        self._invalidate(path, prefix=True)   # may have been a directory

    def rename(self, src: str, dst: str) -> None:
        self.inner.rename(src, dst)
        self._invalidate(src, prefix=True)
        self._invalidate(dst, prefix=True)

    def size(self, path: str) -> int:
        return self._file_size(path)

    def drop_caches(self) -> None:
        self.cache.clear()
        with self._sizes_lock:
            self._sizes.clear()
        self.inner.drop_caches()


class ReadaheadScheduler:
    """Prefetch upcoming shard blocks onto the shared reader pool.

    ``sharded_image_pipeline(readahead=...)`` announces each shard path as
    it enters the lookahead buffer (``lookahead_shards`` ahead of the
    interleave cursor); :meth:`schedule` enqueues the shard's blocks and at
    most ``window`` block fetches are in flight at once — the per-stage
    window discipline of PR 3, so a grown pool never turns readahead into
    unbounded concurrency.  Fetch errors are swallowed (the consumer's own
    read will surface them through the normal retry/quarantine path).
    """

    def __init__(self, storage: CachingStorage, *, window: int = 8,
                 lookahead_shards: int = 2, pool=None):
        if not isinstance(storage, CachingStorage):
            raise TypeError(
                f"readahead needs a CachingStorage to prefetch into, got "
                f"{type(storage).__name__}")
        self.storage = storage
        self.window = max(1, int(window))
        self.lookahead_shards = max(1, int(lookahead_shards))
        self._pool = pool
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._queue: deque = deque()    # (path, block) pending
        self._inflight = 0
        self._closed = False
        self.scheduled = 0
        self.loaded = 0
        self.errors = 0

    def schedule(self, path: str) -> None:
        """Enqueue every block of ``path`` for prefetch."""
        try:
            n = self.storage.n_blocks(path)
        except OSError:
            return      # the foreground read will report the real error
        with self._lock:
            if self._closed:
                return
            self._queue.extend((path, i) for i in range(n))
            self.scheduled += n
        if metrics.enabled():
            metrics.inc("cache.readahead_blocks", n,
                        cache=self.storage.cache.name)
        self._pump()

    def _pump(self) -> None:
        while True:
            with self._lock:
                if (self._closed or self._inflight >= self.window
                        or not self._queue):
                    return
                path, idx = self._queue.popleft()
                self._inflight += 1
            pool = self._pool if self._pool is not None \
                else reader_pool(self.window)
            pool.submit(self._fetch, path, idx)

    def _fetch(self, path: str, idx: int) -> None:
        try:
            self.storage.prefetch_block(path, idx)
            with self._lock:
                self.loaded += 1
        except Exception:
            with self._lock:
                self.errors += 1
        finally:
            with self._lock:
                self._inflight -= 1
                self._idle.notify_all()
            self._pump()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait until the queue is empty and nothing is in flight."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._queue or self._inflight:
                left = None if deadline is None \
                    else max(0.0, deadline - time.monotonic())
                if left == 0.0:
                    return False
                self._idle.wait(timeout=left)
        return True

    def clear(self) -> None:
        """Drop not-yet-submitted prefetches (epoch teardown)."""
        with self._lock:
            self._queue.clear()

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._queue.clear()
