"""Checkpoint saver: roundtrip, retention, atomic commit, int8, elastic."""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.checkpoint import (
    CheckpointSaver, dequantize_blockwise, quantize_blockwise, resolve_dtype,
)


def tree():
    rng = np.random.default_rng(0)
    return {
        "layer0": {"w": rng.normal(size=(32, 16)).astype(np.float32),
                   "b": np.zeros(16, np.float32)},
        "embed": rng.normal(size=(100, 8)).astype(np.float32),
        "step": np.int32(5),
    }


class TestRoundtrip:
    def test_bit_exact(self, tmp_storage):
        t = tree()
        saver = CheckpointSaver(tmp_storage, "ckpt/m", n_shards=3)
        saver.save(10, t)
        out = saver.restore_pytree(t)
        for a, b in zip(
            [t["layer0"]["w"], t["layer0"]["b"], t["embed"], t["step"]],
            [out["layer0"]["w"], out["layer0"]["b"], out["embed"], out["step"]],
        ):
            np.testing.assert_array_equal(a, b)

    def test_shard_layout(self, tmp_storage):
        saver = CheckpointSaver(tmp_storage, "ckpt/m", n_shards=4)
        r = saver.save(1, tree())
        data_files = [f for f in r.files if ".data-" in f]
        assert len(data_files) == 4
        assert tmp_storage.exists("ckpt/m-1.index")
        assert tmp_storage.exists("ckpt/m-1.meta")

    def test_restore_specific_step(self, tmp_storage):
        saver = CheckpointSaver(tmp_storage, "ckpt/m")
        t = tree()
        saver.save(1, t)
        t2 = {k: (v if not isinstance(v, dict) else v) for k, v in t.items()}
        t2["embed"] = t["embed"] * 2
        saver.save(2, t2)
        old = saver.restore_pytree(t, step=1)
        np.testing.assert_array_equal(old["embed"], t["embed"])


class TestExtensionDtypes:
    def test_resolve_dtype_builtin_and_extension(self):
        assert resolve_dtype("float32") == np.dtype(np.float32)
        import ml_dtypes
        assert resolve_dtype("bfloat16") == np.dtype(ml_dtypes.bfloat16)
        with pytest.raises(TypeError):
            resolve_dtype("not_a_dtype")

    def test_bfloat16_roundtrip(self, tmp_storage):
        """Restore of bfloat16 leaves must not depend on np.dtype('bfloat16')
        being registered (it raises unless ml_dtypes was imported)."""
        import jax.numpy as jnp

        t = {"w": jnp.arange(64, dtype=jnp.bfloat16).reshape(8, 8),
             "b": np.ones(8, np.float32)}
        saver = CheckpointSaver(tmp_storage, "ckpt/m", n_shards=2)
        saver.save(1, t)
        out = saver.restore_pytree(t)
        assert str(out["w"].dtype) == "bfloat16"
        np.testing.assert_array_equal(
            np.asarray(out["w"], np.float32), np.asarray(t["w"], np.float32))

    def test_bfloat16_quantized_save_does_not_crash(self, tmp_storage):
        import jax.numpy as jnp

        t = {"w": jnp.ones((512,), jnp.bfloat16)}
        saver = CheckpointSaver(tmp_storage, "ckpt/m", quantize="int8")
        saver.save(1, t)
        out = saver.restore_pytree(t)
        assert str(out["w"].dtype) == "bfloat16"
        np.testing.assert_allclose(
            np.asarray(out["w"], np.float32), np.ones(512, np.float32),
            atol=0.02)


class TestRetention:
    def test_keep_n(self, tmp_storage):
        saver = CheckpointSaver(tmp_storage, "ckpt/m", keep=2)
        t = tree()
        for s in (10, 20, 30, 40):
            saver.save(s, t)
        assert saver.all_steps() == [30, 40]
        files = tmp_storage.listdir("ckpt")
        assert not any(f.startswith("m-10.") or f.startswith("m-20.") for f in files)
        with pytest.raises(FileNotFoundError):
            saver.restore(step=10)


class TestAtomicity:
    def test_crash_before_marker_keeps_previous(self, tmp_storage):
        saver = CheckpointSaver(tmp_storage, "ckpt/m")
        t = tree()
        saver.save(1, t)
        # simulate crash mid-save of step 2: data written, marker not updated
        base = "ckpt/m-2"
        tmp_storage.write_file(f"{base}.data-00000-of-00001", b"garbage")
        # marker still points at step 1
        assert saver.latest_step() == 1
        out = saver.restore_pytree(t)
        np.testing.assert_array_equal(out["embed"], t["embed"])


class TestQuantized:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_q8_roundtrip_error_bound(self, seed):
        rng = np.random.default_rng(seed)
        x = (rng.normal(size=(777,)) * rng.uniform(0.1, 100)).astype(np.float32)
        q, s, pad = quantize_blockwise(x)
        back = dequantize_blockwise(q, s, pad, x.shape, np.float32)
        # absmax/127 per block bounds the error
        blocks = np.pad(x, (0, pad)).reshape(-1, 256)
        bound = (np.abs(blocks).max(axis=1, keepdims=True) / 127.0) * 0.5 + 1e-7
        err = np.abs(np.pad(x, (0, pad)).reshape(-1, 256) - np.pad(back, (0, pad)).reshape(-1, 256))
        assert (err <= bound + 1e-6).all()

    def test_int8_checkpoint_smaller_and_close(self, tmp_storage):
        t = {"w": np.random.default_rng(0).normal(size=(512, 256)).astype(np.float32)}
        full = CheckpointSaver(tmp_storage, "full/m")
        q8 = CheckpointSaver(tmp_storage, "q8/m", quantize="int8")
        rf = full.save(1, t)
        rq = q8.save(1, t)
        assert rq.n_bytes < rf.n_bytes * 0.35
        out = q8.restore_pytree(t)
        rel = np.abs(out["w"] - t["w"]).max() / np.abs(t["w"]).max()
        assert rel < 0.02


class TestElastic:
    def test_restore_sharded_roundtrip_1dev(self, tmp_storage):
        """Elastic restore path (single device: trivial mesh)."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        t = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
        saver = CheckpointSaver(tmp_storage, "ckpt/m")
        saver.save(3, t)
        mesh_kw = {}
        if hasattr(jax.sharding, "AxisType"):  # absent on older jax
            mesh_kw["axis_types"] = (jax.sharding.AxisType.Auto,)
        mesh = jax.make_mesh((1,), ("data",), **mesh_kw)
        sh = {"w": NamedSharding(mesh, P("data", None))}
        out = saver.restore_sharded(t, sh)
        np.testing.assert_array_equal(np.asarray(out["w"]), t["w"])
