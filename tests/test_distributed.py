"""Multi-device tests (subprocesses: device count is locked at jax init,
and the main test session must keep seeing 1 CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH="src")

# prepended to every subprocess: mesh construction that works with and
# without jax.sharding.AxisType (absent on older jax)
_PREAMBLE = textwrap.dedent("""
    import jax as _jax_compat

    def make_mesh(shape, names):
        kw = {}
        if hasattr(_jax_compat.sharding, "AxisType"):
            kw["axis_types"] = (_jax_compat.sharding.AxisType.Auto,) * len(shape)
        return _jax_compat.make_mesh(shape, names, **kw)
""")


def run_py(code: str, timeout=600) -> str:
    r = subprocess.run([sys.executable, "-c",
                        _PREAMBLE + textwrap.dedent(code)],
                       env=_ENV, capture_output=True, text=True,
                       timeout=timeout, cwd=os.getcwd())
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
class TestDistributed:
    def test_sharded_train_step_runs_and_learns(self):
        out = run_py("""
            import jax, jax.numpy as jnp, json
            from repro.configs import ARCHS
            from repro.sharding.rules import ShardingCtx
            from repro.train import steps as S
            from repro.train.optimizer import OptConfig

            mesh = make_mesh((2,2,2), ("pod","data","model"))
            cfg = ARCHS["qwen3-4b"].smoke()
            opt = OptConfig()
            ctx = ShardingCtx(mesh=mesh)
            rng = jax.random.PRNGKey(0)
            shapes = jax.eval_shape(lambda: S.init_train_state(rng, cfg, opt))
            st_sh = S.state_shardings(cfg, ctx, shapes)
            state = jax.jit(lambda: S.init_train_state(rng, cfg, opt),
                            out_shardings=st_sh)()
            toks = jax.random.randint(rng, (8, 33), 0, cfg.padded_vocab,
                                      dtype=jnp.int32)
            b_sh = S.batch_shardings(cfg, ctx, {"tokens": toks})
            step = jax.jit(S.make_train_step(cfg, opt, ctx, q_chunk=16,
                                             kv_chunk=16),
                           in_shardings=(st_sh, b_sh),
                           out_shardings=(st_sh, None))
            with mesh:
                losses = []
                for _ in range(4):
                    state, m = step(state, {"tokens": toks})
                    losses.append(float(m["loss"]))
            print(json.dumps(losses))
        """)
        losses = json.loads(out.strip().splitlines()[-1])
        assert losses[-1] < losses[0]

    def test_compressed_allreduce_matches_mean(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.train.compress import compressed_allreduce_stacked
            mesh = make_mesh((2,2,2), ("pod","data","model"))
            x = jax.random.normal(jax.random.PRNGKey(0), (2, 4096)) * 3
            with mesh:
                out = compressed_allreduce_stacked(mesh, x)
            ref = np.asarray(x).mean(0)
            rel = float(np.abs(np.asarray(out) - ref).max() / np.abs(ref).max())
            assert rel < 0.02, rel
            print("REL", rel)
        """)
        assert "REL" in out

    def test_elastic_restore_across_topologies(self, tmp_path):
        """Save on a (4,2) mesh layout, restore onto (2,4) — the index is
        topology-free."""
        ckpt_dir = str(tmp_path)
        run_py(f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core.storage import NativeStorage
            from repro.core.checkpoint import CheckpointSaver
            mesh = make_mesh((4,2), ("data","model"))
            w = jnp.arange(64*32, dtype=jnp.float32).reshape(64, 32)
            w = jax.device_put(w, NamedSharding(mesh, P("data","model")))
            saver = CheckpointSaver(NativeStorage({ckpt_dir!r}), "ckpt/m")
            saver.save(1, {{"w": w}})
        """)
        out = run_py(f"""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core.storage import NativeStorage
            from repro.core.checkpoint import CheckpointSaver
            mesh = make_mesh((2,4), ("data","model"))
            saver = CheckpointSaver(NativeStorage({ckpt_dir!r}), "ckpt/m")
            skeleton = {{"w": np.zeros((64,32), np.float32)}}
            sh = {{"w": NamedSharding(mesh, P("data","model"))}}
            out = saver.restore_sharded(skeleton, sh)
            expect = np.arange(64*32, dtype=np.float32).reshape(64,32)
            np.testing.assert_array_equal(np.asarray(out["w"]), expect)
            print("ELASTIC OK", out["w"].sharding)
        """)
        assert "ELASTIC OK" in out
