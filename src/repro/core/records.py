"""Record container + image payload format ("the files" of the paper).

The paper's workloads read one JPEG per file and decode+resize it inside the
mapped function.  We have no JPEG codec in this environment, so we define:

* ``RRF1`` — a TFRecord-like container: for each record
  ``[u64 length][u32 crc32(length)][payload][u32 crc32(payload)]``.
  Corrupt records raise :class:`RecordError` (exercised by
  ``Dataset.ignore_errors()``, paper §III-A).
* ``IMG1`` — an image payload: 16-byte header
  ``magic(4s) | h(u32) | w(u32) | c(u16) | dtype(u16)`` followed by raw
  ``h*w*c`` samples.  ``decode_image`` is the ``tf.image.decode_jpeg``
  analogue: it parses, validates and materializes the array — a real
  CPU-side decode step with a real cost, which is what the paper measures.

Preprocessing mirrors the paper's mapped function: decode → convert dtype to
float in [0,1] → resize to the network's input size (224x224x3 for AlexNet).

Vectorized-pipeline additions (ISSUE 3):

* ``decode_records(blob, copy=False)`` / ``decode_image(payload, copy=False)``
  are the zero-copy variants: record payloads come back as ``memoryview``
  slices of the shard blob and image bodies as read-only ``np.frombuffer``
  views — no byte is copied between the storage read and the resize gather.
* :func:`resize_image` is a LUT-gather bilinear: corner indices and weights
  are precomputed once per (in_hw, out_hw) pair (LRU-cached) and applied as
  four output-sized ``take`` gathers — no ``img[y0][:, x0]``-style
  full-width intermediates — with an optional ``out=`` buffer so a fused
  ``map_and_batch`` can decode straight into the batch tensor.
  :func:`resize_image_reference` keeps the seed implementation as the
  parity oracle (the LUT path is bit-identical to it for float inputs).
* :func:`write_sharded_image_dataset` writes multi-record ``.rrf`` shards
  (many images per file) for the ``Dataset.interleave`` streaming path.
"""
from __future__ import annotations

import struct
import zlib
from functools import lru_cache
from typing import Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

Buffer = Union[bytes, bytearray, memoryview]

RECORD_HDR = struct.Struct("<QI")   # length, crc(length)
RECORD_FTR = struct.Struct("<I")    # crc(payload)
IMG_HDR = struct.Struct("<4sIIHH")  # magic, h, w, c, dtype-code
IMG_MAGIC = b"IMG1"

_DTYPES = {0: np.uint8, 1: np.uint16, 2: np.float32}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


class RecordError(ValueError):
    """Raised on CRC mismatch / truncated record / bad image header."""


# ---------------------------------------------------------------------------
# RRF1 container
# ---------------------------------------------------------------------------
def encode_record(payload: bytes) -> bytes:
    hdr = RECORD_HDR.pack(len(payload), zlib.crc32(struct.pack("<Q", len(payload))))
    ftr = RECORD_FTR.pack(zlib.crc32(payload))
    return hdr + payload + ftr


def decode_records(blob: Buffer, copy: bool = True) -> Iterator[Buffer]:
    """Yield payloads from a byte-string of concatenated RRF1 records.

    With ``copy=False`` each payload is a ``memoryview`` slice of ``blob``
    (zero-copy: CRC validation reads through the view, nothing is
    duplicated).  The views alias ``blob`` — decode or copy them before
    mutating/releasing the backing buffer.
    """
    view = blob if isinstance(blob, memoryview) else memoryview(blob)
    off, n = 0, len(view)
    while off < n:
        if off + RECORD_HDR.size > n:
            raise RecordError("truncated record header")
        length, hcrc = RECORD_HDR.unpack_from(view, off)
        if zlib.crc32(struct.pack("<Q", length)) != hcrc:
            raise RecordError("record header crc mismatch")
        off += RECORD_HDR.size
        if off + length + RECORD_FTR.size > n:
            raise RecordError("truncated record payload")
        payload = view[off : off + length]
        off += length
        (pcrc,) = RECORD_FTR.unpack_from(view, off)
        off += RECORD_FTR.size
        if zlib.crc32(payload) != pcrc:
            raise RecordError("record payload crc mismatch")
        yield payload.tobytes() if copy else payload


def iter_record_views(blob: Buffer) -> Iterator[memoryview]:
    """Zero-copy record iterator (``decode_records(blob, copy=False)``)."""
    return decode_records(blob, copy=False)


def decode_single_record(blob: Buffer, copy: bool = True) -> Buffer:
    payloads = list(decode_records(blob, copy=copy))
    if len(payloads) != 1:
        raise RecordError(f"expected 1 record, found {len(payloads)}")
    return payloads[0]


# ---------------------------------------------------------------------------
# IMG1 payload
# ---------------------------------------------------------------------------
def encode_image(arr: np.ndarray) -> bytes:
    arr = np.ascontiguousarray(arr)
    if arr.ndim == 2:
        arr = arr[:, :, None]
    if arr.ndim != 3:
        raise ValueError(f"image must be HxWxC, got shape {arr.shape}")
    code = _DTYPE_CODES.get(arr.dtype)
    if code is None:
        raise ValueError(f"unsupported image dtype {arr.dtype}")
    h, w, c = arr.shape
    return IMG_HDR.pack(IMG_MAGIC, h, w, c, code) + arr.tobytes()


def decode_image(payload: Buffer, copy: bool = True) -> np.ndarray:
    """``tf.image.decode_jpeg`` analogue (parse + validate + materialize).

    With ``copy=False`` the returned array is a read-only view sharing the
    payload's memory (zero-copy decode): the header is parsed and validated
    but the ``h*w*c`` samples are never duplicated.  The view aliases
    ``payload`` — downstream stages that write (resize ``out=``, dtype
    conversion) allocate their own output, so the pipeline never mutates it.
    """
    if len(payload) < IMG_HDR.size:
        raise RecordError("image payload too short")
    magic, h, w, c, code = IMG_HDR.unpack_from(payload, 0)
    if magic != IMG_MAGIC:
        raise RecordError(f"bad image magic {magic!r}")
    dtype = _DTYPES.get(code)
    if dtype is None:
        raise RecordError(f"bad image dtype code {code}")
    view = payload if isinstance(payload, memoryview) else memoryview(payload)
    body = view[IMG_HDR.size :]
    expected = h * w * c * np.dtype(dtype).itemsize
    if len(body) != expected:
        raise RecordError(f"image body {len(body)}B != expected {expected}B")
    arr = np.frombuffer(body, dtype=dtype).reshape(h, w, c)
    return arr.copy() if copy else arr


# ---------------------------------------------------------------------------
# Preprocessing (the paper's mapped function, post-decode)
# ---------------------------------------------------------------------------
def convert_image_dtype(img: np.ndarray) -> np.ndarray:
    """uint{8,16} -> float32 in [0,1] (tf.image.convert_image_dtype)."""
    if img.dtype == np.uint8:
        return img.astype(np.float32) / 255.0
    if img.dtype == np.uint16:
        return img.astype(np.float32) / 65535.0
    return img.astype(np.float32)


def resize_image_reference(img: np.ndarray, out_h: int, out_w: int) -> np.ndarray:
    """Seed bilinear resize, kept as the parity oracle for the LUT path.

    Materializes ``img[y0][:, x0]``-style intermediates (a full-width row
    gather per corner) — correct but allocation-heavy; the vectorized
    :func:`resize_image` must stay bit-identical to it for float inputs.
    """
    h, w, c = img.shape
    if (h, w) == (out_h, out_w):
        return img
    ys = np.linspace(0, h - 1, out_h, dtype=np.float32)
    xs = np.linspace(0, w - 1, out_w, dtype=np.float32)
    y0 = np.floor(ys).astype(np.int32)
    x0 = np.floor(xs).astype(np.int32)
    y1 = np.minimum(y0 + 1, h - 1)
    x1 = np.minimum(x0 + 1, w - 1)
    wy = (ys - y0.astype(np.float32))[:, None, None]
    wx = (xs - x0.astype(np.float32))[None, :, None]
    img = img.astype(np.float32)
    top = img[y0][:, x0] * (1 - wx) + img[y0][:, x1] * wx
    bot = img[y1][:, x0] * (1 - wx) + img[y1][:, x1] * wx
    return top * (1 - wy) + bot * wy


@lru_cache(maxsize=256)
def bilinear_lut(h: int, w: int, out_h: int, out_w: int):
    """Precomputed gather indices + weights for an (h,w) -> (out_h,out_w)
    bilinear resize.

    Returns ``(i00, i01, i10, i11, wx, wy)``: four flat ``(out_h*out_w,)``
    index tables into the row-major (h*w) plane — one per interpolation
    corner — plus broadcast-ready x/y fractional weights.  Cached per shape
    pair, so a steady-state pipeline computes each LUT exactly once.
    """
    ys = np.linspace(0, h - 1, out_h, dtype=np.float32)
    xs = np.linspace(0, w - 1, out_w, dtype=np.float32)
    y0 = np.floor(ys).astype(np.int32)
    x0 = np.floor(xs).astype(np.int32)
    y1 = np.minimum(y0 + 1, h - 1)
    x1 = np.minimum(x0 + 1, w - 1)
    wy = (ys - y0.astype(np.float32))[:, None, None]
    wx = (xs - x0.astype(np.float32))[None, :, None]
    row0 = (y0.astype(np.int64) * w)[:, None]
    row1 = (y1.astype(np.int64) * w)[:, None]
    i00 = (row0 + x0[None, :]).ravel()
    i01 = (row0 + x1[None, :]).ravel()
    i10 = (row1 + x0[None, :]).ravel()
    i11 = (row1 + x1[None, :]).ravel()
    return i00, i01, i10, i11, wx, wy


def resize_image(
    img: np.ndarray,
    out_h: int,
    out_w: int,
    out: Optional[np.ndarray] = None,
    scale: Optional[float] = None,
) -> np.ndarray:
    """Vectorized LUT-gather bilinear resize (tf.image.resize_images analogue).

    Gathers the four interpolation corners with precomputed flat index
    tables (:func:`bilinear_lut`) — every intermediate is output-sized, so a
    downscale from (H, W) to (h, w) touches ``4*h*w*c`` samples instead of
    the reference path's ``2*H*w*c + 4*h*w*c``.  ``out=`` writes the result
    into a caller-owned buffer (the fused ``map_and_batch`` batch tensor);
    ``scale=`` folds a dtype-conversion multiply (e.g. 1/255) into the final
    pass so uint8 sources never materialize as a full-size float image.

    For float inputs without ``scale`` the arithmetic (gather, per-axis
    lerp order) matches :func:`resize_image_reference` bit for bit.
    """
    h, w, c = img.shape
    if (h, w) == (out_h, out_w):
        res = img if scale is None else img.astype(np.float32) * scale
        if out is None:
            return res
        out[...] = res
        return out
    i00, i01, i10, i11, wx, wy = bilinear_lut(h, w, out_h, out_w)
    flat = np.ascontiguousarray(img).reshape(h * w, c)
    shape = (out_h, out_w, c)
    c00 = flat.take(i00, axis=0).reshape(shape).astype(np.float32)
    c01 = flat.take(i01, axis=0).reshape(shape).astype(np.float32)
    c10 = flat.take(i10, axis=0).reshape(shape).astype(np.float32)
    c11 = flat.take(i11, axis=0).reshape(shape).astype(np.float32)
    top = c00 * (1 - wx) + c01 * wx
    bot = c10 * (1 - wx) + c11 * wx
    if out is None:
        res = top * (1 - wy) + bot * wy
        return res if scale is None else res * scale
    np.multiply(top, 1 - wy, out=top)
    np.multiply(bot, wy, out=bot)
    np.add(top, bot, out=out)
    if scale is not None:
        out *= scale
    return out


def resize_batch(
    imgs: np.ndarray,
    out_h: int,
    out_w: int,
    out: Optional[np.ndarray] = None,
    scale: Optional[float] = None,
) -> np.ndarray:
    """Batched LUT-gather resize for same-size images: (B,H,W,C)->(B,h,w,C).

    One gather per corner for the whole batch (the numpy fallback for the
    Pallas ``resize_convert_images`` kernel).
    """
    b, h, w, c = imgs.shape
    if (h, w) == (out_h, out_w):
        res = imgs.astype(np.float32) if scale is None else (
            imgs.astype(np.float32) * scale)
        if out is None:
            return res
        out[...] = res
        return out
    i00, i01, i10, i11, wx, wy = bilinear_lut(h, w, out_h, out_w)
    flat = np.ascontiguousarray(imgs).reshape(b, h * w, c)
    shape = (b, out_h, out_w, c)
    c00 = flat.take(i00, axis=1).reshape(shape).astype(np.float32)
    c01 = flat.take(i01, axis=1).reshape(shape).astype(np.float32)
    c10 = flat.take(i10, axis=1).reshape(shape).astype(np.float32)
    c11 = flat.take(i11, axis=1).reshape(shape).astype(np.float32)
    top = c00 * (1 - wx) + c01 * wx
    bot = c10 * (1 - wx) + c11 * wx
    res = out if out is not None else np.empty(shape, np.float32)
    np.multiply(top, 1 - wy, out=top)
    np.multiply(bot, wy, out=bot)
    np.add(top, bot, out=res)
    if scale is not None:
        res *= scale
    return res


# uint -> float [0,1] conversion factors (tf.image.convert_image_dtype);
# the single source of truth — the device kernels import this table too
CONVERT_SCALE = {np.dtype(np.uint8): 1.0 / 255.0,
                 np.dtype(np.uint16): 1.0 / 65535.0}


def preprocess_image(payload: Buffer, out_h: int = 224, out_w: int = 224) -> np.ndarray:
    """decode -> convert dtype -> resize: the full mapped function."""
    img = decode_image(payload)
    img = convert_image_dtype(img)
    return resize_image(img, out_h, out_w)


def preprocess_image_into(
    payload: Buffer, out: np.ndarray
) -> np.ndarray:
    """Fused zero-copy mapped function: decode view -> resize+convert -> out.

    The image body is never copied (``decode_image(copy=False)``); the
    uint{8,16} -> float [0,1] conversion is folded into the resize's final
    multiply; the result lands directly in ``out`` (a slice of the batch
    buffer in the fused ``map_and_batch`` path).  Parity with
    :func:`preprocess_image` is within float rounding (the conversion
    multiply commutes with the bilinear lerp up to 1 ulp).
    """
    img = decode_image(payload, copy=False)
    out_h, out_w = out.shape[0], out.shape[1]
    scale = CONVERT_SCALE.get(img.dtype)
    if scale is None:  # float payloads: convert is a plain cast
        return resize_image(img.astype(np.float32), out_h, out_w, out=out)
    return resize_image(img, out_h, out_w, out=out, scale=scale)


# ---------------------------------------------------------------------------
# Dataset writers (one image per file, like ImageNet/Caltech-101 on disk)
# ---------------------------------------------------------------------------
def write_image_dataset(
    storage,
    n_images: int,
    *,
    mean_hw: Tuple[int, int] = (64, 64),
    channels: int = 3,
    n_classes: int = 101,
    seed: int = 0,
    prefix: str = "img",
) -> Tuple[List[str], List[int]]:
    """Write ``n_images`` single-image RRF1 files into ``storage``.

    Image sizes are jittered around ``mean_hw`` to mimic a real photo corpus
    (the paper's ImageNet subset has median 112 KB; Caltech-101 median 12 KB —
    choose ``mean_hw`` accordingly).  Returns (paths, labels).
    """
    rng = np.random.default_rng(seed)
    paths, labels = [], []
    for i in range(n_images):
        h = max(8, int(rng.normal(mean_hw[0], mean_hw[0] * 0.2)))
        w = max(8, int(rng.normal(mean_hw[1], mean_hw[1] * 0.2)))
        img = rng.integers(0, 256, size=(h, w, channels), dtype=np.uint8)
        blob = encode_record(encode_image(img))
        path = f"{prefix}_{i:06d}.rrf"
        storage.write_file(path, blob)
        paths.append(path)
        labels.append(int(rng.integers(0, n_classes)))
    return paths, labels


def write_sharded_image_dataset(
    storage,
    n_images: int,
    images_per_shard: int,
    *,
    mean_hw: Tuple[int, int] = (64, 64),
    hw_jitter: float = 0.2,
    channels: int = 3,
    n_classes: int = 101,
    seed: int = 0,
    prefix: str = "shard",
) -> Tuple[List[str], List[List[int]]]:
    """Write a multi-record sharded corpus: many IMG1 records per ``.rrf``.

    This is the layout the interleave pipeline streams: one sequential read
    per *shard* amortizes the device seek over ``images_per_shard`` images
    (vs one seek per image for :func:`write_image_dataset`'s one-file-per-
    image layout).  ``hw_jitter=0`` produces a uniform-size corpus (required
    by the batched device-side ``resize_convert_images`` path).

    Returns ``(shard_paths, labels_per_shard)`` with labels aligned to the
    record order inside each shard.
    """
    rng = np.random.default_rng(seed)
    paths: List[str] = []
    labels_per_shard: List[List[int]] = []
    i = 0
    s = 0
    while i < n_images:
        parts = []
        labels: List[int] = []
        for _ in range(min(images_per_shard, n_images - i)):
            if hw_jitter > 0:
                h = max(8, int(rng.normal(mean_hw[0], mean_hw[0] * hw_jitter)))
                w = max(8, int(rng.normal(mean_hw[1], mean_hw[1] * hw_jitter)))
            else:
                h, w = mean_hw
            img = rng.integers(0, 256, size=(h, w, channels), dtype=np.uint8)
            parts.append(encode_record(encode_image(img)))
            labels.append(int(rng.integers(0, n_classes)))
            i += 1
        path = f"{prefix}_{s:05d}.rrf"
        storage.write_file(path, b"".join(parts))
        paths.append(path)
        labels_per_shard.append(labels)
        s += 1
    return paths, labels_per_shard


def write_token_dataset(
    storage,
    n_shards: int,
    docs_per_shard: int,
    seq_len: int,
    vocab_size: int,
    *,
    seed: int = 0,
    prefix: str = "tokens",
) -> List[str]:
    """Write shards of token sequences (LM training corpus analogue).

    Each shard file is a sequence of RRF1 records, one record per document,
    payload = int32 token ids.
    """
    rng = np.random.default_rng(seed)
    paths = []
    for s in range(n_shards):
        parts = []
        for _ in range(docs_per_shard):
            toks = rng.integers(0, vocab_size, size=(seq_len,), dtype=np.int32)
            parts.append(encode_record(toks.tobytes()))
        path = f"{prefix}_{s:05d}.rrf"
        storage.write_file(path, b"".join(parts))
        paths.append(path)
    return paths


def decode_token_shard(blob: bytes, seq_len: int) -> np.ndarray:
    docs = [np.frombuffer(p, dtype=np.int32) for p in decode_records(blob)]
    return np.stack([d[:seq_len] for d in docs])
