"""Adam(W) with selectable optimizer-state precision.

``state_dtype``:
* ``float32`` — standard.
* ``bfloat16`` — halves optimizer HBM.
* ``int8``     — blockwise-quantized m/v (absmax per 256-elem block, fp32
  scales): ~3.6x smaller than fp32 states.  This is what lets the 398B-param
  Jamba train on a single 256-chip pod (see DESIGN.md §3) and is the same
  transform the burst-buffer checkpointer and the Pallas quantize kernel use.

All update math runs in fp32 regardless of storage precision.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
QBLOCK = 256


@dataclass(frozen=True)
class OptConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: str = "float32"   # float32 | bfloat16 | int8
    grad_clip: float = 1.0


# ---------------------------------------------------------------------------
# blockwise int8 <-> fp32 (jnp; mirrors kernels/quantize and checkpoint.py)
#
# Blocks run along the LAST axis only: (..., D) -> q (..., D/256, 256),
# s (..., D/256, 1).  This is sharding-preserving — the leading dims keep
# the parameter's partitioning, so a 348B-param MoE stack never gets
# gathered just to update its optimizer state.  (A flatten-based layout
# collapses sharded dims and forces GSPMD to replicate: the dry-run showed
# 3.2 TiB/device for jamba train before this fix — see EXPERIMENTS.md §Perf.)
# ---------------------------------------------------------------------------
def quantizable(shape) -> bool:
    if not shape:
        return False
    n = 1
    for d in shape:
        n *= d
    return shape[-1] % QBLOCK == 0 and n >= 4096


def _q8(x: Array) -> Dict[str, Array]:
    lead, last = x.shape[:-1], x.shape[-1]
    blocks = x.astype(jnp.float32).reshape(*lead, last // QBLOCK, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return dict(q=q, s=scale)


def _dq8(qs: Dict[str, Array], shape) -> Array:
    blocks = qs["q"].astype(jnp.float32) * qs["s"]
    return blocks.reshape(shape)


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------
def init_opt_state(params: Any, cfg: OptConfig) -> Any:
    def leaf(p):
        if cfg.state_dtype == "int8" and quantizable(p.shape):
            z = jnp.zeros(p.shape, jnp.float32)
            return dict(m=_q8(z), v=_q8(z))
        dt = (jnp.dtype("float32") if cfg.state_dtype == "int8"
              else jnp.dtype(cfg.state_dtype))
        return dict(m=jnp.zeros(p.shape, dt), v=jnp.zeros(p.shape, dt))

    return jax.tree.map(leaf, params)


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adam_update(
    grads: Any, opt_state: Any, params: Any, step: Array, cfg: OptConfig
) -> Tuple[Any, Any]:
    """One AdamW step; returns (new_params, new_opt_state)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def leaf(g, s, p):
        gf = g.astype(jnp.float32) * clip
        q8 = cfg.state_dtype == "int8" and quantizable(p.shape)
        if q8:
            m = _dq8(s["m"], p.shape)
            v = _dq8(s["v"], p.shape)
        else:
            m = s["m"].astype(jnp.float32)
            v = s["v"].astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - cfg.lr * update).astype(p.dtype)
        if q8:
            new_s = dict(m=_q8(m), v=_q8(v))
        else:
            dt = (jnp.dtype("float32") if cfg.state_dtype == "int8"
                  else jnp.dtype(cfg.state_dtype))
            new_s = dict(m=m.astype(dt), v=v.astype(dt))
        return new_p, new_s

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(opt_state)
    # Chain leaf updates through optimization_barrier: without an ordering
    # edge XLA is free to materialize every leaf's fp32 m/v/update buffers
    # simultaneously (~5 fp32 copies of the full model at peak).  The chain
    # caps transient memory at one leaf's working set.
    out = []
    token = None
    for g, s, p in zip(flat_g, flat_s, flat_p):
        if token is not None:
            g, _ = jax.lax.optimization_barrier((g, token))
        new_p, new_s = leaf(g, s, p)
        token = new_p
        out.append((new_p, new_s))
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_state = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_params, new_state
