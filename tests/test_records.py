"""Record container + image codec: unit + property tests."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import records


class TestRecordContainer:
    def test_roundtrip_single(self):
        payload = b"hello world" * 100
        blob = records.encode_record(payload)
        assert records.decode_single_record(blob) == payload

    def test_roundtrip_multi(self):
        payloads = [b"a" * i for i in range(0, 50, 7)]
        blob = b"".join(records.encode_record(p) for p in payloads)
        assert list(records.decode_records(blob)) == payloads

    def test_corrupt_payload_raises(self):
        blob = bytearray(records.encode_record(b"x" * 100))
        blob[20] ^= 0xFF  # flip a payload byte
        with pytest.raises(records.RecordError):
            list(records.decode_records(bytes(blob)))

    def test_truncated_raises(self):
        blob = records.encode_record(b"x" * 100)
        with pytest.raises(records.RecordError):
            list(records.decode_records(blob[:-3]))

    @given(st.lists(st.binary(min_size=0, max_size=500), max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_property_roundtrip(self, payloads):
        blob = b"".join(records.encode_record(p) for p in payloads)
        assert list(records.decode_records(blob)) == payloads


class TestImageCodec:
    @given(
        h=st.integers(1, 40), w=st.integers(1, 40), c=st.sampled_from([1, 3, 4])
    )
    @settings(max_examples=30, deadline=None)
    def test_property_image_roundtrip(self, h, w, c):
        rng = np.random.default_rng(0)
        img = rng.integers(0, 256, (h, w, c), dtype=np.uint8)
        out = records.decode_image(records.encode_image(img))
        np.testing.assert_array_equal(out, img)

    def test_bad_magic_raises(self):
        img = np.zeros((4, 4, 3), np.uint8)
        payload = bytearray(records.encode_image(img))
        payload[0] = ord(b"X")
        with pytest.raises(records.RecordError):
            records.decode_image(bytes(payload))

    def test_resize_identity(self):
        img = np.random.default_rng(0).random((16, 16, 3)).astype(np.float32)
        np.testing.assert_array_equal(records.resize_image(img, 16, 16), img)

    def test_resize_bilinear_constant(self):
        img = np.full((10, 12, 3), 7.0, np.float32)
        out = records.resize_image(img, 5, 20)
        assert out.shape == (5, 20, 3)
        np.testing.assert_allclose(out, 7.0, rtol=1e-6)

    def test_preprocess_dtype_and_range(self):
        img = np.random.default_rng(0).integers(0, 256, (30, 20, 3), dtype=np.uint8)
        out = records.preprocess_image(
            records.encode_image(img), 24, 24)
        assert out.dtype == np.float32 and out.shape == (24, 24, 3)
        assert out.min() >= 0.0 and out.max() <= 1.0


class TestWriters:
    def test_image_dataset_writer(self, tmp_storage):
        paths, labels = records.write_image_dataset(
            tmp_storage, 10, mean_hw=(16, 16), n_classes=5)
        assert len(paths) == len(labels) == 10
        img = records.preprocess_image(
            records.decode_single_record(tmp_storage.read_file(paths[0])), 8, 8)
        assert img.shape == (8, 8, 3)
        assert all(0 <= l < 5 for l in labels)

    def test_token_dataset_writer(self, tmp_storage):
        paths = records.write_token_dataset(tmp_storage, 3, 4, 32, 1000)
        shard = records.decode_token_shard(tmp_storage.read_file(paths[0]), 32)
        assert shard.shape == (4, 32)
        assert shard.min() >= 0 and shard.max() < 1000
