"""repro.metrics subsystem: registry, sketches, exporters, sampler, stall."""
import json
import os
import threading
import time
import tracemalloc

import pytest

from repro import metrics
from repro.metrics.export import _sanitize
from repro.metrics.registry import MetricsRegistry

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_fallback import given, settings, strategies as st


@pytest.fixture(autouse=True)
def _no_global_registry():
    """Each test starts and ends with no global registry installed."""
    metrics.stop()
    yield
    metrics.stop()


# ---------------------------------------------------------------------------
# name rendering
# ---------------------------------------------------------------------------
class TestNames:
    def test_render_parse_roundtrip(self):
        for name, labels in [
            ("a.b", ()),
            ("storage.read_bytes", (("tier", "hdd"),)),
            ("x", (("a", "1"), ("b", "2"))),
        ]:
            rendered = metrics.render_name(name, labels)
            assert metrics.parse_name(rendered) == (name, labels)

    def test_labels_canonically_sorted(self):
        reg = MetricsRegistry()
        reg.counter("c", b="2", a="1").inc(5)
        (key,) = reg.collect()["counters"]
        assert key == 'c{a="1",b="2"}'


# ---------------------------------------------------------------------------
# counters / gauges
# ---------------------------------------------------------------------------
class TestCounter:
    def test_inc_and_value(self):
        reg = MetricsRegistry()
        c = reg.counter("ops")
        c.inc()
        c.inc(2.5)
        assert c.value() == 3.5

    def test_negative_increment_rejected(self):
        with pytest.raises(ValueError):
            MetricsRegistry().counter("ops").inc(-1)

    def test_concurrent_increments_exact(self):
        """Many threads bumping the same counter must lose no increments —
        the per-thread-cell design's whole point."""
        reg = MetricsRegistry()
        c = reg.counter("ops")
        n_threads, per_thread = 8, 5000

        def work():
            for _ in range(per_thread):
                c.inc()

        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value() == n_threads * per_thread

    def test_same_key_same_instrument(self):
        reg = MetricsRegistry()
        assert reg.counter("x", tier="hdd") is reg.counter("x", tier="hdd")
        assert reg.counter("x", tier="hdd") is not reg.counter("x", tier="ssd")


class TestGauge:
    def test_set_and_add(self):
        reg = MetricsRegistry()
        g = reg.gauge("backlog")
        g.set(10)
        g.add(-3)
        assert g.value() == 7

    def test_function_gauge_polled_at_collect(self):
        reg = MetricsRegistry()
        state = {"v": 1}
        reg.register_gauge("depth", lambda: state["v"])
        assert reg.collect()["gauges"]["depth"] == 1
        state["v"] = 42
        assert reg.collect()["gauges"]["depth"] == 42

    def test_dead_provider_does_not_poison_collect(self):
        reg = MetricsRegistry()
        reg.register_gauge("bad", lambda: 1 / 0)
        reg.gauge("good").set(5)
        snap = reg.collect()
        assert "bad" not in snap["gauges"]
        assert snap["gauges"]["good"] == 5


# ---------------------------------------------------------------------------
# streaming histogram sketch
# ---------------------------------------------------------------------------
def true_quantile(xs, q):
    """Same rank semantics as hist_quantile: nearest lower rank."""
    import math

    s = sorted(xs)
    rank = max(0, math.ceil(q / 100.0 * len(s)) - 1)
    return s[rank]


class TestHistogram:
    def test_quantiles_within_alpha(self):
        reg = MetricsRegistry(alpha=0.05)
        h = reg.histogram("lat")
        xs = [0.001 * (i % 97 + 1) ** 2 for i in range(5000)]
        for v in xs:
            h.observe(v)
        for q in (50.0, 95.0, 99.0):
            est, true = h.quantile(q), true_quantile(xs, q)
            assert abs(est - true) / true <= 0.05 + 1e-9, (q, est, true)

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(1e-4, 1e4), min_size=1, max_size=100))
    def test_quantile_property(self, xs):
        h = MetricsRegistry(alpha=0.05).histogram("h")
        for v in xs:
            h.observe(v)
        for q in (0.0, 50.0, 95.0, 100.0):
            est, true = h.quantile(q), true_quantile(xs, q)
            assert abs(est - true) / true <= 0.05 + 1e-9

    def test_zero_and_negative_values(self):
        h = MetricsRegistry().histogram("h")
        for v in (-1.0, 0.0, 5.0):
            h.observe(v)
        snap = h.snapshot()
        assert snap["zero"] == 2
        assert snap["count"] == 3
        assert h.quantile(0.0) <= 0.0
        assert h.quantile(100.0) == pytest.approx(5.0, rel=0.05)

    def test_concurrent_observes_merge_exactly(self):
        """Thread shards must merge to the exact count/sum, quantiles
        within sketch error of the pooled sample."""
        h = MetricsRegistry(alpha=0.05).histogram("h")
        n_threads, per_thread = 6, 2000

        def work(k):
            for i in range(per_thread):
                h.observe(0.001 + ((k * per_thread + i) % 100) * 0.01)

        threads = [threading.Thread(target=work, args=(k,))
                   for k in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        xs = [0.001 + (j % 100) * 0.01 for j in range(n_threads * per_thread)]
        snap = h.snapshot()
        assert snap["count"] == len(xs)
        assert snap["sum"] == pytest.approx(sum(xs), rel=1e-6)
        for q in (50.0, 95.0, 99.0):
            est, true = h.quantile(q), true_quantile(xs, q)
            assert abs(est - true) / true <= 0.05 + 1e-9

    def test_merge_snapshots_equals_single_sketch(self):
        reg = MetricsRegistry(alpha=0.05)
        a, b, all_ = (reg.histogram(n) for n in ("a", "b", "all"))
        xs = [0.01 * (i + 1) for i in range(200)]
        for v in xs[:100]:
            a.observe(v)
            all_.observe(v)
        for v in xs[100:]:
            b.observe(v)
            all_.observe(v)
        merged = metrics.merge_hist_snapshots(a.snapshot(), b.snapshot())
        assert merged["buckets"] == all_.snapshot()["buckets"]
        assert merged["count"] == 200
        for q in (50.0, 99.0):
            assert metrics.hist_quantile(merged, q) == all_.quantile(q)

    def test_merge_gamma_mismatch_rejected(self):
        reg = MetricsRegistry()
        a = reg.histogram("a", alpha=0.05)
        b = reg.histogram("b", alpha=0.01)
        a.observe(1.0)
        b.observe(1.0)
        with pytest.raises(ValueError):
            metrics.merge_hist_snapshots(a.snapshot(), b.snapshot())

    def test_quantile_accepts_stringified_bucket_keys(self):
        h = MetricsRegistry().histogram("h")
        for v in (1.0, 2.0, 3.0):
            h.observe(v)
        snap = h.snapshot()
        snap["buckets"] = {str(k): v for k, v in snap["buckets"].items()}
        assert metrics.hist_quantile(snap, 50.0) == h.quantile(50.0)


# ---------------------------------------------------------------------------
# module-level API: enable/disable discipline
# ---------------------------------------------------------------------------
class TestModuleAPI:
    def test_disabled_hooks_are_noops(self):
        assert not metrics.enabled()
        metrics.inc("c")
        metrics.observe("h", 1.0)
        metrics.set_gauge("g", 1.0)
        metrics.add_gauge("g", 1.0)
        assert metrics.timer("t") is metrics.NULL_METRIC
        assert metrics.get_registry() is None

    def test_start_enables_and_stop_disables(self):
        reg = metrics.start()
        assert metrics.enabled()
        metrics.inc("c", 3)
        with metrics.timer("t"):
            pass
        snap = reg.collect()
        assert snap["counters"]["c"] == 3
        assert snap["histograms"]["t"]["count"] == 1
        assert metrics.stop() is reg
        assert not metrics.enabled()

    def test_start_enabled_false(self):
        metrics.start(enabled=False)
        metrics.inc("c")
        assert metrics.get_registry().collect()["counters"] == {}

    def test_persistent_gauge_provider_reattaches(self):
        """Providers registered while no registry exists (the process-global
        ReaderPool predates metrics.start()) attach to every new registry."""
        metrics.register_gauge("pool.depth", lambda: 7, pool="p0")
        try:
            reg = metrics.start()
            assert reg.collect()["gauges"]['pool.depth{pool="p0"}'] == 7
            metrics.stop()
            reg2 = metrics.start()
            assert reg2.collect()["gauges"]['pool.depth{pool="p0"}'] == 7
            metrics.unregister_gauge("pool.depth", pool="p0")
            assert 'pool.depth{pool="p0"}' not in reg2.collect()["gauges"]
        finally:
            metrics.unregister_gauge("pool.depth", pool="p0")

    def test_disabled_path_allocates_nothing(self):
        """10k disabled-path hook calls must not allocate meaningfully —
        the same bar as the tracer's NULL_SPAN fast path."""
        metrics.stop()
        for _ in range(100):  # warm up any lazy internals
            metrics.inc("c")
            with metrics.timer("t"):
                pass
        tracemalloc.start()
        for _ in range(10_000):
            metrics.inc("c", 2)
            metrics.observe("h", 0.5)
            metrics.set_gauge("g", 1.0)
            with metrics.timer("t"):
                pass
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert peak < 16_384, f"disabled metrics path allocated {peak} bytes"


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
class TestPrometheusExport:
    def _populated(self):
        reg = metrics.start()
        metrics.inc("storage.read_ops", 3, tier="hdd")
        metrics.inc("storage.read_ops", 1, tier="ssd")
        metrics.set_gauge("prefetch.occupancy", 2, it="0")
        for v in (0.001, 0.002, 0.004, 0.008):
            metrics.observe("storage.read_s", v, tier="hdd")
        return reg

    def test_counters_gauges_roundtrip(self):
        reg = self._populated()
        snap = reg.collect()
        parsed = metrics.from_prometheus_text(metrics.to_prometheus_text(reg))
        for rendered, v in snap["counters"].items():
            name, labels = metrics.parse_name(rendered)
            key = metrics.render_name(_sanitize(name), labels)
            assert parsed["counters"][key] == v
        for rendered, v in snap["gauges"].items():
            name, labels = metrics.parse_name(rendered)
            key = metrics.render_name(_sanitize(name), labels)
            assert parsed["gauges"][key] == v

    def test_histogram_le_form(self):
        reg = self._populated()
        snap = reg.collect()
        parsed = metrics.from_prometheus_text(metrics.to_prometheus_text(reg))
        h = parsed["histograms_le"]['storage_read_s{tier="hdd"}']
        hsnap = snap["histograms"]['storage.read_s{tier="hdd"}']
        assert h["count"] == hsnap["count"] == 4
        assert h["sum"] == pytest.approx(hsnap["sum"])
        # cumulative counts must be nondecreasing and end at count
        cums = [c for _, c in h["buckets"]]
        assert cums == sorted(cums)
        assert cums[-1] == h["count"]
        # le bounds match the sketch geometry: gamma ** idx
        les = [le for le, _ in h["buckets"]]
        assert les == sorted(les)

    def test_text_render_is_canonical(self):
        reg = self._populated()
        text = metrics.to_prometheus_text(reg)
        assert text == metrics.to_prometheus_text(reg.collect())
        assert "# TYPE storage_read_ops counter" in text
        # one TYPE line per family even with several labeled series
        assert text.count("# TYPE storage_read_ops counter") == 1


class TestJsonlExport:
    def test_snapshot_roundtrip_lossless(self):
        reg = metrics.start()
        for v in (0.001, 0.05, 0.4, 2.0):
            metrics.observe("lat", v)
        metrics.inc("ops", 9)
        snap = reg.collect()
        back = metrics.snapshot_from_json(metrics.snapshot_to_json(snap))
        assert back["counters"] == snap["counters"]
        assert back["histograms"]["lat"]["buckets"] == \
            snap["histograms"]["lat"]["buckets"]
        for q in (50.0, 95.0, 99.0):
            assert metrics.hist_quantile(back["histograms"]["lat"], q) == \
                metrics.hist_quantile(snap["histograms"]["lat"], q)

    def test_dump_load_jsonl(self, tmp_path):
        reg = metrics.start()
        metrics.inc("ops")
        snaps = [reg.collect(), reg.collect()]
        p = str(tmp_path / "series.jsonl")
        metrics.dump_jsonl(snaps, p)
        back = metrics.load_jsonl(p)
        assert len(back) == 2
        assert back[0]["counters"] == snaps[0]["counters"]

    def test_series_markdown_renders(self):
        reg = metrics.start()
        metrics.set_gauge("occ", 3)
        metrics.inc("ops", 5)
        metrics.observe("lat", 0.01)
        lines = metrics.series_markdown([reg.collect(), reg.collect()])
        text = "\n".join(lines)
        assert "`occ`" in text and "`ops`" in text and "`lat`" in text


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------
class TestSampler:
    def test_collects_series_and_jsonl(self, tmp_path):
        reg = metrics.start()
        p = str(tmp_path / "m.jsonl")
        sampler = metrics.Sampler(interval_s=0.02, jsonl_path=p)
        sampler.start()
        for i in range(5):
            metrics.inc("ticks")
            time.sleep(0.02)
        sampler.stop()
        pts = sampler.points()
        assert len(pts) >= 1
        assert pts[-1]["counters"]["ticks"] == 5
        loaded = metrics.load_jsonl(p)
        assert len(loaded) == len(pts)
        assert loaded[-1]["counters"]["ticks"] == 5
        # timestamps monotone nondecreasing
        ts = [s["t"] for s in pts]
        assert ts == sorted(ts)

    def test_short_run_still_lands_a_point(self):
        metrics.start()
        sampler = metrics.Sampler(interval_s=60.0)
        sampler.start()
        metrics.inc("c")
        sampler.stop()
        assert len(sampler.points()) == 1

    def test_no_registry_no_points(self):
        sampler = metrics.Sampler(interval_s=0.01)
        sampler.start()
        time.sleep(0.05)
        sampler.stop()
        assert sampler.points() == []

    def test_invalid_interval(self):
        with pytest.raises(ValueError):
            metrics.Sampler(interval_s=0.0)


# ---------------------------------------------------------------------------
# stall detection
# ---------------------------------------------------------------------------
class TestStallDetector:
    def test_trips_on_injected_slow_step_and_dumps_snapshot(self, tmp_path):
        metrics.start()
        metrics.inc("pipeline.records", 100)
        det = metrics.StallDetector(window=16, quantile=95.0, factor=3.0,
                                    min_samples=4,
                                    snapshot_dir=str(tmp_path))
        for i in range(8):
            assert det.observe(i, 0.010) is None
        ev = det.observe(8, 0.200)  # 20x baseline: must trip
        assert ev is not None
        assert ev.step == 8
        assert ev.duration_s == pytest.approx(0.200)
        assert ev.threshold_s == pytest.approx(0.030, rel=0.01)
        # the snapshot carries the live registry state
        assert ev.snapshot["metrics"]["counters"]["pipeline.records"] == 100
        dump = tmp_path / "stall_step8.json"
        assert dump.exists()
        data = json.loads(dump.read_text())
        assert data["step"] == 8
        assert data["snapshot"]["metrics"]["counters"][
            "pipeline.records"] == 100

    def test_tripped_step_excluded_from_baseline(self):
        det = metrics.StallDetector(window=16, factor=3.0, min_samples=4)
        for i in range(8):
            det.observe(i, 0.010)
        assert det.observe(8, 1.0) is not None     # stall
        assert det.observe(9, 0.010) is None        # normal step still normal
        assert det.observe(10, 1.0) is not None     # baseline not inflated
        assert det.summary()["stalls"] == 2
        assert det.summary()["steps"] == [8, 10]

    def test_no_trip_before_min_samples(self):
        det = metrics.StallDetector(min_samples=8)
        for i in range(7):
            assert det.observe(i, 10.0 if i == 5 else 0.01) is None

    def test_on_stall_callback(self):
        seen = []
        det = metrics.StallDetector(min_samples=2, window=4,
                                    on_stall=seen.append)
        det.observe(0, 0.01)
        det.observe(1, 0.01)
        det.observe(2, 5.0)
        assert [e.step for e in seen] == [2]

    def test_validation(self):
        with pytest.raises(ValueError):
            metrics.StallDetector(window=1)
        with pytest.raises(ValueError):
            metrics.StallDetector(factor=1.0)


# ---------------------------------------------------------------------------
# subsystem integration: the wired-through producers
# ---------------------------------------------------------------------------
class TestInstrumentation:
    def test_storage_per_tier_counters_and_latency(self, tmp_path):
        from repro.core.storage import NativeStorage

        metrics.start()
        st = NativeStorage(str(tmp_path))
        st.write_file("a.bin", b"x" * 1000)
        st.read_file("a.bin")
        st.read_range("a.bin", 0, 100)
        snap = metrics.get_registry().collect()
        assert snap["counters"]['storage.read_ops{tier="native"}'] == 2
        assert snap["counters"]['storage.read_bytes{tier="native"}'] == 1100
        assert snap["counters"]['storage.write_bytes{tier="native"}'] == 1000
        assert snap["histograms"]['storage.read_s{tier="native"}'][
            "count"] == 2

    def test_fault_injection_counter(self, tmp_path):
        from repro.core.faults import FaultInjected, FaultyStorage
        from repro.core.storage import NativeStorage

        metrics.start()
        faulty = FaultyStorage(NativeStorage(str(tmp_path)))
        faulty.fail_after(0)
        with pytest.raises(FaultInjected):
            faulty.write_file("x.bin", b"data")
        snap = metrics.get_registry().collect()
        assert snap["counters"][
            'storage.faults_injected{op="write_file"}'] == 1

    def test_prefetcher_occupancy_and_counters(self):
        from repro.core.prefetcher import PrefetchIterator

        metrics.start()
        it = PrefetchIterator(iter(range(20)), buffer_size=4)
        assert list(it) == list(range(20))
        it.close(timeout=5.0)
        snap = metrics.get_registry().collect()
        produced = [v for k, v in snap["counters"].items()
                    if k.startswith("prefetch.produced")]
        consumed = [v for k, v in snap["counters"].items()
                    if k.startswith("prefetch.consumed")]
        assert sum(produced) == 20
        assert sum(consumed) == 20
        waits = [h for k, h in snap["histograms"].items()
                 if k.startswith("prefetch.consumer_wait_s")]
        assert waits and waits[0]["count"] == 20

    def test_readerpool_gauges_lifecycle(self):
        from repro.core.readerpool import ReaderPool

        metrics.start()
        pool = ReaderPool(name="testpool")
        pool.ensure(2)
        futs = [pool.submit(lambda x=i: x * 2) for i in range(10)]
        assert sorted(f.result() for f in futs) == [i * 2 for i in range(10)]
        snap = metrics.get_registry().collect()
        size = [v for k, v in snap["gauges"].items()
                if k.startswith("readerpool.size")
                and "testpool" in k]
        assert size == [2]
        assert snap["counters"]["readerpool.submitted"] == 10
        pool.shutdown()
        snap = metrics.get_registry().collect()
        assert not any(k.startswith("readerpool.size") and "testpool" in k
                       for k in snap["gauges"])

    def test_pipeline_records_and_drops(self, tmp_storage):
        from repro.core import records
        from repro.core.dataset import Dataset

        metrics.start()
        paths, labels = records.write_image_dataset(
            tmp_storage, 8, mean_hw=(8, 8))
        n_ok = 0
        calls = {"n": 0}

        def decode(p):
            calls["n"] += 1
            if calls["n"] % 4 == 0:
                raise ValueError("corrupt")
            return p

        ds = Dataset.from_tensor_slices(paths).map(decode).ignore_errors()
        n_ok = sum(1 for _ in ds)
        snap = metrics.get_registry().collect()
        assert snap["counters"]["pipeline.records"] == n_ok
        assert snap["counters"]["pipeline.dropped"] == 8 - n_ok
        # the latency timer covers every decode attempt, failures included
        assert snap["histograms"]["pipeline.decode_s"]["count"] == 8


class TestTraceReportAttachment:
    def test_overlap_line_omitted_without_compute_busy_time(self):
        """Read-only runs (fig5) and zero-duration compute spans must not
        print a misleading 0.00% overlap line."""
        from repro import trace
        from repro.trace.tracer import SpanRecord

        def mkspan(stage, t0, dur):
            return SpanRecord(stage=stage, name="", tid=1, thread="t1",
                              t0=t0, dur=dur, nbytes=0)

        read_only = [mkspan(trace.STAGE_STORAGE_READ, 0.0, 1.0)]
        assert "overlap" not in trace.to_markdown(read_only)
        zero_compute = read_only + [mkspan(trace.STAGE_COMPUTE, 1.0, 0.0)]
        assert "overlap" not in trace.to_markdown(zero_compute)
        assert trace.overlap_ratio(zero_compute) == 0.0
        real = read_only + [mkspan(trace.STAGE_COMPUTE, 0.5, 1.0)]
        assert "overlap" in trace.to_markdown(real)

    def test_metrics_series_attaches_to_markdown(self):
        from repro import trace
        from repro.trace.tracer import SpanRecord

        metrics.start()
        metrics.set_gauge("prefetch.occupancy", 3)
        metrics.inc("pipeline.records", 12)
        series = [metrics.get_registry().collect()]
        spans = [SpanRecord(stage=trace.STAGE_STORAGE_READ, name="", tid=1,
                            thread="t1", t0=0.0, dur=0.5, nbytes=100)]
        md = trace.to_markdown(spans, metrics_series=series)
        assert "## Metrics timeline" in md
        assert "prefetch.occupancy" in md
        assert "pipeline.records" in md


class TestTrainerHeartbeat:
    def _run_trainer(self, stall_detector=None, slow_at=None):
        import numpy as np

        from repro.train.trainer import Trainer

        def train_step(state, batch):
            if slow_at is not None and int(state["step"]) == slow_at:
                time.sleep(0.25)
            else:
                time.sleep(0.002)
            return ({"step": state["step"] + 1},
                    {"loss": np.float32(0.0)})

        tr = Trainer(train_step, {"step": np.int32(0)},
                     iter([(i,) for i in range(40)]),
                     stall_detector=stall_detector)
        tr.run(30)
        return tr

    def test_per_step_heartbeat_metrics(self):
        metrics.start()
        self._run_trainer()
        snap = metrics.get_registry().collect()
        assert snap["counters"]["trainer.steps"] == 30
        assert snap["histograms"]["trainer.compute_s"]["count"] == 30
        assert snap["histograms"]["trainer.data_wait_s"]["count"] == 30
        assert snap["gauges"]["trainer.last_step"] == 30
        assert snap["gauges"]["trainer.step_s"] > 0

    def test_stall_detector_trips_in_trainer(self, tmp_path):
        metrics.start()
        det = metrics.StallDetector(window=32, min_samples=8, factor=3.0,
                                    snapshot_dir=str(tmp_path))
        tr = self._run_trainer(stall_detector=det, slow_at=20)
        assert det.summary()["stalls"] == 1
        (ev,) = det.events
        assert ev.duration_s > ev.threshold_s
        assert ev.snapshot["metrics"]["counters"]["trainer.steps"] >= 8
        assert list(tmp_path.glob("stall_step*.json"))
        assert tr.report()["stalls"]["stalls"] == 1
