"""tf.data-like pipeline semantics (paper §II-A)."""
import threading
import time

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.dataset import Dataset


class TestBasics:
    def test_from_tensor_slices_order(self):
        assert list(Dataset.from_tensor_slices([3, 1, 2])) == [3, 1, 2]

    def test_take_repeat(self):
        assert list(Dataset.range(3).repeat(2)) == [0, 1, 2, 0, 1, 2]
        assert list(Dataset.range(10).take(4)) == [0, 1, 2, 3]

    def test_batch_shapes(self):
        batches = list(Dataset.range(10).batch(3))
        assert [b.shape for b in batches] == [(3,), (3,), (3,)]  # drop remainder
        batches = list(Dataset.range(10).batch(3, drop_remainder=False))
        assert batches[-1].shape == (1,)

    def test_batch_pytree(self):
        ds = Dataset.from_tensor_slices(
            [(np.ones(2) * i, np.int32(i)) for i in range(4)]
        ).batch(2)
        imgs, labels = next(iter(ds))
        assert imgs.shape == (2, 2) and labels.shape == (2,)


class TestShuffle:
    @given(st.integers(0, 2**31 - 1), st.integers(1, 64))
    @settings(max_examples=20, deadline=None)
    def test_shuffle_is_permutation(self, seed, buf):
        items = list(range(100))
        out = list(Dataset.from_tensor_slices(items).shuffle(buf, seed=seed))
        assert sorted(out) == items

    def test_shuffle_deterministic_by_seed(self):
        a = list(Dataset.range(50).shuffle(16, seed=7))
        b = list(Dataset.range(50).shuffle(16, seed=7))
        c = list(Dataset.range(50).shuffle(16, seed=8))
        assert a == b
        assert a != c  # astronomically unlikely to collide

    def test_shuffle_actually_shuffles(self):
        out = list(Dataset.range(100).shuffle(100, seed=0))
        assert out != list(range(100))


class TestMap:
    def test_map_serial(self):
        assert list(Dataset.range(4).map(lambda x: x * 2)) == [0, 2, 4, 6]

    @pytest.mark.parametrize("threads", [2, 4])
    def test_map_parallel_deterministic_order(self, threads):
        out = list(Dataset.range(20).map(
            lambda x: x * 10, num_parallel_calls=threads))
        assert out == [x * 10 for x in range(20)]

    def test_map_parallel_completion_order_is_complete(self):
        def slow_even(x):
            time.sleep(0.02 if x % 2 == 0 else 0.0)
            return x

        out = list(Dataset.range(16).map(
            slow_even, num_parallel_calls=4, deterministic=False))
        assert sorted(out) == list(range(16))

    def test_map_parallel_uses_threads(self):
        """8 sleeps of 50ms on 8 threads must take far less than 400ms."""
        def slow(x):
            time.sleep(0.05)
            return x

        t0 = time.monotonic()
        out = list(Dataset.range(8).map(slow, num_parallel_calls=8))
        elapsed = time.monotonic() - t0
        assert sorted(out) == list(range(8))
        assert elapsed < 0.25, f"no thread overlap: {elapsed:.3f}s"


class TestErrorHandling:
    def test_ignore_errors_drops_bad(self):
        def maybe_fail(x):
            if x % 3 == 0:
                raise ValueError("boom")
            return x

        out = list(Dataset.range(10).map(maybe_fail).ignore_errors())
        assert out == [x for x in range(10) if x % 3 != 0]

    def test_error_propagates_without_ignore(self):
        def fail(x):
            raise RuntimeError("boom")

        with pytest.raises(RuntimeError):
            list(Dataset.range(3).map(fail))


class TestCachePrefetch:
    def test_cache_second_epoch_no_recompute(self):
        calls = []

        def f(x):
            calls.append(x)
            return x

        ds = Dataset.range(5).map(f).cache()
        assert list(ds) == list(range(5))
        assert list(ds) == list(range(5))
        assert len(calls) == 5  # second epoch served from memory

    def test_prefetch_preserves_stream(self):
        out = list(Dataset.range(100).prefetch(4))
        assert out == list(range(100))

    def test_prefetch_error_propagates(self):
        def fail(x):
            if x == 5:
                raise RuntimeError("boom")
            return x

        with pytest.raises(RuntimeError):
            list(Dataset.range(10).map(fail).prefetch(2))


# ---------------------------------------------------------------------------
# O(1) iterator resume (PR 10): interleave_order replica, seekable shard
# streaming, ResumableIterator seek
# ---------------------------------------------------------------------------
from repro.core.dataset import (ResumableIterator, interleave_order,
                                sharded_record_dataset)
from repro.core.faults import FaultyStorage
from repro.core.storage import NativeStorage


class TestInterleaveOrder:
    def _real_order(self, counts, cyc, blk):
        """Ground truth: run the actual interleave over (src, idx) pairs."""
        ds = Dataset.from_tensor_slices(list(range(len(counts)))).interleave(
            lambda s: iter([(s, i) for i in range(counts[s])]),
            cycle_length=cyc, block_length=blk)
        return list(ds)

    @pytest.mark.parametrize("counts,cyc,blk", [
        ([4, 4, 4], 2, 2),        # exact block multiples (empty-turn case)
        ([5, 3, 7, 2, 6], 3, 2),  # uneven tails
        ([8], 4, 3),              # single source, cycle > sources
        ([2, 2], 4, 1),
        ([0, 3, 4], 2, 2),        # empty source retires on first turn
        ([3, 0, 0, 5, 1], 2, 3),
        ([6, 6], 1, 4),           # degenerate cycle: pure concatenation
    ])
    def test_matches_real_interleave(self, counts, cyc, blk):
        assert interleave_order(counts, cyc, blk) == \
            self._real_order(counts, cyc, blk)

    @given(st.lists(st.integers(0, 9), min_size=1, max_size=6),
           st.integers(1, 5), st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_matches_real_interleave_property(self, counts, cyc, blk):
        assert interleave_order(counts, cyc, blk) == \
            self._real_order(counts, cyc, blk)

    def test_validates_args(self):
        with pytest.raises(ValueError):
            interleave_order([1], cycle_length=0)
        with pytest.raises(ValueError):
            interleave_order([1], block_length=0)


class TestShardedRecordDataset:
    REC = 8

    def _mk_shards(self, storage, byte_sizes):
        paths = []
        for j, n in enumerate(byte_sizes):
            p = f"data/shard{j}.rec"
            storage.write_file(p, bytes((j * 16 + k) % 251 for k in range(n)))
            paths.append(p)
        return paths

    def test_seek_tail_matches_full_stream(self, tmp_storage):
        # short final records at 20 (4 bytes) and 33 (1 byte)
        paths = self._mk_shards(tmp_storage, [24, 20, 8, 33, 16])
        full = list(sharded_record_dataset(
            tmp_storage, paths, self.REC, cycle_length=2, block_length=2,
            seed=3))
        n = len(full)
        for start in (1, 3, n // 2, n - 1, n, n + 7):
            tail = list(sharded_record_dataset(
                tmp_storage, paths, self.REC, cycle_length=2, block_length=2,
                seed=3, start=start))
            assert tail == full[start:], f"start={start}"

    def test_seek_reads_no_skipped_records(self, tmp_storage):
        """Positioning is arithmetic: only the tail's records are read."""
        paths = self._mk_shards(tmp_storage, [24, 20, 8, 33, 16])
        full = list(sharded_record_dataset(tmp_storage, paths, self.REC,
                                           seed=1))
        counting = FaultyStorage(tmp_storage)  # unarmed: just an op log
        start = len(full) - 3
        tail = list(sharded_record_dataset(counting, paths, self.REC,
                                           seed=1, start=start))
        assert tail == full[start:]
        reads = [e for e in counting.op_log if e[0].startswith("read")]
        assert len(reads) == len(full) - start  # zero reads for the skip

    def test_seed_changes_order(self, tmp_storage):
        paths = self._mk_shards(tmp_storage, [32, 32, 32, 32, 32, 32])
        a = list(sharded_record_dataset(tmp_storage, paths, self.REC, seed=0))
        b = list(sharded_record_dataset(tmp_storage, paths, self.REC, seed=5))
        assert sorted(a) == sorted(b) and a != b


class TestResumableIteratorSeek:
    DATA = [[f"e{e}r{i}" for i in range(10)] for e in range(3)]

    def _seekable(self):
        data = self.DATA
        return lambda ep, start=0: Dataset.from_tensor_slices(
            data[ep % len(data)][start:])

    def _replay_only(self):
        data = self.DATA
        return lambda ep: Dataset.from_tensor_slices(data[ep % len(data)])

    def test_seekability_detected_from_signature(self):
        assert ResumableIterator(self._seekable()).state().get("seek") is True
        assert "seek" not in ResumableIterator(self._replay_only()).state()
        assert "seek" not in ResumableIterator(
            Dataset.range(4)).state()  # plain Dataset: never seekable

    def test_seek_restore_equals_replay_restore(self):
        it = ResumableIterator(self._seekable(), epochs=2)
        head = [next(it) for _ in range(7)]
        st = it.state()
        rest = list(it)  # uninterrupted continuation = ground truth

        seeked = ResumableIterator(self._seekable(), epochs=2)
        seeked.restore_state(st)
        assert list(seeked) == rest

        replayed = ResumableIterator(self._replay_only(), epochs=2)
        replayed.restore_state(st)  # same dict, "seek" key ignored
        assert list(replayed) == rest
        assert head == self.DATA[0][:7]

    def test_seek_restore_counts_metric(self):
        from repro import metrics

        it = ResumableIterator(self._seekable())
        reg = metrics.start()
        try:
            it.restore_state({"epoch": 0, "offset": 4, "version": 1})
            counters = reg.collect()["counters"]
            assert sum(v for k, v in counters.items()
                       if k.startswith("pipeline.resume_seeks")) == 1
            assert not any(k.startswith("pipeline.resume_skipped")
                           for k in counters)
        finally:
            metrics.stop()
        assert next(it) == self.DATA[0][4]

    def test_seek_past_epoch_end_rolls_epoch(self):
        it = ResumableIterator(self._seekable(), epochs=2)
        it.restore_state({"epoch": 0, "offset": len(self.DATA[0]),
                          "version": 1})
        assert next(it) == self.DATA[1][0]

    def test_e2e_sharded_factory_seek_without_replay_io(self, tmp_storage):
        rec = 8
        paths = []
        for j in range(4):
            p = f"data/s{j}.rec"
            tmp_storage.write_file(p, bytes(range(j * 32, j * 32 + 32)))
            paths.append(p)

        def factory_on(storage):
            return lambda ep, start=0: sharded_record_dataset(
                storage, paths, rec, cycle_length=2, block_length=2,
                seed=ep, start=start)

        it = ResumableIterator(factory_on(tmp_storage), epochs=1)
        head = [next(it) for _ in range(9)]
        st = it.state()
        rest = list(it)

        counting = FaultyStorage(tmp_storage)
        it2 = ResumableIterator(factory_on(counting), epochs=1)
        it2.restore_state(st)
        assert list(it2) == rest
        reads = [e for e in counting.op_log if e[0].startswith("read")]
        assert len(reads) == len(rest)  # none of the 9 head records re-read
        assert len(head) == 9
