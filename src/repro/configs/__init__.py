"""Architecture registry: ``--arch <id>`` resolves here."""
from .base import ModelConfig, InputShape, SHAPES, runnable_cells

from . import (
    seamless_m4t_medium,
    granite_moe_3b_a800m,
    mixtral_8x22b,
    qwen2_vl_7b,
    phi3_medium_14b,
    deepseek_coder_33b,
    gemma3_4b,
    qwen3_4b,
    mamba2_2p7b,
    jamba_1p5_large_398b,
    alexnet_mini,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        seamless_m4t_medium,
        granite_moe_3b_a800m,
        mixtral_8x22b,
        qwen2_vl_7b,
        phi3_medium_14b,
        deepseek_coder_33b,
        gemma3_4b,
        qwen3_4b,
        mamba2_2p7b,
        jamba_1p5_large_398b,
    )
}

ALEXNET = alexnet_mini.CONFIG
ALEXNET_SMOKE = alexnet_mini.SMOKE


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ModelConfig", "InputShape", "SHAPES", "ARCHS", "get_config",
    "runnable_cells", "ALEXNET", "ALEXNET_SMOKE",
]
