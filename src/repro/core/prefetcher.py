"""Prefetching — the paper's key input-pipeline mechanism (§II-A.2).

The paper documents TensorFlow's prefetcher as: a background thread holding a
double-ended queue buffer, waiting on a condition variable; the consumer pops
elements and notifies the thread, which wakes up and fetches more from the
upstream operation.  :class:`PrefetchIterator` is precisely that structure.

:func:`prefetch_to_device` extends the idea across the PCIe/host boundary
(which TF 1.10 did not): batches are moved onto the accelerator (with an
optional sharding) ``size`` steps ahead, so host->HBM transfer also overlaps
with the device step.

Lifecycle: ``close()`` stops the producer thread promptly (no waiting for
GC) and closes the upstream iterator chain from the producer's own thread —
dataset iterators propagate their ``close()`` here, so an abandoned
pipeline releases its background thread end-to-end.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Iterable, Iterator, Optional

from .. import metrics, trace


class _Sentinel:
    pass


_END = _Sentinel()

_iter_ids = itertools.count()


class PrefetchIterator:
    """Background-thread prefetcher: deque + condition variable (TF design)."""

    def __init__(self, upstream: Iterable, buffer_size: int = 1):
        if buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")
        self._upstream = iter(upstream)
        self._buffer_size = buffer_size
        self._buffer: deque = deque()
        self._mid = next(_iter_ids)  # metrics label: one series per iterator
        self._cond = threading.Condition()
        self._done = False          # producer finished (or errored)
        self._error: Optional[BaseException] = None
        self._closed = False
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    # -- producer ------------------------------------------------------------
    def _run(self) -> None:
        try:
            while True:
                # span covers only the upstream pull (the background work the
                # prefetcher exists to overlap), not the buffer-full wait
                with trace.span(trace.STAGE_PREFETCH, "fetch"):
                    try:
                        item = next(self._upstream)
                    except StopIteration:
                        return
                m = metrics.enabled()
                t0 = time.monotonic() if m else 0.0
                with self._cond:
                    while len(self._buffer) >= self._buffer_size and not self._closed:
                        self._cond.wait()
                    if self._closed:
                        return
                    self._buffer.append(item)
                    trace.count("prefetch_buffer", len(self._buffer))
                    if m:
                        # producer stall: buffer full, consumer too slow —
                        # the healthy state (compute-bound training)
                        metrics.observe("prefetch.producer_stall_s",
                                        time.monotonic() - t0, it=self._mid)
                        metrics.inc("prefetch.produced", 1, it=self._mid)
                        metrics.set_gauge("prefetch.occupancy",
                                          len(self._buffer), it=self._mid)
                    self._cond.notify_all()
        except BaseException as e:  # propagate to consumer
            with self._cond:
                self._error = e
        finally:
            # tear down the upstream chain from the thread that owns it
            # (propagates close through map/interleave nodes when the
            # consumer abandons the pipeline)
            close = getattr(self._upstream, "close", None)
            if close is not None:
                try:
                    close()
                except Exception:
                    pass
            with self._cond:
                self._done = True
                self._cond.notify_all()

    # -- consumer --------------------------------------------------------------
    def __iter__(self) -> "PrefetchIterator":
        return self

    def __next__(self) -> Any:
        m = metrics.enabled()
        t0 = time.monotonic() if m else 0.0
        with self._cond:
            while not self._buffer and not self._done:
                self._cond.wait()
            if self._buffer:
                item = self._buffer.popleft()
                if m:
                    # consumer wait: buffer starved, producer too slow —
                    # the paper's data-wait observable, live per element
                    metrics.observe("prefetch.consumer_wait_s",
                                    time.monotonic() - t0, it=self._mid)
                    metrics.inc("prefetch.consumed", 1, it=self._mid)
                    metrics.set_gauge("prefetch.occupancy",
                                      len(self._buffer), it=self._mid)
                self._cond.notify_all()
                return item
            if self._error is not None:
                err, self._error = self._error, None
                raise err
            raise StopIteration

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop the producer thread and release the upstream chain.

        Idempotent; with ``timeout`` the call also joins the producer thread
        (used by the no-leaked-threads regression tests).  Called
        automatically when a downstream dataset iterator is closed.
        """
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if timeout is not None:
            self._thread.join(timeout)

    def __del__(self):  # pragma: no cover - best effort
        try:
            self.close()
        except Exception:
            pass


def prefetch_to_device(iterator: Iterable, size: int = 2, sharding=None) -> Iterator:
    """Move batches onto device ``size`` steps ahead of consumption.

    Each element may be an array or a pytree of arrays.  With a
    ``jax.sharding.Sharding`` the put is a sharded device_put (multi-chip);
    otherwise a plain device_put.  Transfers are issued asynchronously by
    JAX, so keeping a queue of in-flight puts overlaps H2D with compute.
    """
    import jax

    queue: deque = deque()

    def _put(batch):
        if sharding is not None:
            return jax.tree.map(lambda x: jax.device_put(x, sharding), batch)
        return jax.tree.map(jax.device_put, batch)

    it = iter(iterator)
    try:
        for _ in range(size):
            queue.append(_put(next(it)))
    except StopIteration:
        pass
    while queue:
        out = queue.popleft()
        try:
            queue.append(_put(next(it)))
        except StopIteration:
            pass
        yield out
