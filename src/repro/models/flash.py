"""Memory-lean attention with a hand-written (flash-style) VJP.

Differentiating the naive chunked-scan attention stores every KV-chunk's
probability block for the backward pass — O(Sq*Skv) residuals, the single
biggest memory term in the train-cell dry-runs.  This implementation keeps
the standard flash contract instead:

  forward : online softmax over KV chunks; saves only (q, k, v, o, lse)
  backward: recomputes p = exp(s - lse) chunk by chunk;
            dv += p^T do ; ds = p * (do v^T - D) ; dq += ds k ; dk += ds^T q

Sharding note: GQA is handled by *broadcasting* KV heads to the full H
(4D einsums ``bqhd,bkhd->bqhk`` throughout).  The grouped 5D layout
(B,S,Hkv,g,hd) looks cheaper but splits the sharded H dim into (Hkv, g) —
neither divisible by the 16-way model axis — and GSPMD responds with
involuntary full rematerialization inside the scan (measured +25 GiB/device
on mixtral train).  The KV-head gradient reduces the broadcast at the end.

``window`` (SWA / gemma3 local:global) may be a traced scalar.  fp32
accumulation throughout; bf16 in/out.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array
NEG_INF = -1e30


def _penalty_for(q_pos, kv_pos, causal: bool, has_window: bool, window):
    """Additive f32 mask (0 / NEG_INF), shape (qc, kc).

    An additive penalty instead of a boolean ``where``: XLA hoists the
    layer-invariant mask out of the layer loop, and the select form gets
    materialized broadcast over (B, H) — >1 GiB/device carried through the
    whole backward scan.  The (qc, kc) f32 penalty stays 1 MB."""
    ok = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        ok &= kv_pos[None, :] <= q_pos[:, None]
    if has_window:
        ok &= kv_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, has_window: bool, q_chunk: int, kv_chunk: int,
                group: int):
    """Build the custom-vjp attention for one static configuration."""

    def _broadcast_kv(k):
        if group == 1:
            return k
        return jnp.repeat(k, group, axis=2)          # (B,Skv,H,hd)

    def fwd_impl(q, k, v, window):
        # q: (B,Sq,H,hd); k/v: (B,Skv,Hkv,hd)
        B, Sq, H, hd = q.shape
        Skv = k.shape[1]
        scale = 1.0 / math.sqrt(hd)
        qf = q.astype(jnp.float32) * scale
        kb, vb = _broadcast_kv(k), _broadcast_kv(v)
        nq, nk = Sq // q_chunk, Skv // kv_chunk
        kc = jnp.moveaxis(kb.reshape(B, nk, kv_chunk, H, hd), 1, 0)
        vc = jnp.moveaxis(vb.reshape(B, nk, kv_chunk, H, hd), 1, 0)
        kv_pos_all = jnp.arange(Skv).reshape(nk, kv_chunk)

        def one(qi):
            q_blk = lax.dynamic_slice_in_dim(qf, qi * q_chunk, q_chunk, 1)
            q_pos = qi * q_chunk + jnp.arange(q_chunk)
            init = (
                jnp.zeros((B, q_chunk, H, hd), jnp.float32),
                jnp.full((B, q_chunk, H), NEG_INF, jnp.float32),
                jnp.zeros((B, q_chunk, H), jnp.float32),
            )

            def body(carry, xs):
                acc, m, l = carry
                k_blk, v_blk, kv_pos = xs
                s = jnp.einsum("bqhd,bkhd->bqhk", q_blk,
                               k_blk.astype(jnp.float32))
                s = s + _penalty_for(q_pos, kv_pos, causal, has_window,
                                     window)[None, :, None, :]
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bqhk,bkhd->bqhd", p, v_blk.astype(jnp.float32))
                return (acc_new, m_new, l_new), None

            (acc, m, l), _ = lax.scan(body, init, (kc, vc, kv_pos_all))
            l = jnp.maximum(l, 1e-30)
            return acc / l[..., None], m + jnp.log(l)

        o_chunks, lse_chunks = lax.map(one, jnp.arange(nq))
        o = jnp.moveaxis(o_chunks, 0, 1).reshape(B, Sq, H, hd)
        lse = jnp.moveaxis(lse_chunks, 0, 1).reshape(B, Sq, H)
        return o, lse

    def f(q, k, v, window):
        o, _ = fwd_impl(q, k, v, window)
        return o.astype(q.dtype)

    def f_fwd(q, k, v, window):
        o, lse = fwd_impl(q, k, v, window)
        o16 = o.astype(q.dtype)
        return o16, (q, k, v, window, o16, lse)

    def f_bwd(res, do):
        q, k, v, window, o, lse = res
        B, Sq, H, hd = q.shape
        Skv, Hkv = k.shape[1], k.shape[2]
        scale = 1.0 / math.sqrt(hd)
        nq, nk = Sq // q_chunk, Skv // kv_chunk

        dof = do.astype(jnp.float32)
        qf = q.astype(jnp.float32)
        Df = (dof * o.astype(jnp.float32)).sum(-1)           # (B,Sq,H)

        kb, vb = _broadcast_kv(k), _broadcast_kv(v)
        kc = jnp.moveaxis(kb.reshape(B, nk, kv_chunk, H, hd), 1, 0)
        vc = jnp.moveaxis(vb.reshape(B, nk, kv_chunk, H, hd), 1, 0)
        kv_pos_all = jnp.arange(Skv).reshape(nk, kv_chunk)

        def q_body(carry, qi):
            dk_acc, dv_acc = carry                # (nk,B,kc,H,hd) f32
            sl = lambda x: lax.dynamic_slice_in_dim(x, qi * q_chunk, q_chunk, 1)
            q_blk, do_blk = sl(qf), sl(dof)
            lse_blk, D_blk = sl(lse), sl(Df)
            q_pos = qi * q_chunk + jnp.arange(q_chunk)

            def kv_body(dq_blk, xs):
                k_blk, v_blk, kv_pos, dk_blk, dv_blk = xs
                kf = k_blk.astype(jnp.float32)
                vf = v_blk.astype(jnp.float32)
                s = scale * jnp.einsum("bqhd,bkhd->bqhk", q_blk, kf)
                s = s + _penalty_for(q_pos, kv_pos, causal, has_window,
                                     window)[None, :, None, :]
                p = jnp.exp(s - lse_blk[..., None])          # (B,qc,H,kc)
                dv_new = dv_blk + jnp.einsum("bqhk,bqhd->bkhd", p, do_blk)
                dp = jnp.einsum("bqhd,bkhd->bqhk", do_blk, vf)
                ds = p * (dp - D_blk[..., None])
                dq_blk = dq_blk + scale * jnp.einsum(
                    "bqhk,bkhd->bqhd", ds, kf)
                dk_new = dk_blk + scale * jnp.einsum(
                    "bqhk,bqhd->bkhd", ds, q_blk)
                return dq_blk, (dk_new, dv_new)

            dq0 = jnp.zeros_like(q_blk)
            dq_blk, (dk_acc, dv_acc) = lax.scan(
                kv_body, dq0, (kc, vc, kv_pos_all, dk_acc, dv_acc))
            return (dk_acc, dv_acc), dq_blk

        dk0 = jnp.zeros((nk, B, kv_chunk, H, hd), jnp.float32)
        dv0 = jnp.zeros((nk, B, kv_chunk, H, hd), jnp.float32)
        (dk_acc, dv_acc), dq_chunks = lax.scan(
            q_body, (dk0, dv0), jnp.arange(nq))
        dq = jnp.moveaxis(dq_chunks, 0, 1).reshape(B, Sq, H, hd).astype(q.dtype)
        dkb = jnp.moveaxis(dk_acc, 0, 1).reshape(B, Skv, H, hd)
        dvb = jnp.moveaxis(dv_acc, 0, 1).reshape(B, Skv, H, hd)
        if group > 1:
            dkb = dkb.reshape(B, Skv, Hkv, group, hd).sum(3)
            dvb = dvb.reshape(B, Skv, Hkv, group, hd).sum(3)
        dk = dkb.astype(k.dtype)
        dv = dvb.astype(v.dtype)
        dwindow = jnp.zeros_like(window)
        return dq, dk, dv, dwindow

    flash = jax.custom_vjp(f)
    flash.defvjp(f_fwd, f_bwd)
    return flash


def flash_attention_train(
    q: Array, k: Array, v: Array, *,
    causal: bool = True,
    window: Optional[Array] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Array:
    """Differentiable chunked attention with flash-style memory profile."""
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    if Sq % q_chunk or Skv % kv_chunk:
        q_chunk, kv_chunk = Sq, Skv
    has_window = window is not None
    w = (jnp.asarray(window, jnp.int32) if has_window
         else jnp.int32(2 ** 30))
    fn = _make_flash(causal, has_window, q_chunk, kv_chunk, H // Hkv)
    return fn(q, k, v, w)
