"""Fault injection & crash consistency: the atomicity guarantees, proven.

Every checkpointer documents "a crash mid-save leaves the previous
checkpoint restorable" — these tests kill the storage at exact points
(before the commit marker, on the marker itself, during the drain) with
:class:`FaultyStorage` and assert the previous step survives on every path:
CheckpointSaver, AsyncCheckpointer, BurstBufferCheckpointer (both tiers),
and AsyncBurstBufferCheckpointer — the latter under *every* write-op
injection point of its save/drain path, and under the torn-write and
reordered-fsync crash models, not just clean op-boundary kills.
"""
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core.async_burst_buffer import AsyncBurstBufferCheckpointer
from repro.core.async_checkpoint import AsyncCheckpointer
from repro.core.burst_buffer import BurstBufferCheckpointer
from repro.core.checkpoint import CheckpointSaver
from repro.core.faults import FaultInjected, FaultyStorage
from repro.core.storage import NativeStorage


def tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.normal(size=(64, 64)).astype(np.float32),
        "b": rng.normal(size=(64,)).astype(np.float32),
        "step": np.int32(seed),
    }


class TestFaultyStorage:
    def test_fail_after_counts_writes(self, tmp_storage):
        f = FaultyStorage(tmp_storage).fail_after(2)
        f.write_file("a", b"1")
        f.write_file("b", b"2")
        with pytest.raises(FaultInjected):
            f.write_file("c", b"3")
        assert tmp_storage.exists("a") and tmp_storage.exists("b")
        assert not tmp_storage.exists("c")  # fault fires before the write

    def test_sticky_failure_models_dead_device(self, tmp_storage):
        f = FaultyStorage(tmp_storage).fail_after(0)
        with pytest.raises(FaultInjected):
            f.write_file("a", b"1")
        with pytest.raises(FaultInjected):  # still dead
            f.write_file("b", b"2")
        f.heal()
        f.write_file("c", b"3")
        assert f.read_file("c") == b"3"

    def test_fail_on_path_substring(self, tmp_storage):
        f = FaultyStorage(tmp_storage).fail_on("marker")
        f.write_file("data-0", b"x")
        with pytest.raises(FaultInjected):
            f.write_file("the/marker", b"y")

    def test_read_faults(self, tmp_storage):
        tmp_storage.write_file("a", b"payload")
        f = FaultyStorage(tmp_storage).fail_after(0, ops=("read",))
        f.write_file("b", b"ok")  # writes unaffected
        with pytest.raises(FaultInjected):
            f.read_file("a")
        with pytest.raises(FaultInjected):
            f.read_range("a", 0, 3)


class TestSaverCrashConsistency:
    def test_crash_on_data_shard_keeps_previous(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        saver = CheckpointSaver(faulty, "ckpt/m", n_shards=2)
        t1 = tree(1)
        saver.save(1, t1)
        faulty.fail_after(0)  # first write of the next save dies
        with pytest.raises(FaultInjected):
            saver.save(2, tree(2))
        faulty.heal()
        assert saver.latest_step() == 1  # marker never moved
        out = saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])

    def test_crash_on_marker_write_keeps_previous(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        saver = CheckpointSaver(faulty, "ckpt/m")
        t1 = tree(1)
        saver.save(1, t1)
        faulty.fail_on("ckpt/checkpoint")  # kill exactly the commit
        with pytest.raises(FaultInjected):
            saver.save(2, tree(2))
        faulty.heal()
        # step-2 data landed but was never committed: previous still latest
        assert saver.latest_step() == 1
        out = saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])

    def test_crash_with_parallel_shard_writes(self, tmp_storage):
        """A failing shard aborts the whole save before the marker, even
        with the other shards written concurrently."""
        faulty = FaultyStorage(tmp_storage)
        saver = CheckpointSaver(faulty, "ckpt/m", n_shards=4, io_threads=4)
        t1 = tree(1)
        saver.save(1, t1)
        faulty.fail_after(2)  # third shard write of the next save dies
        with pytest.raises(FaultInjected):
            saver.save(2, tree(2))
        faulty.heal()
        assert saver.latest_step() == 1
        out = saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])


class TestAsyncCrashConsistency:
    def test_wait_surfaces_background_write_error(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        ac = AsyncCheckpointer(faulty, "ckpt/m")
        t1 = tree(1)
        ac.save(1, t1).result()
        faulty.fail_after(0)
        handle = ac.save(2, tree(2))  # snapshot succeeds; write will die
        assert isinstance(handle.exception(), FaultInjected)
        with pytest.raises(FaultInjected):
            ac.wait()
        faulty.heal()
        assert ac.latest_step() == 1
        out = ac.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        ac.close()

    def test_error_reported_once_not_resurfaced_forever(self, tmp_storage):
        """After a failed save is reported by wait(), a healed device and
        successful later saves must make wait() clean again."""
        faulty = FaultyStorage(tmp_storage)
        ac = AsyncCheckpointer(faulty, "ckpt/m")
        faulty.fail_after(0)
        ac.save(1, tree(1))
        with pytest.raises(FaultInjected):
            ac.wait()
        faulty.heal()
        ac.save(2, tree(2))
        ac.wait()  # must not re-raise the stale step-1 error
        assert ac.latest_step() == 2
        ac.close()


class TestBurstBufferCrashConsistency:
    def test_fast_tier_crash_mid_save_keeps_previous(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        faulty_fast = FaultyStorage(fast)
        bb = BurstBufferCheckpointer(faulty_fast, slow, "ckpt/m")
        t1 = tree(1)
        bb.save(1, t1)
        bb.wait()
        faulty_fast.fail_after(0)
        with pytest.raises(FaultInjected):
            bb.save(2, tree(2))
        faulty_fast.heal()
        bb.wait()
        # both tiers still restore step 1
        out = bb.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        slow_saver = CheckpointSaver(slow, "ckpt/m")
        assert slow_saver.latest_step() == 1
        out = slow_saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        bb.close()

    def test_drain_error_surfaces_in_wait_and_slow_tier_consistent(
            self, fast_slow_storage):
        fast, slow = fast_slow_storage
        faulty_slow = FaultyStorage(slow)
        bb = BurstBufferCheckpointer(fast, faulty_slow, "ckpt/m")
        t1 = tree(1)
        bb.save(1, t1)
        bb.wait()
        faulty_slow.fail_after(0)  # the next drain's first slow write dies
        bb.save(2, tree(2))        # staging to fast succeeds
        with pytest.raises(FaultInjected):
            bb.wait()
        faulty_slow.heal()
        # slow tier: marker still at step 1, and step 1 restores
        slow_saver = CheckpointSaver(slow, "ckpt/m")
        assert slow_saver.latest_step() == 1
        out = slow_saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        # fast tier holds the newer staged step — nothing was lost
        assert bb.fast_saver.latest_step() == 2
        bb.close()

    def test_drain_marker_crash_keeps_slow_consistent(self, fast_slow_storage):
        """Die exactly on the slow-tier commit marker: files of the new step
        are on the slow tier but it must still restore the previous step."""
        fast, slow = fast_slow_storage
        faulty_slow = FaultyStorage(slow)
        bb = BurstBufferCheckpointer(fast, faulty_slow, "ckpt/m")
        t1 = tree(1)
        bb.save(1, t1)
        bb.wait()
        faulty_slow.fail_on("ckpt/checkpoint")
        bb.save(2, tree(2))
        with pytest.raises(FaultInjected):
            bb.wait()
        faulty_slow.heal()
        slow_saver = CheckpointSaver(slow, "ckpt/m")
        assert slow_saver.latest_step() == 1
        out = slow_saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        bb.close()


class TestTornWriteModel:
    """The torn-write fault mode itself: a frac prefix really lands."""

    def test_partial_prefix_lands_then_device_dies(self, tmp_storage):
        f = FaultyStorage(tmp_storage).torn_write(0.5, n_ops=1)
        f.write_file("a", b"x" * 100)                # op 0: clean
        with pytest.raises(FaultInjected):
            f.write_file("b", b"y" * 100)            # op 1: torn
        assert tmp_storage.size("b") == 50           # half the buffer landed
        with pytest.raises(FaultInjected):
            f.write_file("c", b"z")                  # sticky: device is dead
        assert not tmp_storage.exists("c")
        f.heal()
        f.write_file("c", b"z")
        assert f.read_file("c") == b"z"

    def test_torn_targets_path_substring(self, tmp_storage):
        f = FaultyStorage(tmp_storage).torn_write(0.25, on="marker")
        f.write_file("data-0", b"d" * 8)             # non-matching: clean
        with pytest.raises(FaultInjected):
            f.write_file("the/marker", b"m" * 8)
        assert tmp_storage.size("the/marker") == 2

    def test_invalid_fraction_rejected(self, tmp_storage):
        f = FaultyStorage(tmp_storage)
        with pytest.raises(ValueError):
            f.torn_write(1.0)
        with pytest.raises(ValueError):
            f.torn_write(-0.1)


class TestReorderedFsyncModel:
    """The volatile-cache durability model: unsynced writes are not durable,
    and the *last-issued* one can survive a crash while earlier ones don't
    (durability reordering — the adversary of unsynced commit markers)."""

    def test_crash_rolls_back_unsynced_writes(self, tmp_storage):
        f = FaultyStorage(tmp_storage).reordered_fsync()
        f.write_file("a", b"old")
        f.fsync_dir(".")                  # barrier: "a"=old is durable
        f.write_file("a", b"new")         # volatile overwrite
        f.write_file("b", b"data")        # volatile create
        lost = f.crash(keep="none")
        assert sorted(lost) == ["a", "b"]
        assert tmp_storage.read_file("a") == b"old"  # pre-image restored
        assert not tmp_storage.exists("b")           # never durable

    def test_sync_write_is_a_barrier(self, tmp_storage):
        f = FaultyStorage(tmp_storage).reordered_fsync()
        f.write_file("a", b"1")
        f.write_file("barrier", b"2", sync=True)  # flushes "a" too (syncfs)
        f.write_file("c", b"3")
        lost = f.crash(keep="none")
        assert lost == ["c"]
        assert tmp_storage.read_file("a") == b"1"
        assert tmp_storage.read_file("barrier") == b"2"

    def test_keep_last_spares_newest_volatile_write(self, tmp_storage):
        f = FaultyStorage(tmp_storage).reordered_fsync()
        f.write_file("data", b"D")
        f.write_file("marker", b"M")      # issued last, hit the medium first
        lost = f.crash(keep="last")
        assert lost == ["data"]
        assert not tmp_storage.exists("data")
        assert tmp_storage.read_file("marker") == b"M"

    def test_rename_does_not_launder_volatility(self, tmp_storage):
        """tmp+rename of an unsynced file: the rename target inherits the
        volatility and rolls back to *its* pre-image (the old marker)."""
        tmp_storage.write_file("marker", b"OLD")
        f = FaultyStorage(tmp_storage).reordered_fsync()
        f.write_file("marker.tmp", b"NEW")    # volatile
        f.rename("marker.tmp", "marker")
        lost = f.crash(keep="none")
        assert lost == ["marker"]
        assert tmp_storage.read_file("marker") == b"OLD"

    def test_crash_requires_arming(self, tmp_storage):
        with pytest.raises(RuntimeError):
            FaultyStorage(tmp_storage).crash()


class TestTornWriteCrashConsistency:
    def test_saver_torn_marker_keeps_previous(self, tmp_storage):
        """A torn write on the marker path must not corrupt the commit: the
        tmp+rename protocol leaves the old marker bytes untouched (a plain
        truncate-and-rewrite of the marker would leave corrupt JSON and
        make *both* steps unreachable)."""
        faulty = FaultyStorage(tmp_storage)
        saver = CheckpointSaver(faulty, "ckpt/m")
        t1 = tree(1)
        saver.save(1, t1)
        faulty.torn_write(0.5, on="ckpt/checkpoint")
        with pytest.raises(FaultInjected):
            saver.save(2, tree(2))
        faulty.heal()
        assert saver.latest_step() == 1   # old marker parses, still JSON
        out = saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])

    def test_saver_torn_data_shard_keeps_previous(self, tmp_storage):
        faulty = FaultyStorage(tmp_storage)
        saver = CheckpointSaver(faulty, "ckpt/m", n_shards=2)
        t1 = tree(1)
        saver.save(1, t1)
        faulty.torn_write(0.7, n_ops=0)   # first shard write of next save
        with pytest.raises(FaultInjected):
            saver.save(2, tree(2))
        faulty.heal()
        assert saver.latest_step() == 1
        out = saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])

    def test_bb_torn_drain_keeps_slow_consistent(self, fast_slow_storage):
        """A drain range-write torn mid-buffer leaves a half-written file on
        the slow tier — the un-advanced marker must keep it invisible."""
        fast, slow = fast_slow_storage
        faulty_slow = FaultyStorage(slow)
        bb = BurstBufferCheckpointer(fast, faulty_slow, "ckpt/m",
                                     drain_streams=2, drain_chunk=4096)
        t1 = tree(1)
        bb.save(1, t1)
        bb.wait()
        faulty_slow.torn_write(0.5, n_ops=1)
        bb.save(2, tree(2))
        with pytest.raises(FaultInjected):
            bb.wait()
        faulty_slow.heal()
        slow_saver = CheckpointSaver(slow, "ckpt/m")
        assert slow_saver.latest_step() == 1
        out = slow_saver.restore_pytree(t1)
        np.testing.assert_array_equal(out["w"], t1["w"])
        assert bb.fast_saver.latest_step() == 2  # fast tier unaffected
        bb.close()


class TestReorderedFsyncCrashConsistency:
    def test_drain_marker_is_a_durability_barrier(self, tmp_storage):
        """Regression for the unsynced slow-tier marker: the drain's data
        writes are volatile (``write_range(sync=False)``), so if the commit
        marker were published without a sync barrier, durability reordering
        could persist the *marker* while the data it commits rolls back —
        a marker pointing at garbage.  The marker write must therefore be
        ``sync=True`` (flushing everything issued before it) *before* the
        rename publishes it: after ``crash(keep="last")`` the drained step
        must restore bit-identically."""
        with tempfile.TemporaryDirectory() as d2:
            faulty_slow = FaultyStorage(NativeStorage(d2)).reordered_fsync()
            bb = BurstBufferCheckpointer(tmp_storage, faulty_slow, "ckpt/m",
                                         drain_streams=2, drain_chunk=4096)
            t1 = tree(1)
            bb.save(1, t1)
            bb.wait()
            bb.close()
            faulty_slow.crash(keep="last")  # power loss after drain "done"
            slow_saver = CheckpointSaver(faulty_slow, "ckpt/m")
            assert slow_saver.latest_step() == 1
            out = slow_saver.restore_pytree(t1)
            np.testing.assert_array_equal(out["w"], t1["w"])

    def test_asyncbb_survives_crash_after_every_save(self, tmp_storage):
        """Same property through the fused engine, across multiple saves."""
        with tempfile.TemporaryDirectory() as d2:
            faulty_slow = FaultyStorage(NativeStorage(d2)).reordered_fsync()
            abb = AsyncBurstBufferCheckpointer(
                tmp_storage, faulty_slow, "ckpt/m",
                drain_streams=2, drain_chunk=4096)
            trees = {s: tree(s) for s in (1, 2, 3)}
            for s in (1, 2, 3):
                abb.save(s, trees[s])
            abb.wait()
            abb.close()
            faulty_slow.crash(keep="last")
            slow_saver = CheckpointSaver(faulty_slow, "ckpt/m")
            latest = slow_saver.latest_step()
            assert latest == 3
            out = slow_saver.restore_pytree(trees[latest])
            np.testing.assert_array_equal(out["w"], trees[latest]["w"])


class TestAsyncBBInjectionSweep:
    """Torn-write injection at *every* write op of the async burst buffer's
    save/drain path, on each tier: whatever lands half-written, a restorable
    step must survive on every tier that has a marker."""

    PREFIX = "ckpt/m"

    def _make(self, fast_dir, slow_dir):
        fast = FaultyStorage(NativeStorage(fast_dir))
        slow = FaultyStorage(NativeStorage(slow_dir))
        abb = AsyncBurstBufferCheckpointer(
            fast, slow, self.PREFIX, n_shards=2,
            drain_streams=2, drain_chunk=4096)
        return fast, slow, abb

    def _count_step2_write_ops(self):
        """Clean run: how many write ops does saving step 2 issue per tier?"""
        with tempfile.TemporaryDirectory() as d1, \
                tempfile.TemporaryDirectory() as d2:
            fast, slow, abb = self._make(d1, d2)
            abb.save(1, tree(1))
            abb.wait()
            f0 = sum(1 for op, _, _ in fast.op_log if op.startswith("write")
                     or op == "append_file")
            s0 = sum(1 for op, _, _ in slow.op_log if op.startswith("write")
                     or op == "append_file")
            abb.save(2, tree(2))
            abb.wait()
            abb.close()
            f1 = sum(1 for op, _, _ in fast.op_log if op.startswith("write")
                     or op == "append_file")
            s1 = sum(1 for op, _, _ in slow.op_log if op.startswith("write")
                     or op == "append_file")
        return f1 - f0, s1 - s0

    def _assert_tier_restorable(self, storage, trees):
        """The tier's marker must point at a step that restores
        bit-identically to what was saved."""
        saver = CheckpointSaver(storage, self.PREFIX)
        step = saver.latest_step()
        assert step in trees, f"marker points at unknown step {step}"
        out = saver.restore_pytree(trees[step])
        np.testing.assert_array_equal(out["w"], trees[step]["w"])
        return step

    def test_every_injection_point(self):
        n_fast, n_slow = self._count_step2_write_ops()
        assert n_fast >= 4 and n_slow >= 4  # shards+index+meta+marker ranges
        trees = {1: tree(1), 2: tree(2)}

        for tier_name, n_ops in (("fast", n_fast), ("slow", n_slow)):
            for k in range(n_ops):
                with tempfile.TemporaryDirectory() as d1, \
                        tempfile.TemporaryDirectory() as d2:
                    fast, slow, abb = self._make(d1, d2)
                    abb.save(1, trees[1])
                    abb.wait()
                    target = fast if tier_name == "fast" else slow
                    target.torn_write(0.5, n_ops=k)
                    abb.save(2, trees[2])
                    with pytest.raises(FaultInjected):
                        abb.wait()
                    target.heal()
                    try:
                        abb.close()
                    except FaultInjected:
                        pass  # a second failure from the same cascade
                    ctx = f"tier={tier_name}, injection point {k}/{n_ops}"
                    # the un-injected fast tier always commits step 2; an
                    # injected tier must still restore *a* step (usually 1)
                    if tier_name == "fast":
                        self._assert_tier_restorable(fast, trees)
                        # stage died -> nothing was drained for step 2
                        assert CheckpointSaver(
                            slow, self.PREFIX).latest_step() == 1, ctx
                    else:
                        assert CheckpointSaver(
                            fast, self.PREFIX).latest_step() == 2, ctx
                        step = self._assert_tier_restorable(slow, trees)
                        assert step == 1, ctx  # marker never advanced


class TestHangModel:
    """The stuck-op fault: the op blocks (bytes land on release), nothing
    raises — the model drain watchdogs exist to detect."""

    def test_hang_blocks_then_released_op_completes(self, tmp_storage):
        f = FaultyStorage(tmp_storage).hang(n_ops=0)
        done = threading.Event()

        def writer():
            f.write_file("a", b"payload")  # wedges here
            done.set()

        t = threading.Thread(target=writer, daemon=True)
        t.start()
        deadline = time.monotonic() + 5.0
        while f.hung_now == 0 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert f.hung_now == 1 and f.hung_ops == 1
        assert not done.is_set()
        assert not tmp_storage.exists("a")  # nothing raised, nothing landed
        f.release_hung()
        assert done.wait(5.0)
        assert tmp_storage.read_file("a") == b"payload"  # bytes land on release
        assert f.hung_now == 0

    def test_hang_duration_self_releases(self, tmp_storage):
        f = FaultyStorage(tmp_storage).hang(n_ops=0, duration=0.05)
        t0 = time.monotonic()
        f.write_file("a", b"x")
        assert time.monotonic() - t0 >= 0.05
        assert tmp_storage.read_file("a") == b"x"
        assert f.hung_ops == 1

    def test_hang_is_one_shot_unless_repeat(self, tmp_storage):
        f = FaultyStorage(tmp_storage).hang(n_ops=0, duration=0.02)
        f.write_file("a", b"1")
        t0 = time.monotonic()
        f.write_file("b", b"2")  # disarmed: no stall
        assert time.monotonic() - t0 < 0.02
        assert f.hung_ops == 1
        f.hang(n_ops=0, duration=0.02, repeat=True)
        f.write_file("c", b"3")
        f.write_file("d", b"4")
        assert f.hung_ops == 3  # both tripped while armed

    def test_hang_on_path_substring_and_op_counting(self, tmp_storage):
        f = FaultyStorage(tmp_storage).hang(on="marker", duration=0.05)
        t0 = time.monotonic()
        f.write_file("data-0", b"x")  # path doesn't match: no stall
        assert time.monotonic() - t0 < 0.05
        f.write_file("the/marker", b"y")
        assert time.monotonic() - t0 >= 0.05
        f.hang(n_ops=2, duration=0.03)
        t1 = time.monotonic()
        f.write_file("p", b"1")
        f.write_file("q", b"2")  # two ops let through
        assert time.monotonic() - t1 < 0.03
        f.write_file("r", b"3")  # the third trips
        assert time.monotonic() - t1 >= 0.03

    def test_heal_unwedges_and_disarms(self, tmp_storage):
        f = FaultyStorage(tmp_storage).hang(n_ops=0, repeat=True)
        done = threading.Event()

        def writer():
            f.write_file("a", b"1")
            done.set()

        threading.Thread(target=writer, daemon=True).start()
        deadline = time.monotonic() + 5.0
        while f.hung_now == 0 and time.monotonic() < deadline:
            time.sleep(0.005)
        f.heal()
        assert done.wait(5.0)
        t0 = time.monotonic()
        f.write_file("b", b"2")  # disarmed: immediate
        assert time.monotonic() - t0 < 0.05

    def test_invalid_duration_rejected(self, tmp_storage):
        with pytest.raises(ValueError):
            FaultyStorage(tmp_storage).hang(duration=-1.0)
