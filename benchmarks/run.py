"""Benchmark suite — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig9       # one

Prints ``name,key=val,...`` CSV (also appended to reports/bench_results.csv)
with a ``derived`` line per benchmark comparing against the paper's claim.
"""
import os
import sys
import time

sys.path.insert(0, "src")

ALL = ["table1", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
       "fig11", "fig12", "fig13", "fig14", "fig15", "roofline"]


def main() -> None:
    which = sys.argv[1:] or ALL
    # fresh results file
    os.makedirs("reports", exist_ok=True)
    from . import (fig4_threads, fig5_read_only, fig6_prefetch,
                   fig7_batchsize, fig8_trace, fig9_checkpoint,
                   fig10_async_ckpt, fig11_pipeline, fig12_async_bb,
                   fig13_recovery, fig14_cache, fig15_preemption,
                   roofline_table, table1_ior)
    mods = dict(table1=table1_ior, fig4=fig4_threads, fig5=fig5_read_only,
                fig6=fig6_prefetch, fig7=fig7_batchsize, fig8=fig8_trace,
                fig9=fig9_checkpoint, fig10=fig10_async_ckpt,
                fig11=fig11_pipeline, fig12=fig12_async_bb,
                fig13=fig13_recovery, fig14=fig14_cache,
                fig15=fig15_preemption, roofline=roofline_table)
    for name in which:
        t0 = time.monotonic()
        print(f"# --- {name} ---", flush=True)
        mods[name].run()  # fig11 also writes reports/BENCH_pipeline.json
        print(f"# {name} done in {time.monotonic()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
