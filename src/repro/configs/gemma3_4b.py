"""gemma3-4b — dense, 5 local (SWA-1024) layers per 1 global, 128k ctx.
[hf:google/gemma-3-1b-pt family; unverified] 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144, head_dim=256."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    window=1024,
    local_global_period=6,  # layers 5, 11, ... are global; rest local
    qk_norm=True,
    rope_theta=1e6,
    source="hf:google/gemma-3-1b-pt; unverified",
)
