"""Storage tiers for the I/O benchmarks and the burst buffer.

The paper (Table I) benchmarks four devices with IOR:

    ============  ==========  ===========
    device        max read    max write
    ============  ==========  ===========
    HDD           163.00 MB/s  133.14 MB/s
    SSD           280.55 MB/s  195.05 MB/s
    Intel Optane  1603.06 MB/s 511.78 MB/s
    Lustre        1968.62 MB/s 991.91 MB/s
    ============  ==========  ===========

This container has a single disk (and a single core), so we reproduce the
paper's *environment* with a calibrated token-bucket simulator:
:class:`SimulatedStorage` performs real file I/O against a backing directory
but paces it so that aggregate and per-stream bandwidth, seek latency, and
seek contention match the device model.  :class:`NativeStorage` is the
passthrough used on real machines.

Every storage object exposes the same tiny interface the rest of the
framework uses (read_file/write_file/fsync_dir/listdir/...), mirroring how
TensorFlow's file-system adapters (POSIX/S3/GCS/HDFS — paper Fig. 1) share
one interface.
"""
from __future__ import annotations

import os
import shutil
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from .. import metrics, trace
from .stats import IOTracer


def _op_metrics(op: str, tier: str, nbytes: int, dur_s: float) -> None:
    """Per-tier op/bytes counters + latency sketch (one enabled() check at
    each call site keeps the disabled path allocation-free)."""
    metrics.inc(f"storage.{op}_ops", 1, tier=tier)
    metrics.inc(f"storage.{op}_bytes", nbytes, tier=tier)
    metrics.observe(f"storage.{op}_s", dur_s, tier=tier)


# ---------------------------------------------------------------------------
# Device models
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TierSpec:
    """Bandwidth/latency model of one storage device.

    ``seek_contention`` inflates per-op latency as concurrency grows
    (``lat_n = seek_latency * (1 + seek_contention * (n_inflight - 1))``) —
    this is what makes HDD thread-scaling saturate around the paper's 2.3x
    while Lustre keeps scaling to ~7.8x.
    """

    name: str
    read_bw: float          # aggregate B/s
    write_bw: float         # aggregate B/s
    stream_read_bw: float   # single-stream B/s
    stream_write_bw: float  # single-stream B/s
    seek_latency: float     # s per op
    seek_contention: float  # dimensionless


# Calibrated against paper Table I (aggregate) + Fig. 4/5 (scaling shape).
TIERS: Dict[str, TierSpec] = {
    "hdd": TierSpec("hdd", 163.00e6, 133.14e6, 75e6, 70e6, 8e-3, 0.42),
    "ssd": TierSpec("ssd", 280.55e6, 195.05e6, 150e6, 110e6, 0.1e-3, 0.05),
    "optane": TierSpec("optane", 1603.06e6, 511.78e6, 900e6, 300e6, 0.01e-3, 0.02),
    "lustre": TierSpec("lustre", 1968.62e6, 991.91e6, 260e6, 135e6, 0.5e-3, 0.0),
}


class Storage:
    """Abstract file-store interface (the TF file-system-adapter analogue)."""

    name = "abstract"

    # -- reads -------------------------------------------------------------
    def read_file(self, path: str) -> bytes:
        raise NotImplementedError

    def read_range(self, path: str, offset: int, length: int) -> bytes:
        """Read ``length`` bytes starting at ``offset`` (streaming reads).

        Default implementation slices a full read — subclasses override to
        avoid materializing the whole file.
        """
        return self.read_file(path)[offset : offset + length]

    # -- writes ------------------------------------------------------------
    def write_file(self, path: str, data: bytes, sync: bool = False) -> None:
        raise NotImplementedError

    def append_file(self, path: str, data: bytes, sync: bool = False) -> None:
        """Append ``data`` to ``path`` (streaming writes; pays write cost)."""
        raise NotImplementedError

    def write_range(self, path: str, offset: int, data: bytes,
                    sync: bool = False) -> None:
        """pwrite-style positional write: place ``data`` at ``offset``.

        Writes past EOF extend the file (the gap reads as zeros), so
        concurrent writers can land disjoint ranges of one file in any
        order — this is what lets a single large checkpoint shard drain on
        multiple streams instead of one serial ``copy_to`` chain.

        The default is a read-modify-write over the whole file (correct for
        any backend, O(file) per call); :class:`NativeStorage` and
        :class:`SimulatedStorage` override with a real ``os.pwrite``.
        """
        existing = self.read_file(path) if self.exists(path) else b""
        if len(existing) < offset:
            existing += b"\x00" * (offset - len(existing))
        new = existing[:offset] + bytes(data) + existing[offset + len(data):]
        self.write_file(path, new, sync=sync)

    def fsync_dir(self, path: str) -> None:
        """paper §III-C: syncfs() after Saver returns."""
        raise NotImplementedError

    # -- namespace ---------------------------------------------------------
    def listdir(self, path: str) -> List[str]:
        raise NotImplementedError

    def exists(self, path: str) -> bool:
        raise NotImplementedError

    def makedirs(self, path: str) -> None:
        raise NotImplementedError

    def remove(self, path: str) -> None:
        raise NotImplementedError

    def rename(self, src: str, dst: str) -> None:
        raise NotImplementedError

    def size(self, path: str) -> int:
        raise NotImplementedError

    def drop_caches(self) -> None:
        """posix_fadvise(DONTNEED) analogue (paper §IV)."""

    def copy_to(self, src_path: str, dst_storage: "Storage", dst_path: str,
                chunk: int = 8 << 20) -> None:
        """Tier-to-tier copy that pays read cost here and write cost there
        (used by the burst-buffer drainer).

        Streams ``chunk`` bytes at a time through :meth:`read_range` /
        :meth:`append_file`, so peak memory is one chunk — a multi-GB
        checkpoint shard never materializes as a single blob.
        """
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        size = self.size(src_path)
        if size <= chunk:
            dst_storage.write_file(dst_path, self.read_file(src_path),
                                   sync=False)
            return
        offset = 0
        while offset < size:
            data = self.read_range(src_path, offset, min(chunk, size - offset))
            if offset == 0:
                dst_storage.write_file(dst_path, data, sync=False)
            else:
                dst_storage.append_file(dst_path, data, sync=False)
            offset += len(data)


class NativeStorage(Storage):
    """Direct POSIX passthrough rooted at ``root``."""

    name = "native"

    def __init__(self, root: str, tracer: Optional[IOTracer] = None):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self.tracer = tracer

    def _abs(self, path: str) -> str:
        return os.path.join(self.root, path)

    def read_file(self, path: str) -> bytes:
        m = metrics.enabled()
        t0 = time.monotonic() if m else 0.0
        with trace.span(trace.STAGE_STORAGE_READ, path) as sp:
            with open(self._abs(path), "rb") as f:
                data = f.read()
            sp.set_bytes(len(data))
        if m:
            _op_metrics("read", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("read", len(data), path)
        return data

    def read_range(self, path: str, offset: int, length: int) -> bytes:
        m = metrics.enabled()
        t0 = time.monotonic() if m else 0.0
        with trace.span(trace.STAGE_STORAGE_READ, path) as sp:
            with open(self._abs(path), "rb") as f:
                f.seek(offset)
                data = f.read(length)
            sp.set_bytes(len(data))
        if m:
            _op_metrics("read", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("read", len(data), path)
        return data

    def write_file(self, path: str, data: bytes, sync: bool = False) -> None:
        m = metrics.enabled()
        t0 = time.monotonic() if m else 0.0
        with trace.span(trace.STAGE_STORAGE_WRITE, path, len(data)):
            ap = self._abs(path)
            os.makedirs(os.path.dirname(ap) or ".", exist_ok=True)
            with open(ap, "wb") as f:
                f.write(data)
                if sync:
                    f.flush()
                    os.fsync(f.fileno())
        if m:
            _op_metrics("write", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("write", len(data), path)

    def append_file(self, path: str, data: bytes, sync: bool = False) -> None:
        m = metrics.enabled()
        t0 = time.monotonic() if m else 0.0
        with trace.span(trace.STAGE_STORAGE_WRITE, path, len(data)):
            ap = self._abs(path)
            os.makedirs(os.path.dirname(ap) or ".", exist_ok=True)
            with open(ap, "ab") as f:
                f.write(data)
                if sync:
                    f.flush()
                    os.fsync(f.fileno())
        if m:
            _op_metrics("write", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("write", len(data), path)

    def write_range(self, path: str, offset: int, data: bytes,
                    sync: bool = False) -> None:
        m = metrics.enabled()
        t0 = time.monotonic() if m else 0.0
        with trace.span(trace.STAGE_STORAGE_WRITE, path, len(data)):
            ap = self._abs(path)
            os.makedirs(os.path.dirname(ap) or ".", exist_ok=True)
            fd = os.open(ap, os.O_WRONLY | os.O_CREAT, 0o644)
            try:
                os.pwrite(fd, bytes(data), offset)
                if sync:
                    os.fsync(fd)
            finally:
                os.close(fd)
        if m:
            _op_metrics("write", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("write", len(data), path)

    def fsync_dir(self, path: str) -> None:
        ap = self._abs(path)
        try:
            fd = os.open(ap, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        except OSError:
            pass

    def listdir(self, path: str) -> List[str]:
        return sorted(os.listdir(self._abs(path)))

    def exists(self, path: str) -> bool:
        return os.path.exists(self._abs(path))

    def makedirs(self, path: str) -> None:
        os.makedirs(self._abs(path), exist_ok=True)

    def remove(self, path: str) -> None:
        ap = self._abs(path)
        if os.path.isdir(ap):
            shutil.rmtree(ap)
        elif os.path.exists(ap):
            os.remove(ap)

    def rename(self, src: str, dst: str) -> None:
        os.replace(self._abs(src), self._abs(dst))

    def size(self, path: str) -> int:
        return os.path.getsize(self._abs(path))

    def drop_caches(self) -> None:
        # Advise the kernel we no longer need the pages of files under root.
        if not hasattr(os, "posix_fadvise"):
            return
        for dirpath, _dirs, files in os.walk(self.root):
            for fn in files:
                try:
                    fd = os.open(os.path.join(dirpath, fn), os.O_RDONLY)
                    try:
                        os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
                    finally:
                        os.close(fd)
                except OSError:
                    pass


class _TokenBucket:
    """Pacing primitive: admission at ``rate`` B/s, shared by all streams.

    Instead of sleeping inside a lock, each acquire reserves a time slot
    [start, start+bytes/rate) on a virtual device timeline and sleeps until
    its slot ends — giving FIFO bandwidth sharing that behaves like a device
    queue under concurrency.
    """

    def __init__(self, rate: float):
        self.rate = float(rate)
        self._lock = threading.Lock()
        self._next_free = 0.0  # virtual device-free time (monotonic)

    def reserve(self, nbytes: int) -> float:
        """Reserve a slot; returns the monotonic time the device would
        finish this transfer (caller sleeps until then)."""
        now = time.monotonic()
        if self.rate <= 0 or nbytes <= 0:
            return now
        dur = nbytes / self.rate
        with self._lock:
            start = max(now, self._next_free)
            end = start + dur
            self._next_free = end
        return end

    def acquire(self, nbytes: int) -> None:
        end = self.reserve(nbytes)
        delay = end - time.monotonic()
        if delay > 0:
            time.sleep(delay)


class SimulatedStorage(Storage):
    """Real files under ``root``, paced to behave like ``spec``.

    Reads/writes really hit the backing filesystem (so correctness is real),
    then sleep whatever extra time the modelled device would have needed.
    A per-op seek latency with a concurrency-dependent contention factor plus
    per-stream and aggregate token buckets reproduce the thread-scaling
    behaviour of the paper's four devices.
    """

    def __init__(self, root: str, spec: TierSpec,
                 tracer: Optional[IOTracer] = None,
                 time_scale: float = 1.0):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)
        self.spec = spec
        self.name = spec.name
        self.tracer = tracer
        # time_scale < 1 speeds up the simulation uniformly (all bandwidths
        # multiplied by 1/time_scale) so benchmarks finish quickly while
        # preserving every *ratio* the paper reports.
        self.time_scale = float(time_scale)
        self._read_bucket = _TokenBucket(spec.read_bw / self.time_scale)
        self._write_bucket = _TokenBucket(spec.write_bw / self.time_scale)
        self._lock = threading.Lock()
        self._inflight = 0

    # -- concurrency tracking ------------------------------------------------
    def _enter(self) -> int:
        with self._lock:
            self._inflight += 1
            return self._inflight

    def _exit(self) -> None:
        with self._lock:
            self._inflight -= 1

    def _seek_latency(self, n_inflight: int) -> float:
        lat = self.spec.seek_latency * (
            1.0 + self.spec.seek_contention * max(0, n_inflight - 1)
        )
        return lat * self.time_scale

    def _seek(self, n_inflight: int) -> None:
        lat = self._seek_latency(n_inflight)
        if lat > 0:
            time.sleep(lat)

    def paced_sleep(self, seconds: float) -> None:
        """Sleep ``seconds`` of *modelled* time, i.e. ``seconds *
        time_scale`` of wall clock.  Inject as ``RetryPolicy(sleep=...)`` so
        retry backoff runs on the same scaled clock as the device pacing —
        the faulty-path latency tax then reproduces at any ``time_scale``."""
        wall = seconds * self.time_scale
        if wall > 0:
            time.sleep(wall)

    def _abs(self, path: str) -> str:
        return os.path.join(self.root, path)

    def _pace(self, t0: float, n_inflight: int, nbytes: int,
              stream_bw: float, bucket: _TokenBucket) -> None:
        """Sleep until the modelled device would have finished the op: the
        later of single-stream time (incl. seek) and the shared device-queue
        slot — real backing-I/O time is credited, so fast tiers aren't
        penalized by the real disk."""
        stream_end = t0 + self._seek_latency(n_inflight) + nbytes / (
            stream_bw / self.time_scale)
        bucket_end = bucket.reserve(nbytes)
        delay = max(stream_end, bucket_end) - time.monotonic()
        if delay > 0:
            time.sleep(delay)

    # -- I/O -----------------------------------------------------------------
    def read_file(self, path: str) -> bytes:
        n = self._enter()
        t0 = time.monotonic()
        # span covers the modelled device time (pacing sleeps included):
        # the trace shows what the simulated tier would really cost
        with trace.span(trace.STAGE_STORAGE_READ, path) as sp:
            try:
                with open(self._abs(path), "rb") as f:
                    data = f.read()
                sp.set_bytes(len(data))
                self._pace(t0, n, len(data), self.spec.stream_read_bw,
                           self._read_bucket)
            finally:
                self._exit()
        # metric latency covers the modelled device time (pacing included)
        if metrics.enabled():
            _op_metrics("read", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("read", len(data), path)
        return data

    def read_range(self, path: str, offset: int, length: int) -> bytes:
        n = self._enter()
        t0 = time.monotonic()
        with trace.span(trace.STAGE_STORAGE_READ, path) as sp:
            try:
                with open(self._abs(path), "rb") as f:
                    f.seek(offset)
                    data = f.read(length)
                sp.set_bytes(len(data))
                self._pace(t0, n, len(data), self.spec.stream_read_bw,
                           self._read_bucket)
            finally:
                self._exit()
        if metrics.enabled():
            _op_metrics("read", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("read", len(data), path)
        return data

    def write_file(self, path: str, data: bytes, sync: bool = False) -> None:
        n = self._enter()
        t0 = time.monotonic()
        with trace.span(trace.STAGE_STORAGE_WRITE, path, len(data)):
            try:
                ap = self._abs(path)
                os.makedirs(os.path.dirname(ap) or ".", exist_ok=True)
                with open(ap, "wb") as f:
                    f.write(data)
                    # NOTE: no real fsync — durability cost is part of the
                    # *modelled* device time; paying the backing disk's real
                    # fsync would distort every tier with a constant unrelated
                    # to the modelled device.
                self._pace(t0, n, len(data), self.spec.stream_write_bw,
                           self._write_bucket)
            finally:
                self._exit()
        if metrics.enabled():
            _op_metrics("write", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("write", len(data), path)

    def append_file(self, path: str, data: bytes, sync: bool = False) -> None:
        n = self._enter()
        t0 = time.monotonic()
        with trace.span(trace.STAGE_STORAGE_WRITE, path, len(data)):
            try:
                ap = self._abs(path)
                os.makedirs(os.path.dirname(ap) or ".", exist_ok=True)
                with open(ap, "ab") as f:
                    f.write(data)
                self._pace(t0, n, len(data), self.spec.stream_write_bw,
                           self._write_bucket)
            finally:
                self._exit()
        if metrics.enabled():
            _op_metrics("write", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("write", len(data), path)

    def write_range(self, path: str, offset: int, data: bytes,
                    sync: bool = False) -> None:
        n = self._enter()
        t0 = time.monotonic()
        with trace.span(trace.STAGE_STORAGE_WRITE, path, len(data)):
            try:
                ap = self._abs(path)
                os.makedirs(os.path.dirname(ap) or ".", exist_ok=True)
                fd = os.open(ap, os.O_WRONLY | os.O_CREAT, 0o644)
                try:
                    os.pwrite(fd, bytes(data), offset)
                finally:
                    os.close(fd)
                self._pace(t0, n, len(data), self.spec.stream_write_bw,
                           self._write_bucket)
            finally:
                self._exit()
        if metrics.enabled():
            _op_metrics("write", self.name, len(data), time.monotonic() - t0)
        if self.tracer:
            self.tracer.record("write", len(data), path)

    def fsync_dir(self, path: str) -> None:
        # Modelled as one seek-class operation.
        n = self._enter()
        try:
            self._seek(n)
        finally:
            self._exit()

    # -- namespace (unthrottled metadata ops) --------------------------------
    def listdir(self, path: str) -> List[str]:
        return sorted(os.listdir(self._abs(path)))

    def exists(self, path: str) -> bool:
        return os.path.exists(self._abs(path))

    def makedirs(self, path: str) -> None:
        os.makedirs(self._abs(path), exist_ok=True)

    def remove(self, path: str) -> None:
        ap = self._abs(path)
        if os.path.isdir(ap):
            shutil.rmtree(ap)
        elif os.path.exists(ap):
            os.remove(ap)

    def rename(self, src: str, dst: str) -> None:
        os.replace(self._abs(src), self._abs(dst))

    def size(self, path: str) -> int:
        return os.path.getsize(self._abs(path))


def make_storage(kind: str, root: str, tracer: Optional[IOTracer] = None,
                 time_scale: float = 1.0) -> Storage:
    """Factory: ``kind`` is 'native' or one of TIERS (hdd/ssd/optane/lustre)."""
    if kind == "native":
        return NativeStorage(root, tracer)
    if kind in TIERS:
        return SimulatedStorage(root, TIERS[kind], tracer, time_scale)
    raise ValueError(f"unknown storage kind {kind!r}; options: native, {list(TIERS)}")
