"""Fig. 4 analogue: micro-benchmark ingestion bandwidth vs reader threads
(full pipeline: read + decode + resize + batch), per storage tier.

Emits the usual CSV rows plus machine-readable ``BENCH_threads.json``
(samples/s, bytes/s and thread-speedup per tier per thread count) so the
perf-regression gate (``benchmarks/regression_gate.py``) can compare runs.

    PYTHONPATH=src python -m benchmarks.fig4_threads [--smoke]
"""
from __future__ import annotations

import json
import os
import sys

from repro.core.microbench import thread_scaling_sweep

from .common import BenchEnv, RESULTS_DIR, emit


def run(tiers=("hdd", "ssd", "optane", "lustre"), preprocess=True,
        name="fig4_threads", pipeline="legacy", n_images=128,
        mean_hw=(190, 190), thread_counts=(1, 2, 4, 8), repeats=3,
        batch_size=32, out_hw=(32, 32), json_name="BENCH_threads.json",
        time_scale=1.0) -> dict:
    # paper: ImageNet subset, median image 112 KB (~190x190x3 raw).
    # ``pipeline="vectorized"`` sweeps the fused map_and_batch read engine
    # instead of the seed per-element chain (thread-scaling shape should
    # match; absolute samples/s is higher — fig11 quantifies the gap).
    env = BenchEnv(tiers=tiers, n_images=n_images, mean_hw=mean_hw,
                   time_scale=time_scale)
    rows, speedups, result = [], {}, {}
    for tier in tiers:
        st = env.storages[tier]
        paths, _ = env.corpora[tier]
        st.drop_caches()
        results = thread_scaling_sweep(
            st, paths, thread_counts=thread_counts, repeats=repeats,
            batch_size=batch_size, preprocess=preprocess, out_hw=out_hw,
            pipeline=pipeline)
        base = results[0].images_per_s
        sp = {r.threads: r.images_per_s / base for r in results}
        speedups[tier] = sp
        per_threads = {}
        for r in results:
            per_threads[str(r.threads)] = {
                "samples_per_s": round(r.images_per_s, 2),
                "bytes_per_s": round(r.total_bytes / r.seconds, 1),
                "speedup": round(r.images_per_s / base, 3),
            }
            rows.append(
                f"{tier},threads={r.threads},img_s={r.images_per_s:.1f},"
                f"mb_s={r.mb_per_s:.2f},speedup={r.images_per_s / base:.2f}")
        result[tier] = per_threads
    derived = (
        f"hdd 2/4/8-thread speedup={speedups.get('hdd', {}).get(2, 0):.2f}/"
        f"{speedups.get('hdd', {}).get(4, 0):.2f}/"
        f"{speedups.get('hdd', {}).get(8, 0):.2f} "
        f"(paper 1.65/1.95/2.3); lustre 8-thread="
        f"{speedups.get('lustre', {}).get(8, 0):.2f} (paper 7.8)")
    emit(name, rows, derived)
    env.close()

    payload = {
        "benchmark": name,
        "config": {
            "tiers": list(tiers), "preprocess": preprocess,
            "pipeline": pipeline, "n_images": n_images,
            "mean_hw": list(mean_hw), "out_hw": list(out_hw),
            "batch_size": batch_size, "repeats": repeats,
            "thread_counts": list(thread_counts), "time_scale": time_scale,
        },
        "tiers": result,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_json = os.path.join(RESULTS_DIR, json_name)
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_json}")
    return payload


def run_smoke(**overrides) -> dict:
    """Tiny-scale CI variant: same shape of output, seconds of runtime."""
    kw = dict(tiers=("hdd", "lustre"), n_images=32, mean_hw=(48, 48),
              thread_counts=(1, 2), repeats=1, batch_size=8, out_hw=(16, 16))
    kw.update(overrides)
    return run(**kw)


if __name__ == "__main__":
    run_smoke() if "--smoke" in sys.argv else run()
