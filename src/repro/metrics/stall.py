"""Stall detection: flag training steps that fall off the rolling baseline.

The paper's Fig. 6 observable — data-wait vs compute per step — is a
*post-hoc* average.  In production the question is live: did *this* step
stall (storage hiccup, drain backlog, prefetch starvation)?
:class:`StallDetector` keeps a rolling window of recent step durations and
trips when a step exceeds ``factor x`` the window's ``quantile`` —
a rolling-percentile threshold rather than a fixed SLO, so the detector
adapts as batch size, tier, or model change.

On a trip it captures a **diagnostic snapshot**: the full metrics registry
state (``registry.collect()``) plus the tail of the active trace's spans —
the two views needed to answer *why* (which stage's latency moved, which
gauge was pinned).  Snapshots attach to the :class:`StallEvent` and are
optionally dumped as JSON files under ``snapshot_dir``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional

from . import registry as _registry


def _rolling_percentile(xs, q: float) -> float:
    # local copy of trace.report.percentile semantics (avoid a cycle:
    # trace.report imports nothing from metrics, but keep layers parallel)
    n = len(xs)
    if n == 0:
        return 0.0
    s = sorted(xs)
    if n == 1:
        return float(s[0])
    pos = (q / 100.0) * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


@dataclass
class StallEvent:
    """One tripped step: duration vs the threshold that flagged it."""

    step: int
    duration_s: float
    threshold_s: float
    baseline_s: float          # the rolling percentile the threshold scaled
    t: float                   # monotonic-ish time of the trip
    snapshot: Optional[dict] = field(default=None, repr=False)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


class StallDetector:
    """Rolling-percentile step-duration watchdog.

    ``observe(step, duration_s)`` is called once per training step.  Once
    ``min_samples`` durations are in the window, a step longer than
    ``factor * percentile(window, quantile)`` (and ``min_duration_s``)
    trips the detector:

    * a :class:`StallEvent` is appended to :attr:`events`;
    * a metrics+trace snapshot is captured (see :meth:`capture_snapshot`);
    * ``on_stall(event)`` fires if given;
    * the event is ALSO excluded from the rolling window, so one stall
      does not inflate the baseline and mask the next one.

    Thread-safe: the trainer calls ``observe`` from its loop, but tests and
    multi-trainer setups may share a detector.
    """

    def __init__(
        self,
        window: int = 64,
        quantile: float = 95.0,
        factor: float = 3.0,
        min_samples: int = 8,
        min_duration_s: float = 0.0,
        snapshot_dir: Optional[str] = None,
        trace_tail: int = 256,
        on_stall: Optional[Callable[[StallEvent], None]] = None,
    ):
        if window < 2:
            raise ValueError(f"window must be >= 2, got {window}")
        if factor <= 1.0:
            raise ValueError(f"factor must exceed 1.0, got {factor}")
        self.quantile = quantile
        self.factor = factor
        self.min_samples = max(2, min_samples)
        self.min_duration_s = min_duration_s
        self.snapshot_dir = snapshot_dir
        self.trace_tail = trace_tail
        self.on_stall = on_stall
        self.events: List[StallEvent] = []
        self._window: Deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()
        self._epoch = time.monotonic()

    # -- the per-step hook ---------------------------------------------------
    def observe(self, step: int, duration_s: float) -> Optional[StallEvent]:
        with self._lock:
            tripped = False
            baseline = threshold = 0.0
            if len(self._window) >= self.min_samples:
                baseline = _rolling_percentile(self._window, self.quantile)
                threshold = max(self.factor * baseline, self.min_duration_s)
                tripped = duration_s > threshold > 0.0
            if not tripped:
                self._window.append(duration_s)
        if not tripped:
            return None
        event = StallEvent(
            step=step,
            duration_s=duration_s,
            threshold_s=threshold,
            baseline_s=baseline,
            t=time.monotonic() - self._epoch,
            snapshot=self.capture_snapshot(step),
        )
        with self._lock:
            self.events.append(event)
        if self.snapshot_dir:
            self._dump(event)
        if self.on_stall is not None:
            self.on_stall(event)
        return event

    # -- diagnostics ---------------------------------------------------------
    def capture_snapshot(self, step: int) -> dict:
        """Metrics registry state + active-trace span tail, as plain data."""
        snap: dict = {"step": step}
        reg = _registry.get_registry()
        if reg is not None:
            snap["metrics"] = reg.collect()
        from .. import trace  # late: trace never imports metrics

        tracer = trace.get_tracer()
        if tracer is not None:
            spans = tracer.spans()[-self.trace_tail:]
            snap["trace_spans"] = [
                dict(stage=r.stage, name=r.name, tid=r.tid, thread=r.thread,
                     t0=r.t0, dur=r.dur, nbytes=r.nbytes)
                for r in spans
            ]
        return snap

    def _dump(self, event: StallEvent) -> str:
        os.makedirs(self.snapshot_dir, exist_ok=True)
        path = os.path.join(self.snapshot_dir,
                            f"stall_step{event.step}.json")
        with open(path, "w") as f:
            json.dump(event.to_dict(), f, indent=2)
        return path

    def summary(self) -> dict:
        with self._lock:
            return dict(
                stalls=len(self.events),
                window_len=len(self._window),
                baseline_p_s=_rolling_percentile(self._window, self.quantile),
                steps=[e.step for e in self.events],
            )
