"""Fig. 9/10 analogue: checkpoint-to-tier runtimes + burst buffer (the 2.6x),
with dstat-style write traces on each tier (Fig. 10).

Protocol (scaled): N_ITERS training iterations, checkpoint every CKPT_EVERY,
sync to device; compare no-ckpt / hdd / ssd / optane / burst-buffer
(optane stage + async drain to hdd).
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs.alexnet_mini import AlexNetConfig
from repro.core import make_storage
from repro.core.burst_buffer import BurstBufferCheckpointer, DirectCheckpointer
from repro.core.dataset import image_pipeline
from repro.core.stats import IOTracer
from repro.models import alexnet as A

from .common import emit, BenchEnv

# bigger FC stack -> ~19 MB checkpoint (paper: ~600 MB vs GPU-scale compute;
# same compute:checkpoint ratio ballpark at our scale)
CFG = AlexNetConfig(name="alexnet-ckpt", in_hw=64,
                    filters=(16, 32, 48, 32, 32), fc=(2048, 2048))
N_ITERS = 30
CKPT_EVERY = 10
CKPT_TIME_SCALE = float(os.environ.get("REPRO_CKPT_TIME_SCALE", "4.0"))  # >1 slows the ckpt tiers: reproduces the paper 600MB-ckpt-vs-GPU-step ratio at our 19MB/CPU scale


def make_step():
    @jax.jit
    def step(params, imgs, labels):
        loss, g = jax.value_and_grad(
            lambda p: A.loss_fn(p, imgs, labels, CFG))(params)
        return jax.tree.map(lambda p, gg: p - 1e-4 * gg, params, g), loss

    return step


def run_training(checkpointer, data_st, paths, labels, step):
    params = A.init_params(jax.random.PRNGKey(0), CFG)
    ds = image_pipeline(data_st, paths, labels, batch_size=16,
                        num_parallel_calls=4, prefetch=1,
                        out_hw=(CFG.in_hw, CFG.in_hw), repeat=True)
    it = iter(ds)
    imgs, lbls = next(it)
    params, _ = step(params, jnp.asarray(imgs), jnp.asarray(lbls))  # compile
    t0 = time.monotonic()
    for i in range(1, N_ITERS + 1):
        imgs, lbls = next(it)
        params, loss = step(params, jnp.asarray(imgs), jnp.asarray(lbls))
        loss.block_until_ready()
        if checkpointer is not None and i % CKPT_EVERY == 0:
            checkpointer.save(i, {"params": params})
    runtime = time.monotonic() - t0
    drain_s = 0.0
    if checkpointer is not None:
        t1 = time.monotonic()
        checkpointer.wait()
        drain_s = time.monotonic() - t1
        checkpointer.close()
    return runtime, drain_s


def run() -> None:
    env = BenchEnv(tiers=("ssd",), n_images=128, mean_hw=(48, 48))
    data_st, (paths, labels) = env.storages["ssd"], env.corpora["ssd"]
    step = make_step()
    rows, runtimes, tracers = [], {}, {}

    from .common import SCRATCH
    with tempfile.TemporaryDirectory(dir=SCRATCH) as root:
        def tier(name, kind=None):
            tr = IOTracer(0.25)
            st = make_storage(kind or name, os.path.join(root, name + "_ck"),
                              tr, time_scale=CKPT_TIME_SCALE)
            tracers[name] = tr
            return st

        # baseline: no checkpoints
        t, _ = run_training(None, data_st, paths, labels, step)
        runtimes["none"] = t
        rows.append(f"target=none,runtime_s={t:.2f},blocked_s=0")

        for name in ("hdd", "ssd", "optane"):
            ck = DirectCheckpointer(tier(name), f"{name}/m", sync=True)
            t, _ = run_training(ck, data_st, paths, labels, step)
            runtimes[name] = t
            rows.append(f"target={name},runtime_s={t:.2f},"
                        f"blocked_s={sum(ck.blocked_s):.2f}")

        fast = tier("optane_bb", "optane")
        slow_tr = IOTracer(0.25)
        slow = make_storage("hdd", os.path.join(root, "hdd_bb"), slow_tr,
                            time_scale=CKPT_TIME_SCALE)
        tracers["hdd_bb"] = slow_tr
        bb = BurstBufferCheckpointer(fast, slow, "bb/m", sync=True)
        t, drain = run_training(bb, data_st, paths, labels, step)
        runtimes["burst_buffer"] = t
        rows.append(f"target=burst_buffer,runtime_s={t:.2f},"
                    f"blocked_s={sum(bb.blocked_s):.2f},"
                    f"post_run_drain_s={drain:.2f}")

        speedup = runtimes["hdd"] / runtimes["burst_buffer"]
        vs_optane = runtimes["burst_buffer"] / runtimes["optane"]
        emit("fig9_checkpoint", rows,
             f"burst-buffer speedup vs direct-hdd={speedup:.2f}x "
             f"(paper 2.6x); bb/optane runtime ratio={vs_optane:.2f} "
             f"(paper ~1.0)")

        # Fig. 10: dstat write traces
        trace_rows = []
        for name in ("hdd", "optane_bb", "hdd_bb"):
            for r in tracers[name].timeline():
                if r["write_mb"] > 0:
                    trace_rows.append(
                        f"device={name},t={r['t']:.2f},write_mb={r['write_mb']:.2f}")
        emit("fig10_trace", trace_rows,
             "hdd_bb (drain) writes lag optane_bb (stage) and extend past "
             "training end — the paper's Fig. 10 pattern")
    env.close()


if __name__ == "__main__":
    run()
