"""Logical-axis sharding rules with divisibility fallback.

Model code names tensor dimensions with *logical* axes ("batch", "d_ff",
"heads", ...).  A :class:`ShardingCtx` resolves logical axes to mesh axes via
a rules table, dropping any assignment whose mesh-axis product does not
evenly divide the dimension (JAX requires even sharding at jit boundaries).

Default physical mapping (see DESIGN.md §3):

* ``batch``   -> ("pod", "data")   — DP, hierarchical across pods
* ``d_ff`` / ``vocab`` / ``heads`` / ``expert_ff`` -> "model"  — TP
* ``d_model`` (weight dim) -> "data" — FSDP/ZeRO weight+optimizer sharding
* ``seq``     -> None by default; "model" when sequence-parallel (SP) is on
* ``kv_seq``  -> "model" for long-context decode

Every rule is checked against the actual dim size; a non-divisible
assignment falls back to ``None`` (replicated) for that dim.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisAssignment = Union[None, str, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, AxisAssignment] = {
    "batch": ("pod", "data"),
    "seq": None,              # attention-internal seq dim
    "res_seq": None,          # residual-stream seq dim; "model" = sequence parallel
    "kv_seq": None,           # "model" for long-context decode cells
    "d_model": None,          # activations: replicated feature dim
    "d_model_w": "data",      # weights: FSDP dim
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "vocab": "model",
    "experts": None,
    # MoE token groups carry the batch partitioning after the
    # (B,S,D)->(G,chunk,D) reshape.  NOT "model": the model axis must stay
    # on d_ff inside the expert matmuls — claiming it for G forces GSPMD to
    # all-gather full fp32 expert weights per layer (3 GiB each on mixtral).
    "moe_groups": ("pod", "data"),
    "conv_w": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "d_inner": "model",
    "stack": None,            # scan-over-layers leading dim
    "enc_seq": None,
}


# logical dims whose failed mesh assignment re-routes onto head_dim
FALLBACK_TO_HEAD_DIM = ("heads", "kv_heads", "ssm_heads")


@dataclass(frozen=True)
class ShardingCtx:
    """Resolves logical specs against a mesh; no-op when mesh is None."""

    mesh: Optional[Mesh] = None
    rules: Dict[str, AxisAssignment] = field(default_factory=lambda: dict(DEFAULT_RULES))
    head_dim_fallback: bool = True

    def with_rules(self, **updates: AxisAssignment) -> "ShardingCtx":
        rules = dict(self.rules)
        rules.update(updates)
        return replace(self, rules=rules)

    # -- resolution ---------------------------------------------------------
    def _axis_size(self, axes: AxisAssignment) -> int:
        if axes is None or self.mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= self.mesh.shape.get(a, 1)
        return size

    def _present(self, axes: AxisAssignment) -> AxisAssignment:
        """Drop mesh axes that don't exist in this mesh (e.g. 'pod' on the
        single-pod mesh)."""
        if axes is None or self.mesh is None:
            return None
        if isinstance(axes, str):
            return axes if axes in self.mesh.shape else None
        kept = tuple(a for a in axes if a in self.mesh.shape)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for dims named by ``logical`` (None = replicated).

        If ``shape`` is given, any assignment that does not divide the dim
        evenly is dropped — and, for head dims, *re-routed*: an arch with
        28/40/56/8 q-heads cannot shard heads 16-way, so the same mesh axes
        fall back onto ``head_dim`` (128/256 always divides).  Contracting
        over a sharded head_dim costs a partial-sum all-reduce but keeps
        attention compute and weights 16-way parallel (see EXPERIMENTS.md
        §Perf iteration 3)."""
        parts = []
        used: set = set()
        failed_axes: Dict[str, AxisAssignment] = {}
        for i, name in enumerate(logical):
            axes = self._present(self.rules.get(name)) if name else None
            if axes is not None:
                flat = (axes,) if isinstance(axes, str) else axes
                if any(a in used for a in flat):
                    axes = None  # a mesh axis may appear only once per spec
            if axes is not None and shape is not None:
                if shape[i] % self._axis_size(axes) != 0:
                    if name in FALLBACK_TO_HEAD_DIM:
                        failed_axes["head_dim"] = axes
                    axes = None
            if axes is not None:
                flat = (axes,) if isinstance(axes, str) else axes
                used.update(flat)
            parts.append(axes)
        if failed_axes and self.head_dim_fallback and shape is not None:
            for i, name in enumerate(logical):
                axes = failed_axes.get(name if name == "head_dim" else "")
                if (name == "head_dim" and parts[i] is None
                        and "head_dim" in failed_axes):
                    axes = failed_axes["head_dim"]
                    flat = (axes,) if isinstance(axes, str) else axes
                    if (not any(a in used for a in flat)
                            and shape[i] % self._axis_size(axes) == 0):
                        parts[i] = axes
                        used.update(flat)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical, shape))

    # -- constraint ----------------------------------------------------------
    def constrain(self, x, *logical: Optional[str]):
        """with_sharding_constraint against the resolved spec (no-op if no
        mesh)."""
        if self.mesh is None:
            return x
        sh = NamedSharding(self.mesh, self.spec(logical, x.shape))
        return jax.lax.with_sharding_constraint(x, sh)

    # -- dp axes helpers -------------------------------------------------------
    @property
    def n_data(self) -> int:
        return self._axis_size(self._present(self.rules.get("batch")))

    @property
    def n_model(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get("model", 1)


def tree_shardings(ctx: ShardingCtx, tree_logical: Any, tree_shapes: Any):
    """Map a pytree of logical-dims tuples + a matching pytree of shapes to a
    pytree of NamedShardings (or None without a mesh)."""
    return jax.tree.map(
        lambda logical, shape: ctx.sharding(logical, shape),
        tree_logical,
        tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )
