"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to fake the pod slice on CPU.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_smoke_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for tests (requires >=prod(shape) fake devices)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def devices_per_pod(mesh) -> int:
    """Devices in one pod (everything except the 'pod' axis)."""
    n = 1
    for name, size in mesh.shape.items():
        if name != "pod":
            n *= size
    return n
