"""Per-arch smoke tests: reduced config, forward + train step on CPU,
shape + finiteness + params-updated assertions (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, runnable_cells
from repro.models.registry import model_fns
from repro.train import steps as S
from repro.train.optimizer import OptConfig

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, rng, B=2, S_len=16):
    tokens = jax.random.randint(rng, (B, S_len + 1), 0, cfg.padded_vocab,
                                dtype=jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, 8, cfg.smoke().d_model), jnp.float32).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = ARCHS[arch].smoke()
        fns = model_fns(cfg)
        params = fns.init_params(jax.random.PRNGKey(0), cfg)
        B, L = 2, 16
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                    cfg.padded_vocab, dtype=jnp.int32)
        if fns.is_encdec:
            frames = jax.random.normal(jax.random.PRNGKey(2), (B, 8, cfg.d_model))
            logits, aux = fns.forward(params, frames.astype(jnp.bfloat16),
                                      tokens, cfg, remat=False)
        else:
            logits, aux = fns.forward(params, tokens, cfg, remat=False)
        assert logits.shape == (B, L, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert np.isfinite(float(aux))

    def test_train_step_updates_params(self, arch):
        cfg = ARCHS[arch].smoke()
        opt = OptConfig(lr=1e-2)
        state = S.init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step = jax.jit(S.make_train_step(cfg, opt, None, remat=False,
                                         q_chunk=16, kv_chunk=16))
        batch = _batch_for(cfg, jax.random.PRNGKey(1))
        new_state, metrics = step(state, batch)
        assert np.isfinite(metrics["loss"])
        assert int(new_state["step"]) == 1
        # at least the embedding moved
        before = np.asarray(state["params"]["embed"], np.float32)
        after = np.asarray(new_state["params"]["embed"], np.float32)
        assert not np.array_equal(before, after)
        # loss decreases over a few steps on a repeated batch
        st = new_state
        first = metrics["loss"]
        for _ in range(3):
            st, metrics = step(st, batch)
        assert metrics["loss"] < first

    def test_prefill_decode_consistency(self, arch):
        cfg = ARCHS[arch].smoke()
        fns = model_fns(cfg)
        params = fns.init_params(jax.random.PRNGKey(0), cfg)
        B, L = 1, 12
        tokens = jax.random.randint(jax.random.PRNGKey(3), (B, L), 0,
                                    cfg.padded_vocab, dtype=jnp.int32)
        if fns.is_encdec:
            frames = jax.random.normal(
                jax.random.PRNGKey(4), (B, 8, cfg.d_model)).astype(jnp.bfloat16)
            cache = fns.init_cache(cfg, B, L, 8)
            lg, cache = fns.prefill(params, frames, tokens[:, :-1], cache, cfg)
            full, _ = fns.forward(params, frames, tokens, cfg, remat=False)
            lg2, _ = fns.decode_step(params, tokens[:, -1:], cache, cfg)
        else:
            cache = fns.init_cache(cfg, B, L)
            lg, cache = fns.prefill(params, tokens[:, :-1], cache, cfg)
            full, _ = fns.forward(params, tokens, cfg, remat=False)
            lg2, _ = fns.decode_step(params, tokens[:, -1:], cache, cfg)
        ref = np.asarray(full[:, -1], np.float32)
        got = np.asarray(lg2, np.float32)
        scale = max(np.abs(ref).max(), 1.0)
        assert np.abs(got - ref).max() / scale < 0.05, (
            f"{arch}: decode diverges from forward"
        )


class TestSkipRules:
    def test_long_500k_only_for_sub_quadratic(self):
        expect_runs = {"mixtral-8x22b", "gemma3-4b", "mamba2-2.7b",
                       "jamba-1.5-large-398b"}
        for arch, cfg in ARCHS.items():
            cells = runnable_cells(cfg)
            if arch in expect_runs:
                assert "long_500k" in cells, arch
            else:
                assert "long_500k" not in cells, arch
            assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells)

    def test_cell_count_is_34(self):
        total = sum(len(runnable_cells(c)) for c in ARCHS.values())
        assert total == 34  # 40 assigned minus 6 documented long_500k skips


class TestAlexNet:
    def test_loss_decreases(self):
        from repro.configs import ALEXNET_SMOKE as cfg
        from repro.models import alexnet as A

        params = A.init_params(jax.random.PRNGKey(0), cfg)
        imgs = jax.random.normal(jax.random.PRNGKey(1),
                                 (4, cfg.in_hw, cfg.in_hw, cfg.channels))
        labels = jnp.array([0, 1, 2, 3])
        loss_grad = jax.jit(jax.value_and_grad(
            lambda p: A.loss_fn(p, imgs, labels, cfg)))
        l0, g = loss_grad(params)
        params2 = jax.tree.map(lambda p, gg: p - 0.01 * gg, params, g)
        l1, _ = loss_grad(params2)
        assert np.isfinite(l0) and l1 < l0
