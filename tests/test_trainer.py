"""Trainer loop: restart, preemption, straggler monitor, ckpt integration."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.burst_buffer import DirectCheckpointer
from repro.train.trainer import Trainer


def toy_setup():
    """A tiny quadratic 'model' so steps are fast and deterministic."""
    state = {"params": {"w": jnp.array([4.0, -2.0])}, "step": jnp.int32(0)}

    def train_step(state, batch):
        def loss(p):
            return jnp.sum((p["w"] - batch) ** 2)
        g = jax.grad(loss)(state["params"])
        new = {
            "params": {"w": state["params"]["w"] - 0.1 * g["w"]},
            "step": state["step"] + 1,
        }
        return new, {"loss": loss(state["params"])}

    def data():
        while True:
            yield jnp.zeros(2)

    return state, train_step, data()


class TestTrainerLoop:
    def test_runs_and_records(self):
        state, step_fn, data = toy_setup()
        tr = Trainer(step_fn, state, data)
        hist = tr.run(5)
        assert len(hist) == 5
        assert hist[-1]["loss"] < hist[0]["loss"]
        assert tr.step == 5
        rep = tr.report()
        assert rep["steps"] == 5 and "data_wait_frac" in rep

    def test_checkpoint_every_k(self, tmp_storage):
        state, step_fn, data = toy_setup()
        ck = DirectCheckpointer(tmp_storage, "ckpt/m", keep=10)
        tr = Trainer(step_fn, state, data, checkpointer=ck, ckpt_every=2)
        tr.run(6)
        assert ck.saver.all_steps() == [2, 4, 6]

    def test_restart_resumes_from_checkpoint(self, tmp_storage):
        state, step_fn, data = toy_setup()
        ck = DirectCheckpointer(tmp_storage, "ckpt/m")
        tr = Trainer(step_fn, state, data, checkpointer=ck, ckpt_every=3)
        tr.run(3)
        w_after_3 = np.asarray(jax.device_get(tr.state["params"]["w"]))

        # "crash" and restart from a fresh initial state
        state2, step_fn2, data2 = toy_setup()
        ck2 = DirectCheckpointer(tmp_storage, "ckpt/m")
        tr2 = Trainer(step_fn2, state2, data2, checkpointer=ck2, resume=True)
        assert tr2.step == 3
        np.testing.assert_allclose(
            np.asarray(jax.device_get(tr2.state["params"]["w"])), w_after_3)

    def test_preemption_checkpoints_and_stops(self, tmp_storage):
        state, step_fn, data = toy_setup()
        ck = DirectCheckpointer(tmp_storage, "ckpt/m")
        tr = Trainer(step_fn, state, data, checkpointer=ck)
        tr.request_stop()
        tr.run(100)
        assert tr.step == 1          # stopped at first boundary
        assert ck.latest_step() == 1  # preemption checkpoint written

    def test_straggler_monitor_flags_slow_input(self):
        import time

        state, step_fn, _ = toy_setup()

        def slow_data():
            while True:
                time.sleep(0.03)
                yield jnp.zeros(2)

        tr = Trainer(step_fn, state, slow_data(), straggler_threshold=0.2)
        tr.run(5)
        rep = tr.report()
        assert rep["straggler_suspect"], rep
