"""Sharded checkpoint save/restore (paper §II-B: tf.train.Saver analogue).

Layout mirrors TF's Saver, generalized to N data shards (one per writer
host on a pod):

    <prefix>-<step>.meta                   # JSON: step, treedef, user config
    <prefix>-<step>.index                  # JSON: tensor -> (shard, offset, ...)
    <prefix>-<step>.data-00000-of-00004    # raw tensor bytes
    <prefix>-<step>.data-00001-of-00004
    ...
    checkpoint                             # commit marker: latest + retained steps

Guarantees:

* **Atomic commit** — data/index/meta are fully written (and optionally
  fsync'd, paper §III-C) *before* the ``checkpoint`` marker is rewritten;
  a crash mid-save leaves the previous checkpoint restorable.
* **Retention** — keep the newest ``keep`` checkpoints (TF default 5).
* **Elastic restore** — the index is topology-free; restore can re-shard
  onto any mesh via ``jax.make_array_from_callback``.
* **Parallel shard I/O** — the N data shards are written (and read back)
  concurrently on an ``io_threads`` pool, the write-side analogue of the
  paper's read thread-scaling (Fig. 4/5); ``save_flat`` takes an
  already-snapshotted flat dict so :class:`repro.core.async_checkpoint.
  AsyncCheckpointer` can run the whole write off the training thread.
* **int8 option** — blockwise-quantized storage (2x–4x smaller bursts), with
  scales stored alongside; see also ``repro.kernels.quantize`` for the TPU
  kernel version of the same transform.
"""
from __future__ import annotations

import io
import json
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import trace

CHECKPOINT_MARKER = "checkpoint"
_QBLOCK = 256  # quantization block (last-dim) size


def write_marker(storage, path: str, payload: bytes, sync: bool = True) -> None:
    """Commit-marker write: tmp file + atomic rename.

    A plain ``write_file`` truncates-then-writes, so a crash *mid-marker*
    (a torn write — see ``repro.core.faults``) can leave a corrupt marker
    and make **both** the old and new checkpoint unreachable.  Writing to a
    sibling tmp and renaming keeps the old marker intact until the new one
    exists in full; ``sync=True`` makes the tmp durable (a write barrier)
    before the rename publishes it — the restorability commit point of the
    whole protocol.
    """
    tmp = path + ".tmp"
    storage.write_file(tmp, payload, sync=sync)
    storage.rename(tmp, path)

#: dtypes eligible for int8 blockwise quantization (by name, so the check
#: never needs np.dtype("bfloat16") — which raises unless ml_dtypes has
#: registered it).
_QUANTIZABLE_DTYPES = ("float32", "float64", "bfloat16")


def resolve_dtype(name: str) -> np.dtype:
    """``np.dtype(name)`` with an ``ml_dtypes`` fallback.

    Extension dtypes (bfloat16, float8_*, ...) are only resolvable by
    string name once ``ml_dtypes`` has been imported somewhere in the
    process; a checkpoint written from a jax pytree but restored in a
    process that never touched jax would otherwise crash with a bare
    ``TypeError: data type 'bfloat16' not understood``.
    """
    try:
        return np.dtype(name)
    except TypeError:
        pass
    try:
        import ml_dtypes
    except ImportError as e:
        raise TypeError(
            f"checkpoint dtype {name!r} is not a numpy builtin and "
            "ml_dtypes is not installed; install ml_dtypes (a jax "
            "dependency) to restore extension-dtype tensors"
        ) from e
    try:
        return np.dtype(getattr(ml_dtypes, name))
    except (AttributeError, TypeError) as e:
        raise TypeError(f"unknown checkpoint dtype {name!r}") from e


# ---------------------------------------------------------------------------
# pytree <-> flat dict of numpy arrays
# ---------------------------------------------------------------------------
def flatten_pytree(tree: Any, copy: bool = False) -> Tuple[Dict[str, np.ndarray], Any]:
    """Flatten ``tree`` to ``{path: host ndarray}`` + its treedef.

    With ``copy=True`` the result is a true point-in-time snapshot that a
    background writer can consume while training mutates the originals:
    any leaf that still aliases caller-owned memory is copied.  That
    includes numpy leaves (passed through by reference) *and* CPU-backend
    jax arrays, where ``np.asarray(jax.device_get(x))`` can be a zero-copy
    view of the live XLA buffer — lethal under donated arguments.
    """
    import jax

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    flat = {}
    for path, leaf in leaves_with_paths:
        key = "/".join(_path_str(p) for p in path) or "leaf"
        arr = np.asarray(jax.device_get(leaf))
        if copy and (arr is leaf or arr.base is not None
                     or not arr.flags["OWNDATA"]):
            arr = np.array(arr, copy=True)
        flat[key] = arr
    return flat, treedef


def _path_str(p) -> str:
    import jax

    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def unflatten_pytree(flat: Dict[str, np.ndarray], treedef) -> Any:
    import jax

    # Re-flatten a skeleton to get key order, then rebuild.
    skeleton = jax.tree_util.tree_unflatten(treedef, list(range(treedef.num_leaves)))
    paths = jax.tree_util.tree_flatten_with_path(skeleton)[0]
    ordered = []
    for path, _ in paths:
        key = "/".join(_path_str(p) for p in path) or "leaf"
        ordered.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)


# ---------------------------------------------------------------------------
# int8 blockwise quantization (numpy mirror of kernels/quantize.py)
# ---------------------------------------------------------------------------
def quantize_blockwise(arr: np.ndarray, block: int = _QBLOCK):
    flat = arr.astype(np.float32).reshape(-1)
    pad = (-len(flat)) % block
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    blocks = flat.reshape(-1, block)
    scale = np.abs(blocks).max(axis=1, keepdims=True) / 127.0
    scale = np.where(scale == 0, 1.0, scale)
    q = np.clip(np.round(blocks / scale), -127, 127).astype(np.int8)
    return q, scale.astype(np.float32), pad


def dequantize_blockwise(q: np.ndarray, scale: np.ndarray, pad: int,
                         shape, dtype) -> np.ndarray:
    flat = (q.astype(np.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Saver
# ---------------------------------------------------------------------------
@dataclass
class SaveResult:
    step: int
    n_bytes: int
    seconds: float
    files: List[str]


@dataclass
class PreemptionReport:
    """Outcome of a graceful-shutdown ``preempt(deadline_s)`` call.

    ``committed_step`` is the newest step durable at the engine's
    preemption tier (the fast tier for the burst buffers) when the call
    returned; ``abandoned_steps`` are saves given up to meet the deadline —
    queued snapshots that were cancelled before touching storage, plus the
    newest in-flight save if it missed the budget.  ``deadline_met`` is
    False only in that last case."""

    committed_step: Optional[int]
    abandoned_steps: List[int]
    deadline_s: Optional[float]
    elapsed_s: float
    deadline_met: bool


class CheckpointSaver:
    """TF-Saver-like sharded checkpointer over a :class:`Storage`.

    ``io_threads`` controls shard-level I/O concurrency: the N data shards
    are written (and, on restore, read) on a thread pool of that size — the
    write-side analogue of the paper's read thread-scaling (Fig. 4/5: 2.3x
    on HDD, 7.8x on Lustre).  ``None`` (default) sizes the pool to
    ``min(n_shards, 8)``; ``1`` forces serial I/O.
    """

    def __init__(
        self,
        storage,
        prefix: str = "ckpt/model",
        *,
        keep: int = 5,
        n_shards: int = 1,
        sync: bool = True,
        quantize: Optional[str] = None,  # None | "int8"
        io_threads: Optional[int] = None,
    ):
        self.storage = storage
        self.prefix = prefix
        self.keep = keep
        self.n_shards = max(1, n_shards)
        self.sync = sync
        self.quantize = quantize
        self.io_threads = (
            min(self.n_shards, 8) if io_threads is None else max(1, io_threads)
        )
        d = prefix.rsplit("/", 1)[0] if "/" in prefix else "."
        self._dir = d
        storage.makedirs(d)

    # -- naming ----------------------------------------------------------------
    def _base(self, step: int) -> str:
        return f"{self.prefix}-{step}"

    def _marker_path(self) -> str:
        return f"{self._dir}/{CHECKPOINT_MARKER}"

    # -- save --------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra_meta: Optional[dict] = None) -> SaveResult:
        t0 = time.monotonic()
        with trace.span(trace.STAGE_CKPT_SNAPSHOT,
                        f"snapshot:{self.prefix}-{step}") as sp:
            flat, treedef = flatten_pytree(tree)
            sp.set_bytes(sum(a.nbytes for a in flat.values()))
        result = self.save_flat(step, flat, extra_meta, treedef=treedef)
        result.seconds = time.monotonic() - t0  # include the snapshot
        return result

    def save_flat(self, step: int, flat: Dict[str, np.ndarray],
                  extra_meta: Optional[dict] = None, *,
                  treedef=None) -> SaveResult:
        """Save an already-snapshotted flat dict of host arrays (the entry
        point the async engine calls from its writer thread)."""
        with trace.span(trace.STAGE_CKPT_WRITE, f"save:{self.prefix}-{step}") as sp:
            result = self._save_flat(step, flat, extra_meta, treedef)
            sp.set_bytes(result.n_bytes)
        return result

    def _serialize(self, flat: Dict[str, np.ndarray]):
        """Pack tensors into per-shard byte buffers + the tensor index."""
        # Assign tensors to shards round-robin by size (largest first) so the
        # N writer hosts carry balanced bytes.
        names = sorted(flat, key=lambda k: -flat[k].nbytes)
        shard_of: Dict[str, int] = {}
        shard_bytes = [0] * self.n_shards
        for name in names:
            s = int(np.argmin(shard_bytes))
            shard_of[name] = s
            shard_bytes[s] += flat[name].nbytes

        buffers = [io.BytesIO() for _ in range(self.n_shards)]
        index: Dict[str, dict] = {}
        for name in flat:
            arr = flat[name]
            s = shard_of[name]
            buf = buffers[s]
            entry: Dict[str, Any] = dict(
                shard=s,
                offset=buf.tell(),
                shape=list(arr.shape),
                dtype=str(arr.dtype),
            )
            if (self.quantize == "int8"
                    and str(arr.dtype) in _QUANTIZABLE_DTYPES
                    and arr.size >= _QBLOCK):
                q, scale, pad = quantize_blockwise(arr)
                buf.write(q.tobytes())
                entry.update(
                    quant="int8", qpad=pad, qblock=_QBLOCK,
                    scale_offset=buf.tell(), scale_len=scale.nbytes,
                )
                buf.write(scale.tobytes())
                entry["length"] = buf.tell() - entry["offset"]
            else:
                data = arr.tobytes()
                buf.write(data)
                entry["length"] = len(data)
            index[name] = entry
        return buffers, index

    def _save_flat(self, step: int, flat: Dict[str, np.ndarray],
                   extra_meta: Optional[dict] = None,
                   treedef=None) -> SaveResult:
        t0 = time.monotonic()
        base = self._base(step)
        buffers, index = self._serialize(flat)

        files: List[str] = []
        total = 0
        # 1) data shards — concurrently on the writer pool (any failure
        #    aborts the save before the marker is touched)
        shard_paths = [
            f"{base}.data-{s:05d}-of-{self.n_shards:05d}"
            for s in range(self.n_shards)
        ]
        # getbuffer(): zero-copy views — getvalue() would transiently double
        # peak host memory on a multi-GB checkpoint
        shard_blobs = [buf.getbuffer() for buf in buffers]
        if self.io_threads > 1 and self.n_shards > 1:
            with ThreadPoolExecutor(
                min(self.io_threads, self.n_shards),
                thread_name_prefix="ckpt-shard-io",
            ) as pool:
                futs = [
                    pool.submit(self.storage.write_file, p, b, self.sync)
                    for p, b in zip(shard_paths, shard_blobs)
                ]
                for f in futs:
                    f.result()
        else:
            for p, b in zip(shard_paths, shard_blobs):
                self.storage.write_file(p, b, sync=self.sync)
        files.extend(shard_paths)
        total += sum(len(b) for b in shard_blobs)
        # 2) index
        index_blob = json.dumps(dict(tensors=index, n_shards=self.n_shards)).encode()
        self.storage.write_file(f"{base}.index", index_blob, sync=self.sync)
        files.append(f"{base}.index")
        total += len(index_blob)
        # 3) meta (graph-structure analogue: the treedef + user config)
        meta = dict(
            step=step,
            treedef=None if treedef is None else str(treedef),
            created=time.time(),
            quantize=self.quantize,
            extra=extra_meta or {},
        )
        meta_blob = json.dumps(meta).encode()
        self.storage.write_file(f"{base}.meta", meta_blob, sync=self.sync)
        files.append(f"{base}.meta")
        total += len(meta_blob)
        if self.sync:
            self.storage.fsync_dir(self._dir)  # paper: syncfs() after Saver

        # 4) commit marker LAST (atomicity), then retention.
        steps = self.all_steps()
        if step not in steps:
            steps.append(step)
        steps.sort()
        retained = steps[-self.keep:]
        marker = json.dumps(dict(latest=step, all_steps=retained)).encode()
        write_marker(self.storage, self._marker_path(), marker,
                     sync=self.sync)
        for old in steps[:-self.keep] if len(steps) > self.keep else []:
            self._delete_step(old)

        return SaveResult(step, total, time.monotonic() - t0, files)

    def _delete_step(self, step: int) -> None:
        base_name = self._base(step).rsplit("/", 1)[-1]
        for name in self.storage.listdir(self._dir):
            if name.startswith(base_name + "."):
                self.storage.remove(f"{self._dir}/{name}")

    # -- introspection -----------------------------------------------------------
    def all_steps(self) -> List[int]:
        if not self.storage.exists(self._marker_path()):
            return []
        marker = json.loads(self.storage.read_file(self._marker_path()))
        return list(marker.get("all_steps", []))

    def latest_step(self) -> Optional[int]:
        if not self.storage.exists(self._marker_path()):
            return None
        return json.loads(self.storage.read_file(self._marker_path()))["latest"]

    # -- restore -------------------------------------------------------------------
    def restore(self, step: Optional[int] = None, treedef=None) -> Tuple[Dict[str, np.ndarray], dict]:
        """Return (flat dict of numpy arrays, meta). Use ``treedef`` (or
        ``restore_pytree``) to rebuild the original structure."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {self.prefix}")
        with trace.span(trace.STAGE_CKPT_RESTORE, f"restore:{self.prefix}-{step}") as sp:
            flat, meta = self._restore(step)
            sp.set_bytes(sum(a.nbytes for a in flat.values()))
        return flat, meta

    def _restore(self, step: int) -> Tuple[Dict[str, np.ndarray], dict]:
        base = self._base(step)
        meta = json.loads(self.storage.read_file(f"{base}.meta"))
        index = json.loads(self.storage.read_file(f"{base}.index"))
        n_shards = index["n_shards"]
        shard_paths = [
            f"{base}.data-{s:05d}-of-{n_shards:05d}" for s in range(n_shards)
        ]
        # shard reads on the same pool policy as shard writes (Fig. 4/5:
        # read thread-scaling is the paper's headline result)
        if self.io_threads > 1 and n_shards > 1:
            with ThreadPoolExecutor(
                min(self.io_threads, n_shards),
                thread_name_prefix="ckpt-shard-io",
            ) as pool:
                blobs = list(pool.map(self.storage.read_file, shard_paths))
        else:
            blobs = [self.storage.read_file(p) for p in shard_paths]
        shards: Dict[int, bytes] = dict(enumerate(blobs))
        flat: Dict[str, np.ndarray] = {}
        for name, e in index["tensors"].items():
            raw = shards[e["shard"]][e["offset"] : e["offset"] + e["length"]]
            shape, dtype = tuple(e["shape"]), resolve_dtype(e["dtype"])
            if e.get("quant") == "int8":
                qlen = e["scale_offset"] - e["offset"]
                q = np.frombuffer(raw[:qlen], dtype=np.int8).reshape(-1, e["qblock"])
                scale = np.frombuffer(
                    raw[qlen : qlen + e["scale_len"]], dtype=np.float32
                ).reshape(-1, 1)
                flat[name] = dequantize_blockwise(q, scale, e["qpad"], shape, dtype)
            else:
                flat[name] = np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
        return flat, meta

    def restore_pytree(self, skeleton: Any, step: Optional[int] = None) -> Any:
        """Restore into the structure of ``skeleton`` (a pytree of anything)."""
        import jax

        flat, _meta = self.restore(step)
        treedef = jax.tree_util.tree_structure(skeleton)
        return unflatten_pytree(flat, treedef)

    def restore_sharded(self, skeleton: Any, shardings: Any,
                        step: Optional[int] = None) -> Any:
        """Elastic restore: place each tensor on the mesh given by
        ``shardings`` (pytree of NamedSharding matching ``skeleton``),
        regardless of the topology that wrote the checkpoint."""
        import jax

        restored = self.restore_pytree(skeleton, step)

        def _place(arr, sharding):
            return jax.make_array_from_callback(
                arr.shape, sharding, lambda idx: arr[idx]
            )

        return jax.tree.map(_place, restored, shardings)
