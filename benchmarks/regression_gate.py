"""CI perf-regression gate over the machine-readable ``BENCH_*.json`` files.

Each benchmark that matters writes a JSON payload under ``reports/``
(fig4 -> ``BENCH_threads.json``, fig5 -> ``BENCH_read_only.json``,
fig11 -> ``BENCH_pipeline.json``).  This gate compares those against the
committed baselines in ``benchmarks/baselines/`` and exits nonzero when a
**throughput** metric regressed beyond tolerance.

Rules:

* Payloads are flattened to dotted numeric leaf paths
  (``tiers.hdd.2.samples_per_s``); only higher-is-better leaves are gated —
  those whose last path segment is in :data:`GATED_LEAVES`.  Everything
  else (configs, booleans, counts) is context, not a gate.
* A gated leaf passes iff ``new >= old * (1 - tolerance)``.  Improvements
  never fail the gate (ratcheting baselines up is ``--update``'s job).
* If the payload ``config`` sections differ, the file is **skipped with a
  warning** — a changed sweep shape makes number-to-number comparison
  meaningless, and the right fix is re-seeding, not a red build.
* A baseline with no matching report is a failure (the benchmark silently
  disappeared) unless ``--allow-missing``.

Usage::

    python -m benchmarks.regression_gate            # tolerance 0.25
    python -m benchmarks.regression_gate --smoke    # tolerance 0.50 (CI)
    python -m benchmarks.regression_gate --update   # reseed baselines
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")
REPORTS_DIR = os.environ.get("REPRO_BENCH_DIR", "reports")

# higher-is-better throughput leaves; latency metrics would need the
# opposite sense and are deliberately not gated here.  blocked_frac_saved
# (fig12) is a ratio in [0, 1] — fraction of direct-checkpoint blocked
# time the async burst buffer eliminates — so "higher is better" holds,
# and likewise goodput_frac (fig13: faulty/clean throughput under the
# retry layer, and fig15: compute over compute + preemption overhead;
# recover_s is lower-is-better and deliberately ungated — fig15 gates its
# reciprocal recovery_per_s instead), warm_speedup (fig14: warm-epoch /
# cold-epoch throughput through the block cache), and the overlap family
# (fig6: prefetch overlap gains — matched by prefix, covering
# overlap_gain / overlap_excess variants).
GATED_LEAVES = ("samples_per_s", "bytes_per_s", "speedup",
                "speedup_sharded_vs_legacy", "steps_per_s",
                "blocked_frac_saved", "goodput_frac", "warm_speedup",
                "recovery_per_s")
GATED_LEAF_PREFIXES = ("overlap",)

DEFAULT_TOLERANCE = 0.25
SMOKE_TOLERANCE = 0.50   # tiny sweeps on shared CI boxes are noisy


def flatten(obj, prefix: str = "") -> Dict[str, float]:
    """Numeric leaves of a nested dict as ``{dotted.path: value}``."""
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix[:-1]] = float(obj)
    return out


def gated_leaves(payload: dict) -> Dict[str, float]:
    def gated(path: str) -> bool:
        leaf = path.split(".")[-1]
        return (leaf in GATED_LEAVES
                or leaf.startswith(GATED_LEAF_PREFIXES))

    return {path: v for path, v in flatten(payload).items() if gated(path)}


def compare(baseline: dict, new: dict, tolerance: float,
            name: str = "?") -> Tuple[List[str], List[str]]:
    """Return ``(regressions, notes)`` for one payload pair."""
    regressions: List[str] = []
    notes: List[str] = []
    if baseline.get("config") != new.get("config"):
        notes.append(
            f"SKIP {name}: config changed (baseline stale — rerun "
            f"`--update` after reviewing)")
        return regressions, notes
    base = gated_leaves(baseline)
    cur = gated_leaves(new)
    for path, old in sorted(base.items()):
        if path not in cur:
            regressions.append(f"{name}:{path} disappeared "
                               f"(baseline {old:.6g})")
            continue
        floor = old * (1.0 - tolerance)
        if cur[path] < floor:
            regressions.append(
                f"{name}:{path} regressed: {cur[path]:.6g} < "
                f"{old:.6g} - {tolerance:.0%} (floor {floor:.6g})")
    if not base:
        notes.append(f"NOTE {name}: no gated leaves in baseline")
    return regressions, notes


def _baseline_files() -> List[str]:
    if not os.path.isdir(BASELINE_DIR):
        return []
    return sorted(f for f in os.listdir(BASELINE_DIR)
                  if f.startswith("BENCH_") and f.endswith(".json"))


def update_baselines() -> int:
    """Copy every ``reports/BENCH_*.json`` into the baseline dir."""
    os.makedirs(BASELINE_DIR, exist_ok=True)
    copied = 0
    for f in sorted(os.listdir(REPORTS_DIR)):
        if f.startswith("BENCH_") and f.endswith(".json"):
            shutil.copyfile(os.path.join(REPORTS_DIR, f),
                            os.path.join(BASELINE_DIR, f))
            print(f"seeded baseline {f}")
            copied += 1
    if copied == 0:
        print(f"no BENCH_*.json under {REPORTS_DIR}/ — run the benchmarks "
              "first", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    global REPORTS_DIR
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tolerance", type=float, default=None,
                    help="allowed fractional regression (default "
                         f"{DEFAULT_TOLERANCE})")
    ap.add_argument("--smoke", action="store_true",
                    help=f"CI smoke mode: tolerance {SMOKE_TOLERANCE}")
    ap.add_argument("--update", action="store_true",
                    help="reseed baselines from the current reports")
    ap.add_argument("--allow-missing", action="store_true",
                    help="a baseline without a matching report is skipped, "
                         "not failed")
    ap.add_argument("--reports-dir", default=REPORTS_DIR)
    args = ap.parse_args(argv)

    REPORTS_DIR = args.reports_dir
    if args.update:
        return update_baselines()

    tolerance = args.tolerance if args.tolerance is not None else (
        SMOKE_TOLERANCE if args.smoke else DEFAULT_TOLERANCE)

    files = _baseline_files()
    if not files:
        print(f"no baselines under {BASELINE_DIR}/ — seed with --update",
              file=sys.stderr)
        return 1

    all_regressions: List[str] = []
    checked = 0
    for fname in files:
        with open(os.path.join(BASELINE_DIR, fname)) as f:
            baseline = json.load(f)
        report_path = os.path.join(REPORTS_DIR, fname)
        if not os.path.exists(report_path):
            msg = f"{fname}: report missing under {REPORTS_DIR}/"
            if args.allow_missing:
                print(f"SKIP {msg}")
                continue
            all_regressions.append(msg)
            continue
        with open(report_path) as f:
            new = json.load(f)
        regs, notes = compare(baseline, new, tolerance, name=fname)
        for n in notes:
            print(n)
        all_regressions.extend(regs)
        checked += 1
        n_leaves = len(gated_leaves(baseline))
        status = "FAIL" if regs else "ok"
        print(f"{status} {fname}: {n_leaves} gated leaves, "
              f"tolerance {tolerance:.0%}")

    if all_regressions:
        print(f"\n{len(all_regressions)} perf regression(s):",
              file=sys.stderr)
        for r in all_regressions:
            print(f"  - {r}", file=sys.stderr)
        return 1
    print(f"\nregression gate passed ({checked} report(s) checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
