"""Shared fixtures. NOTE: device count stays 1 here by design — only the
dry-run sets xla_force_host_platform_device_count (see launch/dryrun.py).
Multi-device tests run in subprocesses (see test_distributed.py)."""
import sys
import tempfile

import pytest

sys.path.insert(0, "src")


@pytest.fixture()
def tmp_storage():
    from repro.core.storage import NativeStorage

    with tempfile.TemporaryDirectory() as d:
        yield NativeStorage(d)


@pytest.fixture()
def fast_slow_storage():
    """(fast, slow) simulated tiers for burst-buffer tests.

    time_scale=4 slows the modelled devices so simulated I/O time dominates
    the checkpoint serializer's real CPU cost (~13 ms/MB on this 1-core
    box) — keeps the blocked-time ratios deterministic."""
    from repro.core.storage import SimulatedStorage, TIERS

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        fast = SimulatedStorage(d1, TIERS["optane"], time_scale=4.0)
        slow = SimulatedStorage(d2, TIERS["hdd"], time_scale=4.0)
        yield fast, slow
