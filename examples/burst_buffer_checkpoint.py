"""Burst-buffer & async checkpointing demo (paper §V-C, the 2.6x result).

    PYTHONPATH=src python examples/burst_buffer_checkpoint.py          # paper's comparison
    PYTHONPATH=src python examples/burst_buffer_checkpoint.py --async  # + async engine

Checkpoints a ~75MB state to (a) direct HDD, (b) direct Optane, (c) Optane
burst buffer with multi-stream async HDD drain, printing blocked time per
strategy and proving the slow tier ends up with every checkpoint.  With
``--async``, also runs the two async engines: the
:class:`AsyncCheckpointer` (training blocks only for the host snapshot —
milliseconds — while the sharded write to HDD runs on a background writer
thread) and the fused :class:`AsyncBurstBufferCheckpointer` (snapshot
blocks; the Optane stage *and* the intra-file parallel HDD drain both run
in background threads, so not even the fast-tier write is paid by the
training thread).
"""
import os, sys, tempfile, time
sys.path.insert(0, "src")

import numpy as np

from repro.core import (AsyncBurstBufferCheckpointer, AsyncCheckpointer,
                        BurstBufferCheckpointer, DirectCheckpointer,
                        make_storage)
from repro.core.checkpoint import CheckpointSaver


def main():
    run_async = "--async" in sys.argv[1:]
    rng = np.random.default_rng(0)
    state = {"params": {f"layer{i}": rng.normal(size=(512, 9216)).astype(np.float32)
                        for i in range(4)}}
    nbytes = sum(v.nbytes for v in state["params"].values())
    print(f"checkpoint payload: {nbytes/1e6:.0f} MB")
    root = tempfile.mkdtemp()
    ts = 1.0

    hdd = make_storage("hdd", os.path.join(root, "hdd"), time_scale=ts)
    d = DirectCheckpointer(hdd, "direct_hdd/m")
    d.save(1, state)
    print(f"direct-to-HDD blocked:    {d.blocked_s[0]:.2f}s")

    opt = make_storage("optane", os.path.join(root, "opt"), time_scale=ts)
    d2 = DirectCheckpointer(opt, "direct_opt/m")
    d2.save(1, state)
    print(f"direct-to-Optane blocked: {d2.blocked_s[0]:.2f}s")

    fast = make_storage("optane", os.path.join(root, "bb_fast"), time_scale=ts)
    slow = make_storage("hdd", os.path.join(root, "bb_slow"), time_scale=ts)
    bb = BurstBufferCheckpointer(fast, slow, "bb/m")
    t0 = time.monotonic()
    bb.save(1, state)
    print(f"burst-buffer blocked:     {bb.blocked_s[0]:.2f}s "
          f"(training continues while the drain runs)")
    bb.wait()
    print(f"async drain finished at t={time.monotonic()-t0:.2f}s")
    restored = CheckpointSaver(slow, "bb/m").restore_pytree(state)
    ok = all(np.array_equal(restored["params"][k], state["params"][k])
             for k in state["params"])
    print(f"slow-tier copy bit-identical: {ok}")
    bb.close()

    if run_async:
        ahdd = make_storage("hdd", os.path.join(root, "async_hdd"),
                            time_scale=ts)
        ac = AsyncCheckpointer(ahdd, "async/m", n_shards=4)
        t0 = time.monotonic()
        handle = ac.save(1, state)
        print(f"async blocked:            {ac.blocked_s[0]:.2f}s "
              f"(snapshot only; sharded HDD write is in flight)")
        handle.result()  # the future-like handle: block = drain
        print(f"background write finished at t={time.monotonic()-t0:.2f}s")
        restored = ac.restore_pytree(state)
        ok = all(np.array_equal(restored["params"][k], state["params"][k])
                 for k in state["params"])
        print(f"async checkpoint bit-identical: {ok}")
        ac.close()

        afast = make_storage("optane", os.path.join(root, "abb_fast"),
                             time_scale=ts)
        aslow = make_storage("hdd", os.path.join(root, "abb_slow"),
                             time_scale=ts)
        abb = AsyncBurstBufferCheckpointer(afast, aslow, "abb/m",
                                           n_shards=4, drain_streams=4)
        t0 = time.monotonic()
        handle = abb.save(1, state)
        print(f"async-bb blocked:         {abb.blocked_s[0]:.2f}s "
              f"(snapshot only; Optane stage + HDD drain in flight)")
        handle.result()   # settles when the *fast* tier has committed
        print(f"fast-tier commit at t={time.monotonic()-t0:.2f}s "
              f"(step already restorable)")
        abb.wait()        # additionally drains the slow tier
        print(f"slow-tier drain finished at t={time.monotonic()-t0:.2f}s")
        restored = CheckpointSaver(aslow, "abb/m").restore_pytree(state)
        ok = all(np.array_equal(restored["params"][k], state["params"][k])
                 for k in state["params"])
        print(f"async-bb slow-tier copy bit-identical: {ok}")
        abb.close()


if __name__ == "__main__":
    main()
