"""int8-compressed gradient all-reduce over the DCN ('pod') axis.

At 2+ pods the data-center network between pods is the thin pipe; the
standard trick is to compress the cross-pod gradient reduction.  We
implement an int8 blockwise-quantized psum with shard_map:

    q8(g) -> psum(int32 accum of q, fp32 psum of scales is NOT valid;
    instead each shard contributes q*s locally dequantized after an
    all_gather of the (q, s) pairs over the small pod axis)

For a pod axis of size 2 (assignment mesh) the all_gather of quantized
payloads moves 4x fewer bytes than an fp32 ring all-reduce and 2x fewer
than bf16, at ~0.4% relative error (see tests/test_compress.py).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

QBLOCK = 256


def _q8_flat(x):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % QBLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def compressed_psum_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean-reduce ``x`` across ``axis_name`` with int8 payloads.

    Must be called inside shard_map with ``axis_name`` in scope.  Each
    participant quantizes its contribution, all-gathers the (q, scales)
    pair, dequantizes and averages locally.
    """
    q, s, pad = _q8_flat(x)
    qs = jax.lax.all_gather(q, axis_name)        # (n, nblocks, QBLOCK) int8
    ss = jax.lax.all_gather(s, axis_name)        # (n, nblocks, 1) fp32
    deq = (qs.astype(jnp.float32) * ss).mean(axis=0).reshape(-1)
    n = x.size
    return deq[:n].reshape(x.shape).astype(x.dtype)


def compressed_allreduce_stacked(mesh, x: jax.Array, axis_name: str = "pod"
                                 ) -> jax.Array:
    """Mean-reduce per-pod contributions with int8 payloads.

    ``x`` has a leading dim equal to the pod-axis size (one local gradient
    per pod), sharded over ``axis_name``.  Returns the mean contribution
    (shape ``x.shape[1:]``), numerically within q8 error of ``x.mean(0)``.
    """
    def per_shard(xs):                       # xs: (1, ...) local slice
        return compressed_psum_mean(xs[0], axis_name)[None]

    nd = x.ndim
    spec = P(axis_name, *([None] * (nd - 1)))
    if hasattr(jax, "shard_map"):
        f = jax.shard_map(per_shard, mesh=mesh, in_specs=spec,
                          out_specs=spec, check_vma=False)
    else:  # older jax: experimental location, check_rep instead of check_vma
        from jax.experimental.shard_map import shard_map as _shard_map

        f = _shard_map(per_shard, mesh=mesh, in_specs=spec,
                       out_specs=spec, check_rep=False)
    return f(x)[0]
