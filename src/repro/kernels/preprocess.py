"""Fused image preprocess — Pallas TPU kernels (input-pipeline hot spot).

The paper's mapped function ends with convert_image_dtype + normalization on
the CPU.  On a TPU pod the natural split (DESIGN.md hardware-adaptation) is:
host decodes, device does the arithmetic.  Two kernels:

* :func:`normalize_images` fuses uint8->f32 cast, [0,1] scaling, and
  per-channel (x - mean)/std in one VMEM pass.
* :func:`resize_convert_images` fuses bilinear resize AND dtype conversion
  for a whole uniform-size batch: resize is expressed as two small
  interpolation matmuls (``Ry @ X @ Rx^T``), which maps onto the MXU
  instead of the gather units, and the [0,1] conversion scale is folded
  into ``Ry`` so it costs nothing.  :func:`resize_convert` dispatches
  between this kernel and the batched numpy LUT-gather fallback
  (:func:`repro.core.records.resize_batch`) on CPU-only hosts.

TPU layout choice for normalize: NHWC with C=3 would waste 128-wide lanes,
so the wrapper moves channels to the sublane dim: (B, C, H*W).  Each grid
step handles one image's (C, PIX_TILE) tile; mean/std live in SMEM-like
small refs (C, 1).
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

PIX_TILE = 2048


def _normalize_kernel(x_ref, mean_ref, std_ref, o_ref):
    x = x_ref[...].astype(jnp.float32) * (1.0 / 255.0)   # (1, C, T)
    mean = mean_ref[...][None, :, :]                     # (1, C, 1)
    std = std_ref[...][None, :, :]
    o_ref[...] = (x - mean) / std


def normalize_images(x: jax.Array, mean: jax.Array, std: jax.Array,
                     *, interpret: bool = True) -> jax.Array:
    """x: (B, C, P) uint8, mean/std: (C,) -> (B, C, P) float32."""
    B, C, P = x.shape
    tile = min(PIX_TILE, P)
    grid = (B, pl.cdiv(P, tile))
    return pl.pallas_call(
        _normalize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, tile), lambda b, i: (b, 0, i)),
            pl.BlockSpec((C, 1), lambda b, i: (0, 0)),
            pl.BlockSpec((C, 1), lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, tile), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((B, C, P), jnp.float32),
        interpret=interpret,
    )(x, mean.reshape(C, 1), std.reshape(C, 1))


# ---------------------------------------------------------------------------
# Batched bilinear resize + dtype convert
# ---------------------------------------------------------------------------
from ..core.records import CONVERT_SCALE as _CONVERT_SCALE  # noqa: E402


@lru_cache(maxsize=64)
def _interp_matrix(n_in: int, n_out: int, scale: float = 1.0) -> np.ndarray:
    """(n_out, n_in) bilinear interpolation matrix, same sample positions as
    ``records.bilinear_lut`` (align-corners linspace); row i holds the two
    corner weights of output sample i, pre-multiplied by ``scale``."""
    pos = np.linspace(0, n_in - 1, n_out, dtype=np.float32)
    lo = np.floor(pos).astype(np.int64)
    hi = np.minimum(lo + 1, n_in - 1)
    frac = pos - lo.astype(np.float32)
    m = np.zeros((n_out, n_in), np.float32)
    rows = np.arange(n_out)
    np.add.at(m, (rows, lo), (1.0 - frac) * scale)
    np.add.at(m, (rows, hi), frac * scale)
    return m


def _make_resize_convert_kernel(scale: float):
    def kernel(x_ref, ry_ref, rx_ref, o_ref):
        x = x_ref[0].astype(jnp.float32)          # (H, W, C)
        ry = ry_ref[...]                          # (OH, H), scale folded in
        rx = rx_ref[...]                          # (OW, W)
        t = jnp.einsum("oh,hwc->owc", ry, x,
                       preferred_element_type=jnp.float32)
        o_ref[0] = jnp.einsum("pw,owc->opc", rx, t,
                              preferred_element_type=jnp.float32)
    kernel.__name__ = f"resize_convert_kernel_s{scale:g}"
    return kernel


def resize_convert_images(x: jax.Array, out_h: int, out_w: int,
                          *, interpret: bool = True) -> jax.Array:
    """Batched device-side resize+convert: (B,H,W,C) u8/u16/f32 ->
    (B,out_h,out_w,C) f32 in [0,1].

    One grid step per image; both interpolation matmuls run on the MXU with
    the dtype-conversion scale folded into the row matrix.  Requires a
    uniform-size batch (H, W shared) — the sharded-corpus writers emit one
    with ``hw_jitter=0``.
    """
    B, H, W, C = x.shape
    scale = float(_CONVERT_SCALE.get(np.dtype(x.dtype), 1.0))
    ry = jnp.asarray(_interp_matrix(H, out_h, scale))
    rx = jnp.asarray(_interp_matrix(W, out_w))
    return pl.pallas_call(
        _make_resize_convert_kernel(scale),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W, C), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((out_h, H), lambda b: (0, 0)),
            pl.BlockSpec((out_w, W), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, out_h, out_w, C), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, out_h, out_w, C), jnp.float32),
        interpret=interpret,
    )(x, ry, rx)


def resize_convert_batch_np(x: np.ndarray, out_h: int, out_w: int) -> np.ndarray:
    """Numpy fallback: batched LUT-gather resize with the conversion scale
    folded into the final pass (bit-compatible with the per-image host path)."""
    from ..core import records

    x = np.asarray(x)
    scale = _CONVERT_SCALE.get(x.dtype)
    if scale is None:
        return records.resize_batch(x.astype(np.float32), out_h, out_w)
    return records.resize_batch(x, out_h, out_w, scale=scale)


def resize_convert(x, out_h: int, out_w: int, *, backend: str = "auto",
                   interpret: bool = True):
    """Dispatch batched resize+convert: ``"pallas"`` (device kernel),
    ``"numpy"`` (host LUT gather), or ``"auto"`` (kernel only when a real
    accelerator backend is present)."""
    if backend == "auto":
        backend = "numpy" if jax.default_backend() == "cpu" else "pallas"
    if backend == "numpy":
        return resize_convert_batch_np(np.asarray(x), out_h, out_w)
    if backend == "pallas":
        return resize_convert_images(jnp.asarray(x), out_h, out_w,
                                     interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}; options: auto/numpy/pallas")
