"""repro.trace — fine-grained I/O tracing & telemetry (tf-Darshan analogue).

The source paper characterizes DL I/O with coarse 1 Hz dstat counters
(§IV-B, Figs. 8/10); its follow-up, *tf-Darshan* (arXiv:2008.04395), shows
that per-operation spans attributed to pipeline stages are what actually
explain ingestion and checkpoint behaviour.  This package is that
follow-up's instrumentation layer for this codebase — the telemetry spine
every subsystem reports through.

Subsystem map:

* :mod:`repro.trace.tracer` — the collector.  :class:`Tracer` keeps
  per-thread span/counter buffers (lock only on first touch per thread);
  module-level :func:`span` / :func:`instant` / :func:`count` are the
  hot-path hooks used by ``repro.core`` and cost one global check plus a
  shared no-op singleton when tracing is off.  Stage constants
  (``STAGE_STORAGE_READ``, ``STAGE_DECODE``, ``STAGE_PREFETCH``,
  ``STAGE_CKPT_WRITE``, ``STAGE_DRAIN``, ``STAGE_COMPUTE``, ...) form the
  attribution taxonomy.
* :mod:`repro.trace.report` — Darshan-style reduction: per-stage op
  counts, bytes, latency percentiles (:func:`aggregate`,
  :func:`percentile`), the compute/input-pipeline :func:`overlap_ratio`
  (paper Fig. 6 made measurable), and :func:`to_markdown`.
* :mod:`repro.trace.export` — Chrome ``trace_event`` JSON for
  Perfetto/chrome://tracing (:func:`to_chrome_trace`,
  :func:`dump_chrome_trace`) plus the inverse :func:`from_chrome_trace`
  for lossless round-trips.

Instrumented producers: ``core/storage.py`` (reads/writes, incl. simulated
device pacing), ``core/dataset.py`` (per-element map/decode),
``core/prefetcher.py`` (background fetches + buffer-depth counter),
``core/checkpoint.py`` (save/restore), ``core/burst_buffer.py`` (drains),
``train/trainer.py`` (per-step data-wait vs compute).  ``core.stats.
IOTracer`` is a thin adapter over :class:`Tracer` for the dstat-style
timeline view.

Typical use::

    from repro import trace

    tracer = trace.start()               # install global collector
    ...run pipeline / training...
    trace.dump_chrome_trace(tracer, "trace.json")   # open in Perfetto
    print(trace.to_markdown(tracer.spans(), counters=tracer.counters()))
    trace.stop()
"""
from .tracer import (
    INPUT_PIPELINE_STAGES,
    NULL_SPAN,
    STAGE_CACHE,
    STAGE_CKPT_RESTORE,
    STAGE_CKPT_SNAPSHOT,
    STAGE_CKPT_WRITE,
    STAGE_COMPUTE,
    STAGE_DATA_WAIT,
    STAGE_DECODE,
    STAGE_DRAIN,
    STAGE_PREFETCH,
    STAGE_STAGE,
    STAGE_STORAGE_READ,
    STAGE_STORAGE_WRITE,
    CounterRecord,
    Span,
    SpanRecord,
    Tracer,
    count,
    enabled,
    get_tracer,
    instant,
    set_tracer,
    span,
    start,
    stop,
)
from .report import (
    StageStats,
    aggregate,
    busy_intervals,
    overlap_ratio,
    percentile,
    to_markdown,
)
from .export import dump_chrome_trace, from_chrome_trace, to_chrome_trace

__all__ = [
    # collector
    "Tracer", "Span", "SpanRecord", "CounterRecord", "NULL_SPAN",
    "span", "instant", "count", "start", "stop", "enabled",
    "get_tracer", "set_tracer",
    # stages
    "STAGE_STORAGE_READ", "STAGE_STORAGE_WRITE", "STAGE_DECODE",
    "STAGE_PREFETCH", "STAGE_CKPT_SNAPSHOT", "STAGE_CKPT_WRITE",
    "STAGE_CKPT_RESTORE",
    "STAGE_DRAIN", "STAGE_STAGE", "STAGE_DATA_WAIT", "STAGE_COMPUTE",
    "STAGE_CACHE",
    "INPUT_PIPELINE_STAGES",
    # reports
    "StageStats", "aggregate", "percentile", "overlap_ratio",
    "busy_intervals", "to_markdown",
    # export
    "to_chrome_trace", "dump_chrome_trace", "from_chrome_trace",
]
