"""Block read-cache: single-flight dedup, budget invariants, spill tier,
invalidation, retry composition, readahead window, and the cache-on ==
cache-off pipeline equivalence property."""
import tempfile
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro import metrics
from repro.core import records
from repro.core.cache import BlockCache, CachingStorage, ReadaheadScheduler
from repro.core.dataset import sharded_image_pipeline
from repro.core.faults import FaultyStorage
from repro.core.readerpool import reader_pool
from repro.core.retry import RetryingStorage, RetryPolicy
from repro.core.storage import NativeStorage


def _read_ops(counted: FaultyStorage) -> int:
    with counted._lock:
        return sum(1 for (op, _p, _n) in counted.op_log
                   if op in ("read_file", "read_range"))


class _SlowStorage(NativeStorage):
    """Each range read takes ~10 ms — long enough that racing readers pile
    up on the in-flight future instead of finding the block already cached."""

    def read_range(self, path, offset, length):
        time.sleep(0.01)
        return super().read_range(path, offset, length)


class TestSingleFlight:
    def test_16_racing_readers_one_storage_read_per_block(self):
        blob = bytes(range(256)) * 1024          # 256 KiB = 4 x 64 KiB blocks
        tmp = tempfile.TemporaryDirectory()
        slow = _SlowStorage(tmp.name)
        slow.write_file("f", blob)
        counted = FaultyStorage(slow)
        with BlockCache(1 << 22, block_size=64 * 1024) as cache:
            cst = CachingStorage(counted, cache)
            barrier = threading.Barrier(16)

            def racer(_):
                barrier.wait(5)
                return cst.read_file("f")

            with ThreadPoolExecutor(16) as pool:
                outs = list(pool.map(racer, range(16)))
            assert all(o == blob for o in outs)
            # the device saw each block exactly once, no duplicate reads
            assert _read_ops(counted) == 4
            s = cache.stats()
            assert s["single_flight_waits"] > 0
            assert s["misses"] >= 4 and s["miss_bytes"] == len(blob)

    def test_loader_error_propagates_and_flight_is_dropped(self, tmp_storage):
        tmp_storage.write_file("f", b"x" * 100)
        counted = FaultyStorage(tmp_storage).transient(n_ops=1, ops=("read",))
        with BlockCache(1 << 20) as cache:
            cst = CachingStorage(counted, cache)
            with pytest.raises(OSError):
                cst.read_file("f")
            # failed flight removed: a fresh call re-drives the loader
            assert cst.read_file("f") == b"x" * 100

    def test_retry_above_cache_absorbs_transient(self, tmp_storage):
        tmp_storage.write_file("f", b"y" * 100)
        faulty = FaultyStorage(tmp_storage).transient(n_ops=1, ops=("read",))
        with BlockCache(1 << 20) as cache:
            rs = RetryingStorage(
                CachingStorage(faulty, cache),
                RetryPolicy(max_attempts=3, base_delay_s=1e-4,
                            max_delay_s=1e-3))
            assert rs.read_file("f") == b"y" * 100
            assert rs.retries == 1


class TestBudgetInvariants:
    def test_occupancy_never_exceeds_capacity(self, tmp_storage):
        rng = np.random.default_rng(0)
        for i in range(8):
            tmp_storage.write_file(f"f{i}", bytes(rng.integers(
                0, 256, size=3000, dtype=np.uint8)))
        with BlockCache(4096, block_size=1024) as cache:
            cst = CachingStorage(tmp_storage, cache)
            for i in rng.permutation(np.repeat(np.arange(8), 4)):
                cst.read_file(f"f{i}")
                assert cache.occupancy_bytes <= cache.capacity
            assert cache.stats()["evictions"] > 0

    def test_lru_keeps_recent_blocks(self, tmp_storage):
        for i in range(3):
            tmp_storage.write_file(f"f{i}", bytes([i]) * 1024)
        counted = FaultyStorage(tmp_storage)
        with BlockCache(2048, block_size=1024) as cache:   # room for 2
            cst = CachingStorage(counted, cache)
            cst.read_file("f0")
            cst.read_file("f1")
            cst.read_file("f0")        # f0 now MRU
            cst.read_file("f2")        # evicts f1 (LRU), not f0
            n = _read_ops(counted)
            cst.read_file("f0")        # hit
            assert _read_ops(counted) == n
            cst.read_file("f1")        # miss (was evicted)
            assert _read_ops(counted) == n + 1

    def test_oversized_block_served_but_not_cached(self, tmp_storage):
        tmp_storage.write_file("big", b"z" * 4096)
        with BlockCache(1024, block_size=8192) as cache:
            cst = CachingStorage(tmp_storage, cache)
            assert cst.read_file("big") == b"z" * 4096
            assert cache.occupancy_bytes == 0


class TestZeroCopy:
    def test_single_block_file_returns_cached_object(self, tmp_storage):
        tmp_storage.write_file("f", b"q" * 500)
        with BlockCache(1 << 20) as cache:
            cst = CachingStorage(tmp_storage, cache)
            a = cst.read_file("f")
            b = cst.read_file("f")
            assert a is b                      # the cached bytes, no copy

    def test_intra_block_range_is_memoryview(self, tmp_storage):
        tmp_storage.write_file("f", bytes(range(200)))
        with BlockCache(1 << 20) as cache:
            cst = CachingStorage(tmp_storage, cache)
            mv = cst.read_range("f", 10, 20)
            assert isinstance(mv, memoryview)
            assert bytes(mv) == bytes(range(10, 30))

    def test_multi_block_range_assembles(self, tmp_storage):
        blob = bytes(np.random.default_rng(1).integers(
            0, 256, size=5000, dtype=np.uint8))
        tmp_storage.write_file("f", blob)
        with BlockCache(1 << 20, block_size=1024) as cache:
            cst = CachingStorage(tmp_storage, cache)
            assert bytes(cst.read_range("f", 500, 3000)) == blob[500:3500]
            assert bytes(cst.read_range("f", 0, 99999)) == blob
            assert cst.read_range("f", 6000, 10) == b""


class TestInvalidation:
    def test_write_through_invalidates(self, tmp_storage):
        with BlockCache(1 << 20) as cache:
            cst = CachingStorage(tmp_storage, cache)
            cst.write_file("f", b"old")
            assert cst.read_file("f") == b"old"
            cst.write_file("f", b"newer")
            assert cst.read_file("f") == b"newer"
            assert cst.size("f") == 5

    def test_rename_and_remove_invalidate(self, tmp_storage):
        with BlockCache(1 << 20) as cache:
            cst = CachingStorage(tmp_storage, cache)
            cst.write_file("a", b"aaa")
            cst.read_file("a")
            cst.rename("a", "b")
            assert cst.read_file("b") == b"aaa"
            assert not cst.exists("a")
            cst.remove("b")
            with pytest.raises(FileNotFoundError):
                cst.read_file("b")

    def test_inflight_load_never_publishes_stale(self, tmp_storage):
        with BlockCache(1 << 20) as cache:
            started, release = threading.Event(), threading.Event()

            def slow_stale_loader():
                started.set()
                release.wait(5)
                return b"stale"

            fut = ThreadPoolExecutor(1).submit(
                cache.get_block, "p", 0, slow_stale_loader)
            assert started.wait(5)
            cache.invalidate("p")       # the write landed mid-load
            release.set()
            assert fut.result(5) == b"stale"   # the old reader gets old data
            # ...but the cache refused to publish it under the new generation
            assert cache.get_block("p", 0, lambda: b"fresh") == b"fresh"


class TestSpillTier:
    def test_evictions_spill_and_serve_from_fast_tier(self, tmp_storage):
        rng = np.random.default_rng(2)
        blobs = {f"f{i}": bytes(rng.integers(0, 256, size=1000,
                                             dtype=np.uint8))
                 for i in range(6)}
        for p, b in blobs.items():
            tmp_storage.write_file(p, b)
        with tempfile.TemporaryDirectory() as d:
            fast = NativeStorage(d)
            counted = FaultyStorage(tmp_storage)
            with BlockCache(2048, block_size=1024, spill_storage=fast,
                            spill_capacity_bytes=1 << 20) as cache:
                cst = CachingStorage(counted, cache)
                for p in blobs:                 # fills DRAM, spills the rest
                    cst.read_file(p)
                assert cache.stats()["spills"] > 0
                assert fast.exists("cache/spill.arena")
                n = _read_ops(counted)
                for p, b in blobs.items():      # every re-read: DRAM or spill
                    assert cst.read_file(p) == b
                assert _read_ops(counted) == n  # slow tier untouched
                assert cache.stats()["spill_hits"] > 0

    def test_spill_capacity_bounds_arena(self, tmp_storage):
        for i in range(8):
            tmp_storage.write_file(f"f{i}", bytes([i]) * 1024)
        with tempfile.TemporaryDirectory() as d:
            fast = NativeStorage(d)
            with BlockCache(1024, block_size=1024, spill_storage=fast,
                            spill_capacity_bytes=3 * 1024) as cache:
                cst = CachingStorage(tmp_storage, cache)
                for i in range(8):
                    cst.read_file(f"f{i}")
                assert cache.spill_occupancy_bytes <= 3 * 1024
                assert fast.size("cache/spill.arena") <= 3 * 1024

    def test_close_removes_arena(self, tmp_storage):
        tmp_storage.write_file("f0", b"a" * 1024)
        tmp_storage.write_file("f1", b"b" * 1024)
        with tempfile.TemporaryDirectory() as d:
            fast = NativeStorage(d)
            cache = BlockCache(1024, block_size=1024, spill_storage=fast)
            cst = CachingStorage(tmp_storage, cache)
            cst.read_file("f0")
            cst.read_file("f1")    # evicts+spills f0
            assert fast.exists("cache/spill.arena")
            cache.close()
            assert not fast.exists("cache/spill.arena")


class TestObservability:
    def test_gauges_registered_and_unregistered_on_close(self, tmp_storage):
        tmp_storage.write_file("f", b"m" * 100)
        reg = metrics.start()
        try:
            cache = BlockCache(1 << 20, name="t-obs")
            cst = CachingStorage(tmp_storage, cache)
            cst.read_file("f")
            cst.read_file("f")
            snap = reg.collect()
            assert snap["gauges"]['cache.occupancy_bytes{cache="t-obs"}'] == 100
            assert snap["gauges"]['cache.hit_ratio{cache="t-obs"}'] == 0.5
            assert snap["counters"]['cache.hits{cache="t-obs"}'] == 1
            assert snap["counters"]['cache.misses{cache="t-obs"}'] == 1
            cache.close()
            snap = reg.collect()
            assert not any(k.startswith("cache.") for k in snap["gauges"])
        finally:
            metrics.stop()

    def test_attribute_counters_work_with_metrics_off(self, tmp_storage):
        tmp_storage.write_file("f", b"m" * 100)
        with BlockCache(1 << 20) as cache:
            cst = CachingStorage(tmp_storage, cache)
            cst.read_file("f")
            cst.read_file("f")
            assert cache.hits == 1 and cache.misses == 1
            assert cache.hit_ratio() == 0.5

    def test_closed_cache_rejects_lookups(self):
        cache = BlockCache(1 << 20)
        cache.close()
        cache.close()                   # idempotent
        with pytest.raises(RuntimeError):
            cache.get_block("p", 0, lambda: b"")


class _GateStorage(NativeStorage):
    """read_range blocks on a gate; tracks the concurrency high-water mark."""

    def __init__(self, root):
        super().__init__(root)
        self.gate = threading.Event()
        self._clock = threading.Lock()
        self.concurrent = 0
        self.max_concurrent = 0

    def read_range(self, path, offset, length):
        with self._clock:
            self.concurrent += 1
            self.max_concurrent = max(self.max_concurrent, self.concurrent)
        try:
            self.gate.wait(5)
            return super().read_range(path, offset, length)
        finally:
            with self._clock:
                self.concurrent -= 1


class TestReadahead:
    def test_window_caps_inflight_fetches(self):
        with tempfile.TemporaryDirectory() as d:
            gated = _GateStorage(d)
            gated.write_file("s0", b"r" * 8192)     # 8 blocks of 1 KiB
            with BlockCache(1 << 20, block_size=1024) as cache:
                cst = CachingStorage(gated, cache)
                ra = ReadaheadScheduler(cst, window=2,
                                        pool=reader_pool(4))
                ra.schedule("s0")
                deadline = time.monotonic() + 5
                while gated.concurrent < 2 and time.monotonic() < deadline:
                    time.sleep(0.01)
                assert ra.scheduled == 8
                assert gated.max_concurrent <= 2    # the window cap held
                gated.gate.set()
                assert ra.drain(timeout=5)
                assert ra.loaded == 8
                assert gated.max_concurrent <= 2
                ra.close()

    def test_prefetched_blocks_serve_without_new_reads(self, tmp_storage):
        blob = bytes(range(256)) * 16
        tmp_storage.write_file("s0", blob)
        counted = FaultyStorage(tmp_storage)
        with BlockCache(1 << 20, block_size=1024) as cache:
            cst = CachingStorage(counted, cache)
            ra = ReadaheadScheduler(cst, window=4)
            ra.schedule("s0")
            assert ra.drain(timeout=5)
            n = _read_ops(counted)
            assert cst.read_file("s0") == blob
            assert _read_ops(counted) == n
            ra.close()

    def test_requires_caching_storage(self, tmp_storage):
        with pytest.raises(TypeError):
            ReadaheadScheduler(tmp_storage)

    def test_errors_swallowed_and_counted(self, tmp_storage):
        tmp_storage.write_file("s0", b"e" * 2048)
        flaky = FaultyStorage(tmp_storage).transient(n_ops=1, ops=("read",))
        with BlockCache(1 << 20, block_size=1024) as cache:
            cst = CachingStorage(flaky, cache)
            ra = ReadaheadScheduler(cst, window=1)
            ra.schedule("s0")
            assert ra.drain(timeout=5)
            assert ra.errors >= 1
            # foreground read still works (fault was transient)
            assert cst.read_file("s0") == b"e" * 2048
            ra.close()


class TestPipelineEquivalence:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 16),
           cap_blocks=st.integers(1, 64),
           readahead=st.booleans())
    def test_cache_on_matches_cache_off_bit_identical(
            self, seed, cap_blocks, readahead):
        """Same corpus, same seed: the cached pipeline must yield exactly
        the batches of the uncached one, in the same order, for any budget
        (heavy eviction included) with readahead racing the consumers."""
        with tempfile.TemporaryDirectory() as d:
            st_ = NativeStorage(d)
            paths, labels = records.write_sharded_image_dataset(
                st_, n_images=24, images_per_shard=6, mean_hw=(24, 24),
                seed=0)

            def batches(storage, **kw):
                ds = sharded_image_pipeline(
                    storage, paths, labels, batch_size=6, cycle_length=2,
                    block_length=3, num_parallel_calls=2, prefetch=0,
                    out_hw=(8, 8), seed=seed, **kw)
                return [(i.copy(), l.copy()) for i, l in ds]

            expected = batches(st_)
            with BlockCache(cap_blocks * 4096, block_size=4096) as cache:
                got = batches(st_, cache=cache,
                              readahead=2 if readahead else None)
                got_warm = batches(st_, cache=cache)   # epoch 2: warm
            assert len(expected) == len(got) == len(got_warm)
            for (ei, el), (gi, gl), (wi, wl) in zip(expected, got, got_warm):
                np.testing.assert_array_equal(ei, gi)
                np.testing.assert_array_equal(el, gl)
                np.testing.assert_array_equal(ei, wi)
                np.testing.assert_array_equal(el, wl)
