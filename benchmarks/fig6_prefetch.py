"""Fig. 6 analogue: AlexNet mini-app runtime, prefetch on/off x threads x tier.

The paper's central claim: with prefetch(1), runtime becomes independent of
threads/tier (input pipeline fully hidden behind per-batch compute)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.alexnet_mini import AlexNetConfig

# heavier FC stack: per-batch compute ~0.3 s, comfortably above per-batch
# I/O on the fast tiers but comparable to single-thread HDD (paper regime)
ACFG = AlexNetConfig(name="alexnet-fig6", in_hw=128,
                     filters=(64, 128, 192, 128, 128), fc=(1024, 1024))
from repro.core.dataset import image_pipeline
from repro.models import alexnet as A

from .common import BenchEnv, emit


def make_train_step():
    @jax.jit
    def step(params, imgs, labels):
        loss, g = jax.value_and_grad(
            lambda p: A.loss_fn(p, imgs, labels, ACFG))(params)
        new_p = jax.tree.map(lambda p, gg: p - 1e-4 * gg, params, g)
        return new_p, loss

    return step


def run_epoch(st, paths, labels, *, threads, prefetch, step, params,
              batch=16, n_batches=6):
    ds = image_pipeline(
        st, paths, labels, batch_size=batch, num_parallel_calls=threads,
        prefetch=prefetch, out_hw=(ACFG.in_hw, ACFG.in_hw), seed=0,
        repeat=True)
    it = iter(ds)
    # warmup compile outside the timed region
    imgs, lbls = next(it)
    params, _ = step(params, jnp.asarray(imgs), jnp.asarray(lbls))
    t0 = time.monotonic()
    for _ in range(n_batches):
        imgs, lbls = next(it)
        params, loss = step(params, jnp.asarray(imgs), jnp.asarray(lbls))
        loss.block_until_ready()
    return time.monotonic() - t0


def run() -> None:
    # Caltech-101-like corpus: median ~12 KB images, unscaled tier model
    env = BenchEnv(tiers=("hdd", "ssd", "optane"), n_images=160,
                   mean_hw=(64, 64), time_scale=1.0)
    step = make_train_step()
    params = A.init_params(jax.random.PRNGKey(0), ACFG)
    rows = []
    times = {}
    for tier in ("hdd", "ssd", "optane"):
        st = env.storages[tier]
        paths, labels = env.corpora[tier]
        for threads in (1, 4):
            for pf in (0, 1):
                t = run_epoch(st, paths, labels, threads=threads,
                              prefetch=pf, step=step, params=params)
                times[(tier, threads, pf)] = t
                rows.append(f"{tier},threads={threads},prefetch={pf},"
                            f"runtime_s={t:.2f}")
    # prefetch-hides-io check: spread of prefetch=1 runtimes across configs
    pf1 = [v for k, v in times.items() if k[2] == 1]
    spread = (max(pf1) - min(pf1)) / max(min(pf1), 1e-9)
    excess = times[("hdd", 1, 0)] / times[("hdd", 1, 1)]
    emit("fig6_prefetch", rows,
         f"prefetch=1 runtime spread across tiers/threads={spread:.2%} "
         f"(paper: ~0 — I/O fully hidden); hdd 1-thread no-prefetch excess="
         f"{excess:.2f}x")
    env.close()


if __name__ == "__main__":
    run()
