"""Sharding rules: divisibility invariant (property test) + resolution."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.sharding.rules import DEFAULT_RULES, ShardingCtx


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by ShardingCtx."""
    def __init__(self, shape):
        self.shape = shape


def ctx(shape={"pod": 2, "data": 16, "model": 16}):
    return ShardingCtx(mesh=FakeMesh(shape))


class TestSpecResolution:
    def test_no_mesh_is_noop(self):
        c = ShardingCtx(mesh=None)
        assert c.sharding(("batch", "d_ff"), (4, 4)) is None
        assert c.constrain("passthrough", "batch") == "passthrough"

    def test_basic_mapping(self):
        spec = ctx().spec(("batch", None, "d_ff"), (64, 7, 160))
        assert spec[0] == ("pod", "data")
        assert spec[2] == "model"

    def test_divisibility_fallback(self):
        # 28 heads on a 16-way model axis -> replicated
        spec = ctx().spec(("batch", "seq", "heads", "head_dim"),
                          (64, 128, 28, 128))
        assert len(spec) < 3 or spec[2] is None

    def test_missing_axis_dropped(self):
        # single-pod mesh has no 'pod' axis
        c = ctx({"data": 16, "model": 16})
        spec = c.spec(("batch",), (32,))
        assert spec[0] == "data"

    def test_axis_used_once(self):
        # both dims want 'model': the second one must be dropped
        c = ctx().with_rules(seq="model")
        spec = c.spec(("seq", "d_ff"), (32, 32))
        assert spec[0] == "model"
        assert len(spec) < 2 or spec[1] is None

    def test_with_rules_override(self):
        c = ctx().with_rules(res_seq="model")
        spec = c.spec(("batch", "res_seq", None), (64, 64, 8))
        assert spec[1] == "model"

    @given(
        dim=st.integers(1, 4096),
        logical=st.sampled_from(list(DEFAULT_RULES)),
    )
    @settings(max_examples=200, deadline=None)
    def test_property_every_assignment_divides(self, dim, logical):
        c = ctx()
        spec = c.spec((logical,), (dim,))
        if len(spec) and spec[0] is not None:
            axes = (spec[0],) if isinstance(spec[0], str) else spec[0]
            size = 1
            for a in axes:
                size *= c.mesh.shape[a]
            assert dim % size == 0

    def test_n_data_and_n_model(self):
        c = ctx()
        assert c.n_data == 32 and c.n_model == 16
        c1 = ctx({"data": 16, "model": 16})
        assert c1.n_data == 16


class TestArchRules:
    """Every assigned arch must produce fully valid specs for its params."""

    @pytest.mark.parametrize("arch", [
        "qwen3-4b", "qwen2-vl-7b", "phi3-medium-14b", "gemma3-4b",
        "mixtral-8x22b", "granite-moe-3b-a800m", "deepseek-coder-33b",
        "mamba2-2.7b", "jamba-1.5-large-398b", "seamless-m4t-medium",
    ])
    def test_param_specs_divide(self, arch):
        import jax
        from repro.configs import ARCHS
        from repro.models.registry import model_fns

        cfg = ARCHS[arch]
        fns = model_fns(cfg)
        shapes = jax.eval_shape(
            lambda: fns.init_params(jax.random.PRNGKey(0), cfg))
        logical = fns.param_logical(cfg)
        c = ctx()

        def is_logical(x):
            return isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)

        def check(log, shp):
            spec = c.spec(log, shp.shape)
            for dim, axes in zip(shp.shape, tuple(spec) + (None,) * 10):
                if axes is None:
                    continue
                flat = (axes,) if isinstance(axes, str) else axes
                size = 1
                for a in flat:
                    size *= c.mesh.shape[a]
                assert dim % size == 0, (arch, log, shp.shape, spec)
            return None

        jax.tree.map(check, logical, shapes, is_leaf=is_logical)
