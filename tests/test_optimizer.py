"""Adam(W) with fp32/bf16/int8 states."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import OptConfig, adam_update, init_opt_state


def quad_problem():
    params = {"w": jnp.array([5.0, -3.0, 2.0]), "b": jnp.array([[1.0, -1.0]])}
    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    return params, loss


class TestAdam:
    @pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
    def test_converges_on_quadratic(self, state_dtype):
        cfg = OptConfig(lr=0.1, state_dtype=state_dtype, grad_clip=0.0)
        params, loss = quad_problem()
        state = init_opt_state(params, cfg)
        l0 = float(loss(params))
        for step in range(60):
            g = jax.grad(loss)(params)
            params, state = adam_update(g, state, params, jnp.int32(step), cfg)
        assert float(loss(params)) < l0 * 0.01

    def test_matches_reference_adam_fp32(self):
        """First-steps agreement with a hand-rolled Adam."""
        cfg = OptConfig(lr=0.01, grad_clip=0.0)
        params, loss = quad_problem()
        state = init_opt_state(params, cfg)
        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)
        p_ref = params
        for t in range(3):
            g = jax.grad(loss)(params)
            params, state = adam_update(g, state, params, jnp.int32(t), cfg)
            g_ref = jax.grad(loss)(p_ref)
            m = jax.tree.map(lambda mm, gg: cfg.b1 * mm + (1 - cfg.b1) * gg, m, g_ref)
            v = jax.tree.map(lambda vv, gg: cfg.b2 * vv + (1 - cfg.b2) * gg * gg, v, g_ref)
            bc1, bc2 = 1 - cfg.b1 ** (t + 1), 1 - cfg.b2 ** (t + 1)
            p_ref = jax.tree.map(
                lambda pp, mm, vv: pp - cfg.lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps),
                p_ref, m, v)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    def test_grad_clip_caps_update(self):
        cfg = OptConfig(lr=1.0, grad_clip=1e-3)
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(params, cfg)
        g = {"w": jnp.full(4, 1e6)}
        new_p, _ = adam_update(g, state, params, jnp.int32(0), cfg)
        assert np.isfinite(np.asarray(new_p["w"])).all()

    def test_int8_state_memory_is_smaller(self):
        params = {"w": jnp.zeros((1024, 256))}
        s32 = init_opt_state(params, OptConfig(state_dtype="float32"))
        s8 = init_opt_state(params, OptConfig(state_dtype="int8"))
        b32 = sum(x.nbytes for x in jax.tree.leaves(s32))
        b8 = sum(x.nbytes for x in jax.tree.leaves(s8))
        assert b8 < b32 * 0.3

    def test_weight_decay_applied(self):
        cfg = OptConfig(lr=0.1, weight_decay=0.1, grad_clip=0.0)
        params = {"w": jnp.ones(3)}
        state = init_opt_state(params, cfg)
        g = {"w": jnp.zeros(3)}
        new_p, _ = adam_update(g, state, params, jnp.int32(0), cfg)
        assert (np.asarray(new_p["w"]) < 1.0).all()
