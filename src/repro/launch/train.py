"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 100 --ckpt-every 20 --data-tier ssd --ckpt fast:optane,slow:hdd

At smoke scale this actually trains on CPU (the ~100M-class configuration
the assignment asks for is ``--arch granite-moe-3b-a800m --smoke`` or any
smoke config scaled via --d-model/--layers).  At full scale the same step
function is what repro.launch.dryrun lowers onto the pod meshes.
"""
import argparse
import os
import sys
import tempfile

import jax
import jax.numpy as jnp

from ..configs import ARCHS
from ..core import BurstBufferCheckpointer, Dataset, DirectCheckpointer, make_storage
from ..core import records
from ..train import steps as S
from ..train.optimizer import OptConfig
from ..train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data-tier", default="ssd")
    ap.add_argument("--ckpt-fast", default="optane")
    ap.add_argument("--ckpt-slow", default="hdd")
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--opt-state", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.smoke()
    opt = OptConfig(lr=args.lr, state_dtype=args.opt_state)
    root = args.workdir or tempfile.mkdtemp(prefix="repro_train_")
    print(f"workdir: {root}")

    data_st = make_storage(args.data_tier, os.path.join(root, "data"),
                           time_scale=0.05)
    shards = records.write_token_dataset(
        data_st, n_shards=8, docs_per_shard=args.batch * 4,
        seq_len=args.seq + 1, vocab_size=cfg.vocab_size)

    def load(path):
        return records.decode_token_shard(data_st.read_file(path), args.seq + 1)

    ds = (Dataset.from_tensor_slices(shards).repeat().shuffle(8, seed=0)
          .map(load, num_parallel_calls=args.threads).prefetch(2))

    def batches():
        for shard in ds:
            for i in range(0, len(shard) - args.batch + 1, args.batch):
                batch = {"tokens": jnp.asarray(shard[i:i + args.batch])}
                if cfg.family == "encdec":
                    batch["frames"] = jnp.zeros(
                        (args.batch, 8, cfg.d_model), jnp.bfloat16)
                yield batch

    fast = make_storage(args.ckpt_fast, os.path.join(root, "bb"), time_scale=0.05)
    slow = make_storage(args.ckpt_slow, os.path.join(root, "archive"),
                        time_scale=0.05)
    ckpt = BurstBufferCheckpointer(fast, slow, f"ckpt/{cfg.name}")

    state = S.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(S.make_train_step(cfg, opt, None, remat=False,
                                     q_chunk=16, kv_chunk=16))
    tr = Trainer(step, state, batches(), checkpointer=ckpt,
                 ckpt_every=args.ckpt_every, install_sigterm=True,
                 on_step=lambda s, m: print(f"step {s}: loss={m['loss']:.4f}")
                 if s % 10 == 0 else None)
    tr.run(args.steps)
    ckpt.wait()
    rep = tr.report()
    print(f"done at step {tr.step}; data-wait {rep['data_wait_frac']:.1%}; "
          f"ckpt blocked {sum(rep['blocked_ckpt_s']):.2f}s")
    ckpt.close()


if __name__ == "__main__":
    main()
