"""Fig. 4 analogue: micro-benchmark ingestion bandwidth vs reader threads
(full pipeline: read + decode + resize + batch), per storage tier."""
from __future__ import annotations

from repro.core.microbench import thread_scaling_sweep

from .common import BenchEnv, emit


def run(tiers=("hdd", "ssd", "optane", "lustre"), preprocess=True,
        name="fig4_threads", pipeline="legacy") -> dict:
    # paper: ImageNet subset, median image 112 KB (~190x190x3 raw).
    # ``pipeline="vectorized"`` sweeps the fused map_and_batch read engine
    # instead of the seed per-element chain (thread-scaling shape should
    # match; absolute samples/s is higher — fig11 quantifies the gap).
    env = BenchEnv(tiers=tiers, n_images=128, mean_hw=(190, 190),
                   time_scale=1.0)
    rows, speedups = [], {}
    for tier in tiers:
        st = env.storages[tier]
        paths, _ = env.corpora[tier]
        st.drop_caches()
        results = thread_scaling_sweep(
            st, paths, thread_counts=(1, 2, 4, 8), repeats=3,
            batch_size=32, preprocess=preprocess, out_hw=(32, 32),
            pipeline=pipeline)
        base = results[0].images_per_s
        sp = {r.threads: r.images_per_s / base for r in results}
        speedups[tier] = sp
        for r in results:
            rows.append(
                f"{tier},threads={r.threads},img_s={r.images_per_s:.1f},"
                f"mb_s={r.mb_per_s:.2f},speedup={r.images_per_s / base:.2f}")
    derived = (
        f"hdd 2/4/8-thread speedup={speedups.get('hdd', {}).get(2, 0):.2f}/"
        f"{speedups.get('hdd', {}).get(4, 0):.2f}/"
        f"{speedups.get('hdd', {}).get(8, 0):.2f} "
        f"(paper 1.65/1.95/2.3); lustre 8-thread="
        f"{speedups.get('lustre', {}).get(8, 0):.2f} (paper 7.8)")
    emit(name, rows, derived)
    env.close()
    return speedups


if __name__ == "__main__":
    run()
