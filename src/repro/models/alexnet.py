"""AlexNet — the paper's mini-application network (§III-B, ~200 lines in TF).

5 conv (ReLU) + 3 maxpool + 3 FC, softmax-xent loss, Adam — exactly the
paper's workload shape: per-batch compute long enough that the prefetcher
can hide the input pipeline behind it.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def init_params(rng, cfg) -> Dict[str, Any]:
    keys = iter(jax.random.split(rng, 16))
    f = cfg.filters
    c_in = cfg.channels
    params: Dict[str, Any] = {}
    kernel_hw = [11, 5, 3, 3, 3]
    for i, (kout, khw) in enumerate(zip(f, kernel_hw)):
        shape = (khw, khw, c_in, kout)
        fan_in = khw * khw * c_in
        params[f"conv{i}"] = dict(
            w=(jax.random.normal(next(keys), shape, jnp.float32)
               * math.sqrt(2.0 / fan_in)),
            b=jnp.zeros((kout,), jnp.float32),
        )
        c_in = kout
    # flatten size: in_hw /4 (conv0 stride) then three /2 maxpools
    hw = cfg.in_hw // 4
    for _ in range(3):
        hw = hw // 2
    flat = hw * hw * f[-1]
    dims = [flat, *cfg.fc, cfg.n_classes]
    for i in range(3):
        params[f"fc{i}"] = dict(
            w=(jax.random.normal(next(keys), (dims[i], dims[i + 1]), jnp.float32)
               * math.sqrt(2.0 / dims[i])),
            b=jnp.zeros((dims[i + 1],), jnp.float32),
        )
    return params


def forward(params: Dict[str, Any], images: Array, cfg) -> Array:
    """images: (B, H, W, C) float32 -> logits (B, n_classes)."""
    x = images
    strides = [4, 1, 1, 1, 1]
    pool_after = {0, 1, 4}
    for i in range(5):
        p = params[f"conv{i}"]
        x = lax.conv_general_dilated(
            x, p["w"], window_strides=(strides[i], strides[i]),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + p["b"]
        x = jax.nn.relu(x)
        if i in pool_after:
            x = lax.reduce_window(
                x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
    x = x.reshape(x.shape[0], -1)
    for i in range(3):
        p = params[f"fc{i}"]
        x = x @ p["w"] + p["b"]
        if i < 2:
            x = jax.nn.relu(x)
    return x


def loss_fn(params, images: Array, labels: Array, cfg) -> Array:
    logits = forward(params, images, cfg)
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, cfg.n_classes)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))
