"""repro.core — the paper's contribution: DL I/O as a first-class subsystem.

* :mod:`repro.core.dataset` — tf.data-like input pipeline (shuffle / parallel
  map / batch / prefetch / cache / ignore_errors).
* :mod:`repro.core.prefetcher` — background-thread prefetcher + device
  double-buffering.
* :mod:`repro.core.records` — record container + image payloads + decode.
* :mod:`repro.core.storage` — storage tiers (native + Table-I-calibrated
  simulator: hdd / ssd / optane / lustre).
* :mod:`repro.core.checkpoint` — sharded TF-Saver-like checkpointing.
* :mod:`repro.core.burst_buffer` — fast-tier staging + async drain (the 2.6x).
* :mod:`repro.core.microbench` — STREAM-like ingestion benchmark.
* :mod:`repro.core.stats` — dstat-like I/O timeline view, an adapter over
  the :mod:`repro.trace` collector.

Telemetry: every I/O layer here (storage reads/writes, per-element
map/decode, prefetch fetches, checkpoint save/restore, burst-buffer
drains) emits stage-attributed spans through :mod:`repro.trace` — the
tf-Darshan-style subsystem.  Tracing is off by default; call
``repro.trace.start()`` to collect, then export with
``repro.trace.dump_chrome_trace`` (Perfetto) or summarize with
``repro.trace.to_markdown``.
"""
from .dataset import Dataset, image_pipeline
from .prefetcher import PrefetchIterator, prefetch_to_device
from .storage import Storage, NativeStorage, SimulatedStorage, TIERS, make_storage
from .checkpoint import CheckpointSaver
from .burst_buffer import BurstBufferCheckpointer, DirectCheckpointer
from .stats import IOTracer, StepTimer

__all__ = [
    "Dataset", "image_pipeline", "PrefetchIterator", "prefetch_to_device",
    "Storage", "NativeStorage", "SimulatedStorage", "TIERS", "make_storage",
    "CheckpointSaver", "BurstBufferCheckpointer", "DirectCheckpointer",
    "IOTracer", "StepTimer",
]
