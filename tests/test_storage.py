"""Storage tier simulator: bandwidth pacing + thread scaling shape."""
import os
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.stats import IOTracer
from repro.core.storage import (
    NativeStorage, SimulatedStorage, Storage, TIERS, TierSpec, make_storage,
)


class TestNative:
    def test_roundtrip_and_meta(self, tmp_storage):
        tmp_storage.write_file("a/b.bin", b"xyz", sync=True)
        assert tmp_storage.read_file("a/b.bin") == b"xyz"
        assert tmp_storage.exists("a/b.bin")
        assert tmp_storage.size("a/b.bin") == 3
        tmp_storage.rename("a/b.bin", "a/c.bin")
        assert not tmp_storage.exists("a/b.bin")
        tmp_storage.remove("a")
        assert not tmp_storage.exists("a")

    def test_tracer_counts(self):
        tracer = IOTracer()
        with tempfile.TemporaryDirectory() as d:
            st = NativeStorage(d, tracer)
            st.write_file("f", b"x" * 1000)
            st.read_file("f")
        t = tracer.totals()
        assert t["write_bytes"] == 1000 and t["read_bytes"] == 1000
        assert t["write_ops"] == 1 and t["read_ops"] == 1


class TestSimulated:
    def test_write_bandwidth_paced(self):
        spec = TierSpec("slow", 10e6, 10e6, 10e6, 10e6, 0, 0)
        with tempfile.TemporaryDirectory() as d:
            st = SimulatedStorage(d, spec)
            t0 = time.monotonic()
            st.write_file("f", b"x" * 2_000_000)  # 2MB at 10MB/s >= 0.2s
            el = time.monotonic() - t0
        assert el >= 0.18, f"not paced: {el}"

    def test_read_faster_tier_is_faster(self):
        # RAM-backed scratch where available (same idiom as benchmarks/
        # common.py): the modelled device pacing must dominate, not the
        # machine's real disk — on a loaded box a 3 MB /tmp read can cost
        # more than the whole modelled optane op
        scratch = "/dev/shm" if os.path.isdir("/dev/shm") else None
        with tempfile.TemporaryDirectory(dir=scratch) as d1, \
                tempfile.TemporaryDirectory(dir=scratch) as d2:
            # time_scale=1: modelled hdd ~48ms vs optane ~3ms — both far
            # above the ~1ms sleep/IO noise floor, so the 2x margin is robust
            hdd = make_storage("hdd", d1, time_scale=1.0)
            opt = make_storage("optane", d2, time_scale=1.0)
            data = b"x" * 3_000_000
            hdd.write_file("f", data)
            opt.write_file("f", data)
            t0 = time.monotonic(); hdd.read_file("f"); t_hdd = time.monotonic() - t0
            t0 = time.monotonic(); opt.read_file("f"); t_opt = time.monotonic() - t0
        assert t_hdd > t_opt * 2

    def test_thread_scaling_saturates_at_aggregate(self):
        """Many concurrent readers can't exceed the aggregate cap."""
        spec = TierSpec("cap", read_bw=20e6, write_bw=20e6,
                        stream_read_bw=10e6, stream_write_bw=10e6,
                        seek_latency=0, seek_contention=0)
        with tempfile.TemporaryDirectory() as d:
            st = SimulatedStorage(d, spec)
            for i in range(8):
                st.write_file(f"f{i}", b"x" * 500_000)
            t0 = time.monotonic()
            with ThreadPoolExecutor(8) as pool:
                list(pool.map(lambda i: st.read_file(f"f{i}"), range(8)))
            el = time.monotonic() - t0
        # 4MB at 20MB/s aggregate -> >= 0.2s regardless of 8 threads
        assert el >= 0.17, f"aggregate cap violated: {el}"

    def test_seek_contention_penalizes_hdd_concurrency(self):
        spec = TIERS["hdd"]
        lat2 = spec.seek_latency * (1 + spec.seek_contention)
        assert lat2 > spec.seek_latency

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            make_storage("floppy", "/tmp/x")


class _HugeSyntheticSource(Storage):
    """Models a huge file without backing bytes: read_range synthesizes the
    requested window.  Lets the chunked-copy test stream a multi-GB-modeled
    blob through real code paths in milliseconds of RAM."""

    def __init__(self, size: int):
        self._size = size
        self.max_read = 0

    def size(self, path: str) -> int:
        return self._size

    def read_file(self, path: str) -> bytes:
        raise AssertionError(
            "full-blob read of a multi-GB-modeled file — copy_to must stream")

    def read_range(self, path: str, offset: int, length: int) -> bytes:
        length = min(length, self._size - offset)
        self.max_read = max(self.max_read, length)
        return bytes((offset + i) & 0xFF for i in range(min(length, 64))) \
            + b"\x00" * max(0, length - 64)


class _SinkSpy(Storage):
    """Write sink recording per-op buffer sizes (nothing hits disk)."""

    def __init__(self):
        self.total = 0
        self.max_write = 0
        self.ops = []

    def write_file(self, path: str, data: bytes, sync: bool = False) -> None:
        self.ops.append(("write", len(data)))
        self.total += len(data)
        self.max_write = max(self.max_write, len(data))

    def append_file(self, path: str, data: bytes, sync: bool = False) -> None:
        self.ops.append(("append", len(data)))
        self.total += len(data)
        self.max_write = max(self.max_write, len(data))


class TestChunkedCopy:
    def test_copy_never_materializes_full_blob(self):
        """Regression: copy_to used to read the whole file into memory,
        ignoring its chunk parameter.  A 4 GiB-modeled copy must stream in
        chunk-sized buffers."""
        size = 4 << 30  # 4 GiB modeled
        chunk = 8 << 20
        src = _HugeSyntheticSource(size)
        dst = _SinkSpy()
        src.copy_to("big", dst, "big", chunk=chunk)
        assert dst.total == size
        assert src.max_read <= chunk, f"read {src.max_read} > chunk {chunk}"
        assert dst.max_write <= chunk, f"wrote {dst.max_write} > chunk {chunk}"
        assert dst.ops[0][0] == "write" and all(
            op == "append" for op, _ in dst.ops[1:])

    def test_chunked_copy_content_exact(self, tmp_storage):
        with tempfile.TemporaryDirectory() as d2:
            dst = NativeStorage(d2)
            rng = np.random.default_rng(0)
            data = rng.integers(0, 256, size=1_000_003, dtype=np.uint8).tobytes()
            tmp_storage.write_file("src.bin", data)
            tmp_storage.copy_to("src.bin", dst, "dst.bin", chunk=64 << 10)
            assert dst.read_file("dst.bin") == data

    def test_small_file_single_write(self, tmp_storage):
        dst = _SinkSpy()
        tmp_storage.write_file("s.bin", b"abc")
        tmp_storage.copy_to("s.bin", dst, "s.bin", chunk=1 << 20)
        assert dst.ops == [("write", 3)]

    def test_read_range_and_append(self, tmp_storage):
        tmp_storage.write_file("f", b"0123456789")
        assert tmp_storage.read_range("f", 2, 4) == b"2345"
        tmp_storage.append_file("f", b"AB")
        assert tmp_storage.read_file("f") == b"0123456789AB"
        assert tmp_storage.size("f") == 12

    def test_simulated_read_range_and_append_paced(self):
        spec = TierSpec("slow", 10e6, 10e6, 10e6, 10e6, 0, 0)
        with tempfile.TemporaryDirectory() as d:
            st = SimulatedStorage(d, spec)
            st.write_file("f", b"x" * 1_000_000)
            t0 = time.monotonic()
            part = st.read_range("f", 0, 1_000_000)  # 1MB at 10MB/s >= 0.1s
            el = time.monotonic() - t0
            assert len(part) == 1_000_000
            assert el >= 0.08, f"read_range not paced: {el}"
            t0 = time.monotonic()
            st.append_file("f", b"y" * 1_000_000)
            el = time.monotonic() - t0
            assert el >= 0.08, f"append_file not paced: {el}"
            assert st.size("f") == 2_000_000


class TestWriteRange:
    """pwrite-style positional writes — the drain engine's intra-file
    parallelism primitive.  Identity contract: any partition of a buffer,
    written as ranges in any order (even concurrently), reconstructs the
    byte-identical file ``write_file`` would have produced."""

    def test_out_of_order_ranges_reconstruct_file(self, tmp_storage):
        rng = np.random.default_rng(0)
        data = rng.integers(0, 256, size=1_000_003, dtype=np.uint8).tobytes()
        tmp_storage.write_range("f", 500_000, data[500_000:])
        tmp_storage.write_range("f", 0, data[:500_000])
        assert tmp_storage.read_file("f") == data

    def test_write_past_eof_zero_fills_gap(self, tmp_storage):
        tmp_storage.write_range("f", 8, b"tail")
        assert tmp_storage.read_file("f") == b"\x00" * 8 + b"tail"

    def test_overwrite_inside_existing_file(self, tmp_storage):
        tmp_storage.write_file("f", b"0123456789")
        tmp_storage.write_range("f", 3, b"XYZ")
        assert tmp_storage.read_file("f") == b"012XYZ6789"

    def test_concurrent_ranges(self, tmp_storage):
        rng = np.random.default_rng(1)
        data = rng.integers(0, 256, size=512 * 1024, dtype=np.uint8).tobytes()
        chunk = 37 * 1024  # deliberately unaligned
        tasks = [(off, data[off:off + chunk])
                 for off in range(0, len(data), chunk)]
        rng.shuffle(tasks)
        with ThreadPoolExecutor(4) as ex:
            list(ex.map(lambda t: tmp_storage.write_range("g", t[0], t[1]),
                        tasks))
        assert tmp_storage.read_file("g") == data

    def test_base_class_fallback(self):
        """The generic read-modify-write default must satisfy the same
        contract for Storage impls without a native pwrite."""

        class MinimalStorage(Storage):
            def __init__(self):
                self.files = {}

            def read_file(self, path):
                return self.files[path]

            def write_file(self, path, data, sync=False):
                self.files[path] = bytes(data)

            def exists(self, path):
                return path in self.files

            def size(self, path):
                return len(self.files[path])

        st = MinimalStorage()
        st.write_range("f", 4, b"BB")
        st.write_range("f", 0, b"AAAA")
        st.write_range("f", 2, b"xy")
        assert st.read_file("f") == b"AAxyBB"

    def test_simulated_write_range_paced(self):
        spec = TierSpec("slow", 10e6, 10e6, 10e6, 10e6, 0, 0)
        with tempfile.TemporaryDirectory() as d:
            st = SimulatedStorage(d, spec)
            t0 = time.monotonic()
            st.write_range("f", 0, b"x" * 1_000_000)  # 1MB @10MB/s >= 0.1s
            el = time.monotonic() - t0
            assert el >= 0.08, f"write_range not paced: {el}"
            assert st.size("f") == 1_000_000


class TestWriteRangeProperties:
    """Hypothesis: write_range partition/permutation == write_file."""

    @given(
        seed=st.integers(0, 2**31 - 1),
        size=st.integers(1, 4096),
        n_cuts=st.integers(0, 8),
    )
    @settings(max_examples=30, deadline=None)
    def test_random_partition_any_order_matches_write_file(
            self, seed, size, n_cuts):
        rng = np.random.default_rng(seed)
        data = rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()
        cuts = sorted(set(
            int(c) for c in rng.integers(1, size, size=n_cuts)
        )) if size > 1 else []
        bounds = [0] + cuts + [size]
        pieces = [(bounds[i], data[bounds[i]:bounds[i + 1]])
                  for i in range(len(bounds) - 1)]
        order = rng.permutation(len(pieces))
        with tempfile.TemporaryDirectory() as d:
            st1 = NativeStorage(d)
            st1.write_file("ref", data)
            for i in order:
                off, chunk = pieces[i]
                st1.write_range("out", off, chunk)
            assert st1.read_file("out") == st1.read_file("ref")

    @given(seed=st.integers(0, 2**31 - 1), size=st.integers(1, 2048))
    @settings(max_examples=20, deadline=None)
    def test_write_then_append_equals_two_ranges(self, seed, size):
        """write_file + append_file and two write_range calls are the same
        bytes — the equivalence the drain relies on when it re-streams a
        staged file as ranges."""
        rng = np.random.default_rng(seed)
        a = rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()
        b = rng.integers(0, 256, size=max(1, size // 2),
                         dtype=np.uint8).tobytes()
        with tempfile.TemporaryDirectory() as d:
            st1 = NativeStorage(d)
            st1.write_file("ref", a)
            st1.append_file("ref", b)
            st1.write_range("out", len(a), b)
            st1.write_range("out", 0, a)
            assert st1.read_file("out") == st1.read_file("ref")


class TestTracerTimeline:
    def test_timeline_csv(self):
        tracer = IOTracer(interval_s=0.05)
        with tempfile.TemporaryDirectory() as d:
            st = NativeStorage(d, tracer)
            st.write_file("f", b"x" * 100)
            time.sleep(0.12)
            st.read_file("f")
        rows = tracer.timeline()
        assert rows[0]["write_mb"] > 0
        assert rows[-1]["read_mb"] > 0
        csv = tracer.to_csv()
        assert csv.splitlines()[0].startswith("t_s,")
