"""Table I analogue: raw device bandwidth of each simulated tier.

IOR-style protocol: 8 parallel sequential streams of 8 MB each (IOR reaches
device max via concurrency; our tier model exposes max aggregate bandwidth
the same way).  The backing files stay in the host page cache on purpose —
the *simulated* device time must dominate the measurement.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

from .common import BenchEnv, emit

N_STREAMS = 8
STREAM_MB = 8


def run() -> None:
    env = BenchEnv(n_images=1, time_scale=1.0)
    rows = []
    total_mb = N_STREAMS * STREAM_MB
    for tier, st in env.storages.items():
        data = b"\xab" * (STREAM_MB << 20)
        with ThreadPoolExecutor(N_STREAMS) as pool:
            t0 = time.monotonic()
            list(pool.map(lambda i: st.write_file(f"ior{i}.bin", data, sync=True),
                          range(N_STREAMS)))
            tw = time.monotonic() - t0
            t0 = time.monotonic()
            list(pool.map(lambda i: st.read_file(f"ior{i}.bin"),
                          range(N_STREAMS)))
            tr = time.monotonic() - t0
        rows.append(f"{tier},read_mb_s={total_mb / tr:.1f},"
                    f"write_mb_s={total_mb / tw:.1f}")
        for i in range(N_STREAMS):
            st.remove(f"ior{i}.bin")
    emit("table1_ior", rows,
         "paper: hdd 163/133, ssd 281/195, optane 1603/512, lustre 1969/992 MB/s")
    env.close()


if __name__ == "__main__":
    run()
