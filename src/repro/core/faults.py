"""Fault injection for storage: crash/fail mid-I/O, deterministically.

The checkpoint stack *documents* atomicity ("a crash mid-save leaves the
previous checkpoint restorable") — this module is how the test suite
*proves* it.  :class:`FaultyStorage` wraps any :class:`Storage` and injects
failures at exact, reproducible points:

* ``fail_after(k)`` — the (k+1)-th matching operation (and, because a
  failed device stays failed, every one after it) raises
  :class:`FaultInjected`.  ``k=0`` fails the first op.
* ``fail_on(substring)`` — ops whose path contains ``substring`` fail
  (e.g. arm on ``"checkpoint"`` to kill exactly the commit-marker write).

``ops`` selects which operation kinds count/trip ("write" covers
``write_file``/``append_file``, "read" covers ``read_file``/``read_range``;
metadata ops are never failed — a crashed *device* is modelled by sticky
write+read failure, not by breaking ``exists``/``listdir`` which restore
paths legitimately probe).  The injected exception is raised *before* the
inner operation runs, so a tripped write leaves the target file untouched —
exactly a process killed between syscalls.

Example — prove a save killed mid-write keeps the previous step::

    faulty = FaultyStorage(storage)
    saver = CheckpointSaver(faulty, "ckpt/m")
    saver.save(1, tree)
    faulty.fail_after(1)                    # 2nd write of the next save dies
    with pytest.raises(FaultInjected):
        saver.save(2, tree)
    faulty.heal()
    assert saver.latest_step() == 1         # marker never moved
"""
from __future__ import annotations

import threading
from typing import List, Optional, Sequence

from .. import metrics
from .storage import Storage


class FaultInjected(OSError):
    """The error :class:`FaultyStorage` raises at its trigger point."""


_WRITE_OPS = ("write_file", "append_file")
_READ_OPS = ("read_file", "read_range")


class FaultyStorage(Storage):
    """Transparent :class:`Storage` wrapper with arm-able failure points."""

    def __init__(self, inner: Storage, *, sticky: bool = True):
        self.inner = inner
        self.name = f"faulty({getattr(inner, 'name', '?')})"
        self.sticky = sticky
        self._lock = threading.Lock()
        self._fail_after: Optional[int] = None
        self._fail_substring: Optional[str] = None
        self._ops: Sequence[str] = _WRITE_OPS
        self._count = 0
        self._tripped = False
        self.op_log: List[tuple] = []  # (op, path, nbytes) of every attempt

    # -- arming ---------------------------------------------------------------
    def fail_after(self, n_ops: int, ops: Sequence[str] = ("write",)) -> "FaultyStorage":
        """Let ``n_ops`` matching ops through, then fail."""
        with self._lock:
            self._fail_after = int(n_ops)
            self._ops = self._expand(ops)
            self._count = 0
            self._tripped = False
        return self

    def fail_on(self, substring: str, ops: Sequence[str] = ("write",)) -> "FaultyStorage":
        """Fail matching ops whose path contains ``substring``."""
        with self._lock:
            self._fail_substring = substring
            self._ops = self._expand(ops)
            self._tripped = False
        return self

    def heal(self) -> "FaultyStorage":
        """Disarm: the device works again (tests assert recovery after)."""
        with self._lock:
            self._fail_after = None
            self._fail_substring = None
            self._count = 0
            self._tripped = False
        return self

    @staticmethod
    def _expand(ops: Sequence[str]) -> Sequence[str]:
        out: List[str] = []
        for o in ops:
            if o == "write":
                out.extend(_WRITE_OPS)
            elif o == "read":
                out.extend(_READ_OPS)
            else:
                out.append(o)
        return tuple(out)

    # -- trigger --------------------------------------------------------------
    def _check(self, op: str, path: str, nbytes: int = 0) -> None:
        with self._lock:
            self.op_log.append((op, path, nbytes))
            if op not in self._ops:
                return
            if self._tripped and self.sticky:
                metrics.inc("storage.faults_injected", 1, op=op)
                raise FaultInjected(f"injected fault (sticky) on {op}({path!r})")
            if self._fail_substring is not None and self._fail_substring in path:
                self._tripped = True
                metrics.inc("storage.faults_injected", 1, op=op)
                raise FaultInjected(
                    f"injected fault on {op}({path!r}) matching "
                    f"{self._fail_substring!r}")
            if self._fail_after is not None:
                if self._count >= self._fail_after:
                    self._tripped = True
                    metrics.inc("storage.faults_injected", 1, op=op)
                    raise FaultInjected(
                        f"injected fault on {op}({path!r}) after "
                        f"{self._count} ops")
                self._count += 1

    # -- delegated I/O ---------------------------------------------------------
    def read_file(self, path: str) -> bytes:
        self._check("read_file", path)
        return self.inner.read_file(path)

    def read_range(self, path: str, offset: int, length: int) -> bytes:
        self._check("read_range", path, length)
        return self.inner.read_range(path, offset, length)

    def write_file(self, path: str, data: bytes, sync: bool = False) -> None:
        self._check("write_file", path, len(data))
        self.inner.write_file(path, data, sync=sync)

    def append_file(self, path: str, data: bytes, sync: bool = False) -> None:
        self._check("append_file", path, len(data))
        self.inner.append_file(path, data, sync=sync)

    def fsync_dir(self, path: str) -> None:
        self.inner.fsync_dir(path)

    # -- delegated namespace (never failed) ------------------------------------
    def listdir(self, path: str) -> List[str]:
        return self.inner.listdir(path)

    def exists(self, path: str) -> bool:
        return self.inner.exists(path)

    def makedirs(self, path: str) -> None:
        self.inner.makedirs(path)

    def remove(self, path: str) -> None:
        self.inner.remove(path)

    def rename(self, src: str, dst: str) -> None:
        self.inner.rename(src, dst)

    def size(self, path: str) -> int:
        return self.inner.size(path)

    def drop_caches(self) -> None:
        self.inner.drop_caches()
