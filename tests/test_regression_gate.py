"""benchmarks/regression_gate.py: flattening, comparison rules, CLI exit
codes against the committed baselines."""
import copy
import json
import os
import pathlib
import shutil
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))

from benchmarks import regression_gate as rg  # noqa: E402


def payload(samples=100.0, speedup=2.0):
    return {
        "benchmark": "fake",
        "config": {"n_images": 32, "thread_counts": [1, 2]},
        "tiers": {
            "hdd": {
                "1": {"samples_per_s": samples, "bytes_per_s": samples * 50,
                      "speedup": 1.0},
                "2": {"samples_per_s": samples * speedup,
                      "bytes_per_s": samples * speedup * 50,
                      "speedup": speedup},
            }
        },
        "bandwidth_monotone": {"hdd": True},
    }


class TestFlatten:
    def test_numeric_leaves_only(self):
        flat = rg.flatten(payload())
        assert flat["tiers.hdd.2.samples_per_s"] == 200.0
        assert flat["config.n_images"] == 32.0
        # booleans and strings are not numeric leaves
        assert "bandwidth_monotone.hdd" not in flat
        assert "benchmark" not in flat

    def test_gated_leaves_filters_to_throughput(self):
        gated = rg.gated_leaves(payload())
        assert set(gated) == {
            "tiers.hdd.1.samples_per_s", "tiers.hdd.1.bytes_per_s",
            "tiers.hdd.1.speedup",
            "tiers.hdd.2.samples_per_s", "tiers.hdd.2.bytes_per_s",
            "tiers.hdd.2.speedup",
        }
        # config ints (n_images etc.) are never gated
        assert not any(p.startswith("config.") for p in gated)


class TestCompare:
    def test_identical_passes(self):
        regs, _ = rg.compare(payload(), payload(), tolerance=0.25)
        assert regs == []

    def test_improvement_passes(self):
        regs, _ = rg.compare(payload(100), payload(150), tolerance=0.25)
        assert regs == []

    def test_within_tolerance_passes(self):
        regs, _ = rg.compare(payload(100), payload(80), tolerance=0.25)
        assert regs == []

    def test_regression_beyond_tolerance_fails(self):
        regs, _ = rg.compare(payload(100), payload(50), tolerance=0.25)
        assert regs
        assert any("samples_per_s" in r for r in regs)

    def test_config_change_skips_with_note(self):
        new = payload(10)  # massive regression, but...
        new["config"]["n_images"] = 64  # ...the sweep shape changed
        regs, notes = rg.compare(payload(100), new, tolerance=0.25)
        assert regs == []
        assert any("config changed" in n for n in notes)

    def test_disappeared_leaf_fails(self):
        new = payload()
        del new["tiers"]["hdd"]["2"]
        regs, _ = rg.compare(payload(), new, tolerance=0.25)
        assert any("disappeared" in r for r in regs)


class TestCli:
    """End-to-end through main() with a temp reports dir."""

    @pytest.fixture()
    def dirs(self, tmp_path, monkeypatch):
        baselines = tmp_path / "baselines"
        reports = tmp_path / "reports"
        baselines.mkdir()
        reports.mkdir()
        monkeypatch.setattr(rg, "BASELINE_DIR", str(baselines))
        return baselines, reports

    def _write(self, d, name, data):
        with open(os.path.join(str(d), name), "w") as f:
            json.dump(data, f)

    def test_pass_and_degraded_fail(self, dirs):
        baselines, reports = dirs
        self._write(baselines, "BENCH_fake.json", payload())
        self._write(reports, "BENCH_fake.json", payload())
        assert rg.main(["--reports-dir", str(reports)]) == 0
        # synthetically degrade throughput far beyond tolerance
        self._write(reports, "BENCH_fake.json", payload(samples=10))
        assert rg.main(["--reports-dir", str(reports)]) != 0

    def test_missing_report(self, dirs):
        baselines, reports = dirs
        self._write(baselines, "BENCH_fake.json", payload())
        assert rg.main(["--reports-dir", str(reports)]) != 0
        assert rg.main(["--reports-dir", str(reports),
                        "--allow-missing"]) == 0

    def test_no_baselines_fails(self, dirs):
        baselines, reports = dirs
        assert rg.main(["--reports-dir", str(reports)]) != 0

    def test_update_seeds_baselines(self, dirs):
        baselines, reports = dirs
        self._write(reports, "BENCH_fake.json", payload())
        assert rg.main(["--update", "--reports-dir", str(reports)]) == 0
        assert (baselines / "BENCH_fake.json").exists()
        assert rg.main(["--reports-dir", str(reports)]) == 0

    def test_tolerance_flag(self, dirs):
        baselines, reports = dirs
        self._write(baselines, "BENCH_fake.json", payload(100))
        self._write(reports, "BENCH_fake.json", payload(80))
        assert rg.main(["--reports-dir", str(reports),
                        "--tolerance", "0.1"]) != 0
        assert rg.main(["--reports-dir", str(reports),
                        "--tolerance", "0.3"]) == 0


@pytest.mark.skipif(
    not os.path.isdir(os.path.join(str(ROOT), "benchmarks", "baselines")),
    reason="no committed baselines")
class TestCommittedBaselines:
    """The committed baselines must gate: identical reports pass, a
    synthetically degraded BENCH json exits nonzero (issue acceptance)."""

    def test_identity_passes_and_degraded_fails(self, tmp_path):
        src = os.path.join(str(ROOT), "benchmarks", "baselines")
        reports = tmp_path / "reports"
        reports.mkdir()
        for f in os.listdir(src):
            shutil.copyfile(os.path.join(src, f), str(reports / f))
        assert rg.main(["--smoke", "--reports-dir", str(reports)]) == 0

        # degrade every gated leaf of one report by 10x
        victim = sorted(os.listdir(src))[0]
        with open(str(reports / victim)) as f:
            data = json.load(f)

        def degrade(obj):
            if isinstance(obj, dict):
                for k, v in obj.items():
                    if k in rg.GATED_LEAVES and isinstance(v, (int, float)):
                        obj[k] = v / 10.0
                    else:
                        degrade(v)

        degraded = copy.deepcopy(data)
        degrade(degraded["tiers" if "tiers" in degraded else "pipelines"])
        if "speedup_sharded_vs_legacy" in degraded:
            degraded["speedup_sharded_vs_legacy"] /= 10.0
        with open(str(reports / victim), "w") as f:
            json.dump(degraded, f)
        assert rg.main(["--smoke", "--reports-dir", str(reports)]) != 0
