"""Property-based checkpoint tests (hypothesis, with the bare-env shim).

* save→restore identity across dtypes / shapes / shard counts / io_threads
  (bit-exact, including extension dtypes via ml_dtypes);
* quantize_blockwise/dequantize_blockwise error bounds, including the
  pad path (size not a multiple of the block) and all-zero blocks.
"""
import tempfile

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.checkpoint import (
    CheckpointSaver, dequantize_blockwise, quantize_blockwise, resolve_dtype,
)
from repro.core.storage import NativeStorage

_QBLOCK = 256

DTYPES = ("float32", "float64", "int32", "int8", "uint8", "bool", "bfloat16")


def _random_array(rng: np.random.Generator, shape, dtype_name: str):
    dtype = resolve_dtype(dtype_name)
    if dtype_name == "bool":
        return rng.integers(0, 2, size=shape).astype(bool)
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        return rng.integers(info.min, info.max, size=shape,
                            endpoint=True).astype(dtype)
    return (rng.normal(size=shape) * 100).astype(dtype)


class TestRoundtripIdentity:
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_shards=st.integers(1, 4),
        io_threads=st.integers(1, 4),
        dtype=st.sampled_from(DTYPES),
        n_leaves=st.integers(1, 5),
    )
    @settings(max_examples=15, deadline=None)
    def test_save_restore_identity(self, seed, n_shards, io_threads, dtype,
                                   n_leaves):
        rng = np.random.default_rng(seed)
        shapes = [
            tuple(int(d) for d in rng.integers(1, 24, size=rng.integers(0, 4)))
            for _ in range(n_leaves)
        ]
        tree = {f"leaf{i}": _random_array(rng, shp, dtype)
                for i, shp in enumerate(shapes)}
        with tempfile.TemporaryDirectory() as d:
            saver = CheckpointSaver(NativeStorage(d), "ckpt/m",
                                    n_shards=n_shards, io_threads=io_threads)
            saver.save(1, tree)
            out = saver.restore_pytree(tree)
        for k in tree:
            assert str(out[k].dtype) == str(tree[k].dtype)
            assert out[k].shape == tree[k].shape
            np.testing.assert_array_equal(
                np.asarray(out[k], dtype=np.float64) if dtype == "bfloat16"
                else out[k],
                np.asarray(tree[k], dtype=np.float64) if dtype == "bfloat16"
                else tree[k])


class TestQuantizeProperties:
    @given(
        seed=st.integers(0, 2**31 - 1),
        size=st.integers(1, 4 * _QBLOCK + 17),
        scale_exp=st.floats(-3.0, 3.0),
    )
    @settings(max_examples=30, deadline=None)
    def test_error_bound_incl_pad_path(self, seed, size, scale_exp):
        """|x - dq(q(x))| <= absmax_block/127 * 0.5 (+eps), any size — the
        pad path (size % 256 != 0) must round-trip shape exactly."""
        rng = np.random.default_rng(seed)
        x = (rng.normal(size=(size,)) * (10.0 ** scale_exp)).astype(np.float32)
        q, scale, pad = quantize_blockwise(x)
        assert (len(x) + pad) % _QBLOCK == 0
        back = dequantize_blockwise(q, scale, pad, x.shape, np.float32)
        assert back.shape == x.shape
        padded_x = np.pad(x, (0, pad)).reshape(-1, _QBLOCK)
        bound = np.abs(padded_x).max(axis=1, keepdims=True) / 127.0 * 0.5 + 1e-7
        err = np.abs(padded_x - np.pad(back, (0, pad)).reshape(-1, _QBLOCK))
        assert (err <= bound + 1e-6).all()

    @given(
        n_blocks=st.integers(1, 4),
        tail=st.integers(0, _QBLOCK - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_all_zero_blocks_roundtrip_exactly(self, n_blocks, tail):
        """scale==0 blocks must not divide by zero and must come back as
        exact zeros."""
        x = np.zeros(n_blocks * _QBLOCK + tail, np.float32)
        q, scale, pad = quantize_blockwise(x)
        assert np.isfinite(scale).all() and (q == 0).all()
        back = dequantize_blockwise(q, scale, pad, x.shape, np.float32)
        np.testing.assert_array_equal(back, x)

    @given(seed=st.integers(0, 2**31 - 1), zero_block=st.integers(0, 3))
    @settings(max_examples=20, deadline=None)
    def test_mixed_zero_and_data_blocks(self, seed, zero_block):
        """An all-zero block embedded among data blocks stays exactly zero."""
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(4, _QBLOCK)).astype(np.float32)
        x[zero_block] = 0.0
        flat = x.reshape(-1)
        q, scale, pad = quantize_blockwise(flat)
        back = dequantize_blockwise(q, scale, pad, flat.shape, np.float32)
        np.testing.assert_array_equal(
            back.reshape(4, _QBLOCK)[zero_block], np.zeros(_QBLOCK))
