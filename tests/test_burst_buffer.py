"""Burst buffer (paper §III-C/V-C): drain completeness, non-blocking, restore."""
import time

import numpy as np

from repro.core.burst_buffer import BurstBufferCheckpointer, DirectCheckpointer
from repro.core.checkpoint import CheckpointSaver


def big_tree(mb=2):
    rng = np.random.default_rng(0)
    return {"w": rng.normal(size=(mb * 1024 * 256,)).astype(np.float32)}


class TestBurstBuffer:
    def test_drain_completeness(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        bb = BurstBufferCheckpointer(fast, slow, "ckpt/m", keep=5)
        t = big_tree(1)
        for s in (10, 20, 30):
            bb.save(s, t)
        bb.wait()
        slow_saver = CheckpointSaver(slow, "ckpt/m")
        assert slow_saver.all_steps() == [10, 20, 30]
        out = slow_saver.restore_pytree(t, step=30)
        np.testing.assert_array_equal(out["w"], t["w"])
        bb.close()

    def test_training_blocked_only_on_fast_tier(self, fast_slow_storage):
        """The blocked time must track the fast tier, not the slow one."""
        fast, slow = fast_slow_storage
        t = big_tree(8)
        direct_slow = DirectCheckpointer(slow, "d/m")
        direct_slow.save(1, t)
        slow_block = direct_slow.blocked_s[0]

        bb = BurstBufferCheckpointer(fast, slow, "bb/m")
        bb.save(1, t)
        bb_block = bb.blocked_s[0]
        bb.wait()
        bb.close()
        assert bb_block < slow_block * 0.6, (
            f"burst buffer blocked {bb_block:.3f}s vs direct-slow {slow_block:.3f}s"
        )

    def test_restore_prefers_fast_tier(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        bb = BurstBufferCheckpointer(fast, slow, "ckpt/m")
        t = big_tree(1)
        bb.save(7, t)
        bb.wait()
        out = bb.restore_pytree(t)
        np.testing.assert_array_equal(out["w"], t["w"])
        assert bb.latest_step() == 7
        bb.close()

    def test_restore_falls_back_to_slow(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        bb = BurstBufferCheckpointer(fast, slow, "ckpt/m")
        t = big_tree(1)
        bb.save(7, t)
        bb.wait()
        bb.close()
        # simulate losing the burst buffer (node-local NVM gone)
        fast.remove("ckpt")
        bb2 = BurstBufferCheckpointer(fast, slow, "ckpt/m")
        out = bb2.restore_pytree(t)
        np.testing.assert_array_equal(out["w"], t["w"])
        bb2.close()

    def test_fast_tier_cleanup(self, fast_slow_storage):
        """Old staged checkpoints are evicted from the small fast tier."""
        fast, slow = fast_slow_storage
        bb = BurstBufferCheckpointer(fast, slow, "ckpt/m", keep=5)
        t = big_tree(1)
        for s in (1, 2, 3):
            bb.save(s, t)
        bb.wait()
        assert bb.fast_saver.all_steps()  # marker intact
        files = fast.listdir("ckpt")
        # only the newest staged step retains data files
        assert not any(f.startswith("m-1.data") for f in files)
        assert any(f.startswith("m-3.data") for f in files)
        bb.close()


class TestDirect:
    def test_direct_interface(self, tmp_storage):
        d = DirectCheckpointer(tmp_storage, "ckpt/m", keep=2)
        t = big_tree(1)
        d.save(1, t)
        d.save(2, t)
        assert d.latest_step() == 2
        out = d.restore_pytree(t)
        np.testing.assert_array_equal(out["w"], t["w"])
        d.wait()  # no-op
        d.close()

    def test_close_discipline_matches_async_engines(self, tmp_storage):
        """PR-7 handle/close parity: close() is idempotent, save() after
        close() raises, and a save failure is delivered exactly once
        (inline) — never again via wait()/close()."""
        import pytest

        from repro.core.faults import FaultInjected, FaultyStorage

        faulty = FaultyStorage(tmp_storage)
        d = DirectCheckpointer(faulty, "ckpt/m")
        t = big_tree(1)
        d.save(1, t)
        faulty.fail_after(0)
        with pytest.raises(FaultInjected):  # delivered inline, once
            d.save(2, t)
        faulty.heal()
        d.wait()    # must NOT re-raise the already-delivered error
        d.close()   # likewise
        d.close()   # idempotent
        with pytest.raises(RuntimeError):
            d.save(3, t)
        assert d.latest_step() == 1  # failed save never committed
