"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

BLOCK = 256


# -- quantize ----------------------------------------------------------------
def quantize_blocks_ref(x: jax.Array):
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_blocks_ref(q: jax.Array, s: jax.Array):
    return q.astype(jnp.float32) * s


# -- preprocess -----------------------------------------------------------------
def normalize_images_ref(x: jax.Array, mean: jax.Array, std: jax.Array):
    xf = x.astype(jnp.float32) / 255.0
    return (xf - mean[None, :, None]) / std[None, :, None]


def resize_convert_ref(x: jax.Array, out_h: int, out_w: int):
    """Oracle for the fused resize+convert kernel: per-axis lerp in fp32 with
    the same align-corners sample positions, conversion applied up front."""
    b, h, w, c = x.shape
    scale = {jnp.uint8: 255.0, jnp.uint16: 65535.0}.get(x.dtype.type, 1.0)
    xf = x.astype(jnp.float32) / scale

    def axis_lerp(arr, n_in, n_out, axis):
        pos = jnp.linspace(0, n_in - 1, n_out)
        lo = jnp.floor(pos).astype(jnp.int32)
        hi = jnp.minimum(lo + 1, n_in - 1)
        frac = (pos - lo).reshape([-1 if a == axis else 1
                                   for a in range(arr.ndim)])
        return (jnp.take(arr, lo, axis=axis) * (1 - frac)
                + jnp.take(arr, hi, axis=axis) * frac)

    return axis_lerp(axis_lerp(xf, h, out_h, 1), w, out_w, 2)


# -- flash attention ---------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal=True):
    """q/k/v: (BH, S, hd); naive softmax attention in fp32."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
