"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2. One attention layer per 8-layer period; the
other 7 use the (Mamba2/SSD) mixer — see DESIGN.md hardware-adaptation notes."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_period=2,          # MoE every 2nd layer (dense MLP otherwise)
    attn_period=8,          # 1 attention : 7 mamba
    ssm_state=128,
    ssm_head_dim=128,
    ssm_expand=2,
    ssm_conv_width=4,
    source="arXiv:2403.19887; hf",
)
