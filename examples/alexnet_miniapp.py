"""The paper's AlexNet mini-application (§III-B), end to end.

    PYTHONPATH=src python examples/alexnet_miniapp.py [--tier hdd|ssd|optane]

Generates a Caltech-101-like corpus on a simulated tier, trains AlexNet with
the full input pipeline, and prints per-step data-wait vs compute (the
paper's prefetch-overlap observable) plus a dstat-style I/O trace.

``--trace OUT.json`` adds per-op span collection (Chrome trace + Darshan
report); ``--metrics OUT.jsonl`` adds live telemetry (sampled gauge/counter
time series, Prometheus snapshot, per-step stall detection).  The two
compose: with both, the trace report embeds the metrics timeline.
"""
import argparse, os, sys, tempfile
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import metrics, trace
from repro.configs import ALEXNET_SMOKE as CFG
from repro.core import IOTracer, image_pipeline, make_storage, \
    sharded_image_pipeline
from repro.core import records
from repro.models import alexnet as A
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tier", default="ssd")
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--prefetch", type=int, default=1)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--sharded", action="store_true",
                    help="stream the corpus from multi-record shards via "
                         "the interleaved read engine instead of "
                         "one-file-per-image")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="collect per-op spans and write a Chrome trace "
                         "(open in Perfetto); also prints the per-stage "
                         "Darshan-style report")
    ap.add_argument("--metrics", metavar="OUT.jsonl", default=None,
                    help="enable live telemetry: sample the metrics "
                         "registry (prefetch occupancy, storage latency "
                         "sketches, per-step heartbeat) into a JSONL time "
                         "series and print the final Prometheus-text "
                         "snapshot; composes with --trace")
    args = ap.parse_args()

    tracer = IOTracer(0.25)
    st = make_storage(args.tier, tempfile.mkdtemp(), tracer, time_scale=0.2)
    if args.sharded:
        shard_paths, shard_labels = records.write_sharded_image_dataset(
            st, 128, 16, mean_hw=(64, 64), n_classes=CFG.n_classes)
    else:
        paths, labels = records.write_image_dataset(
            st, 128, mean_hw=(64, 64), n_classes=CFG.n_classes)
    tracer.reset()

    if args.sharded:
        ds = sharded_image_pipeline(st, shard_paths, shard_labels,
                                    batch_size=16,
                                    cycle_length=args.threads,
                                    num_parallel_calls=args.threads,
                                    prefetch=args.prefetch,
                                    out_hw=(CFG.in_hw, CFG.in_hw),
                                    repeat=True)
    else:
        ds = image_pipeline(st, paths, labels, batch_size=16,
                            num_parallel_calls=args.threads,
                            prefetch=args.prefetch,
                            out_hw=(CFG.in_hw, CFG.in_hw), repeat=True)

    params = A.init_params(jax.random.PRNGKey(0), CFG)
    state = {"params": params, "step": jnp.int32(0)}

    @jax.jit
    def train_step(state, batch):
        imgs, lbls = batch
        loss, g = jax.value_and_grad(
            lambda p: A.loss_fn(p, imgs, lbls, CFG))(state["params"])
        new_p = jax.tree.map(lambda p, gg: p - 1e-4 * gg, state["params"], g)
        return {"params": new_p, "step": state["step"] + 1}, {"loss": loss}

    collector = trace.start() if args.trace else None
    sampler = None
    stall = None
    if args.metrics:
        metrics.start()
        sampler = metrics.Sampler(interval_s=0.1, jsonl_path=args.metrics)
        sampler.start()
        stall = metrics.StallDetector(min_samples=4)
    tr = Trainer(train_step, state, iter(ds), stall_detector=stall)
    tr.run(args.steps)
    tr.close()  # repeat() pipeline: stop the prefetch producer promptly
    rep = tr.report()
    print(f"tier={args.tier} threads={args.threads} prefetch={args.prefetch}"
          f" sharded={args.sharded}")
    print(f"  data-wait fraction: {rep['data_wait_frac']:.1%} "
          f"(prefetch hides I/O when ~0)")
    print(f"  losses: {[round(h['loss'], 3) for h in tr.history]}")
    print("dstat-style read trace (MB/s):")
    print(tracer.to_csv())
    metric_points = None
    if sampler is not None:
        sampler.stop()
        metric_points = sampler.points()
        print(f"\nmetrics time series written to {args.metrics} "
              f"({len(metric_points)} samples)")
        print(metrics.to_prometheus_text(metrics.get_registry()))
        if stall is not None and stall.events:
            print(f"stalls detected: {stall.summary()}")
        metrics.stop()
    if collector is not None:
        trace.stop()
        trace.dump_chrome_trace(collector, args.trace,
                                process_name="alexnet-miniapp")
        print(f"\nChrome trace written to {args.trace}")
        print(trace.to_markdown(collector.spans(),
                                title="Per-stage I/O report",
                                counters=collector.counters(),
                                metrics_series=metric_points))


if __name__ == "__main__":
    main()
