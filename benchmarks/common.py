"""Shared benchmark plumbing: simulated tiers + corpora + CSV emission.

Every benchmark maps to one paper table/figure and prints
``name,<key>=<val>,...`` CSV rows plus a ``derived`` summary line comparing
against the paper's claim.  ``TIME_SCALE`` uniformly accelerates the storage
simulation (all ratios preserved); the default keeps the full suite ~minutes.
"""
from __future__ import annotations

import os
import sys
import tempfile
from typing import Dict, List, Tuple

sys.path.insert(0, "src")

import numpy as np

from repro.core import make_storage
from repro.core import records
from repro.core.stats import IOTracer

TIME_SCALE = float(os.environ.get("REPRO_TIME_SCALE", "0.05"))
RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "reports")
# RAM-backed scratch: the simulator's pacing must dominate, not the real VM
# disk. /dev/shm gives GB/s backing so even the 'optane' tier is honest.
SCRATCH = "/dev/shm" if os.path.isdir("/dev/shm") else None


class BenchEnv:
    """Temp-dir backed set of simulated storage tiers with one corpus each."""

    def __init__(self, tiers=("hdd", "ssd", "optane", "lustre"),
                 n_images=256, mean_hw=(48, 48), seed=0,
                 time_scale=None):
        self._tmp = tempfile.TemporaryDirectory(dir=SCRATCH)
        self.tracers: Dict[str, IOTracer] = {}
        self.storages = {}
        self.corpora: Dict[str, Tuple[List[str], List[int]]] = {}
        for tier in tiers:
            tracer = IOTracer(0.25)
            st = make_storage(tier, os.path.join(self._tmp.name, tier),
                              tracer,
                              time_scale=TIME_SCALE if time_scale is None
                              else time_scale)
            paths, labels = records.write_image_dataset(
                st, n_images, mean_hw=mean_hw, seed=seed)
            tracer.reset()
            self.tracers[tier] = tracer
            self.storages[tier] = st
            self.corpora[tier] = (paths, labels)

    def close(self):
        self._tmp.cleanup()


def emit(name: str, rows: List[str], derived: str = "") -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = []
    for r in rows:
        line = f"{name},{r}"
        print(line)
        out.append(line)
    if derived:
        line = f"{name},derived,{derived}"
        print(line)
        out.append(line)
    with open(os.path.join(RESULTS_DIR, "bench_results.csv"), "a") as f:
        f.write("\n".join(out) + "\n")
