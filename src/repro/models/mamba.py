"""Mamba2 — SSD (state-space duality) mixer, chunked scan + O(1) decode.

Follows the Mamba2 paper (arXiv:2405.21060), ngroups=1:

    h_t = a_t * h_{t-1} + dt_t * B_t ⊗ x_t        a_t = exp(dt_t * A)
    y_t = C_t · h_t + D * x_t

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute inside chunks of length Q, linear recurrence across chunks — the
TPU-friendly formulation (dense matmuls for the MXU, one small scan).
Decode keeps (state, conv window) caches and costs O(1) per token.

Parameter layout per layer (stacked with leading n_layers dim by the model):
    in_proj:  (D, 2*d_inner + 2*N + H)   -> z, x, B, C, dt
    conv_w:   (W, d_inner + 2*N)          causal depthwise conv
    conv_b:   (d_inner + 2*N,)
    dt_bias:  (H,)
    A_log:    (H,)
    D:        (H,)
    norm_w:   (d_inner,)                  gated RMSNorm
    out_proj: (d_inner, D)
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import gated_rms_norm

Array = jax.Array


def mamba_param_shapes(cfg) -> Dict[str, tuple]:
    din, N, H, W = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
    D = cfg.d_model
    return dict(
        in_proj=(D, 2 * din + 2 * N + H),
        conv_w=(W, din + 2 * N),
        conv_b=(din + 2 * N,),
        dt_bias=(H,),
        A_log=(H,),
        D=(H,),
        norm_w=(din,),
        out_proj=(din, D),
    )


def mamba_param_logical(cfg) -> Dict[str, tuple]:
    return dict(
        in_proj=("d_model_w", "d_inner"),
        conv_w=("conv_w", "d_inner"),
        conv_b=("d_inner",),
        dt_bias=(None,),
        A_log=(None,),
        D=(None,),
        norm_w=("d_inner",),
        out_proj=("d_inner", "d_model_w"),
    )


def init_mamba_params(rng, cfg, dtype) -> Dict[str, Array]:
    shapes = mamba_param_shapes(cfg)
    keys = jax.random.split(rng, len(shapes))
    params = {}
    for (name, shape), key in zip(sorted(shapes.items()), keys):
        if name == "A_log":
            params[name] = jnp.log(
                jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
            )
        elif name == "dt_bias":
            # dt init: softplus^-1(uniform [1e-3, 1e-1])
            dt = jnp.exp(
                jax.random.uniform(key, shape, jnp.float32)
                * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3)
            )
            params[name] = dt + jnp.log(-jnp.expm1(-dt))
        elif name == "D":
            params[name] = jnp.ones(shape, jnp.float32)
        elif name in ("norm_w", "conv_b"):
            params[name] = jnp.zeros(shape, dtype)
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            params[name] = (
                jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)
            ).astype(dtype)
    return params


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (B, L, C) with taps w: (W, C)."""
    W = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        shift = W - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(
    x: Array,      # (B, L, H, P)  already multiplied by nothing (dt applied inside)
    dt: Array,     # (B, L, H)     post-softplus
    A: Array,      # (H,)          negative
    Bm: Array,     # (B, L, N)
    Cm: Array,     # (B, L, N)
    D: Array,      # (H,)
    *,
    chunk: int = 128,
    init_state: Optional[Array] = None,   # (B, H, P, N)
) -> Tuple[Array, Array]:
    """Chunked SSD scan.  Returns (y: (B,L,H,P), final_state: (B,H,P,N))."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    if L % chunk:
        chunk = L
    nc = L // chunk

    loga = (dt * A.astype(jnp.float32)).reshape(Bsz, nc, chunk, H)   # log a_t < 0
    xdt = (x.astype(jnp.float32) * dt[..., None]).reshape(Bsz, nc, chunk, H, P)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, chunk, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, N)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((Bsz, H, P, N), jnp.float32)
    )

    def body(s_prev, xs):
        la, xd, b, c = xs               # (B,Q,H), (B,Q,H,P), (B,Q,N), (B,Q,N)
        cl = jnp.cumsum(la, axis=1)     # (B,Q,H) inclusive
        # intra-chunk: y[t] = sum_{s<=t} C_t·B_s * exp(cl_t - cl_s) * xdt_s
        diff = cl[:, :, None, :] - cl[:, None, :, :]        # (B,Q,Q,H) t,s
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("btn,bsn->bts", c, b)               # (B,Q,Q)
        y_intra = jnp.einsum("bts,btsh,bshp->bthp", cb, Lmat, xd)
        # inter-chunk: y[t] += C_t · (exp(cl_t) * S_prev)
        y_inter = jnp.einsum(
            "btn,bth,bhpn->bthp", c, jnp.exp(cl), s_prev
        )
        # state update: S' = S * prod(a) + sum_s exp(cl_end - cl_s) B_s ⊗ xdt_s
        decay_to_end = jnp.exp(cl[:, -1:, :] - cl)          # (B,Q,H)
        S_c = jnp.einsum("bsh,bsn,bshp->bhpn", decay_to_end, b, xd)
        s_new = s_prev * jnp.exp(cl[:, -1, :])[:, :, None, None] + S_c
        return s_new, y_intra + y_inter

    xs = (
        jnp.moveaxis(loga, 1, 0),
        jnp.moveaxis(xdt, 1, 0),
        jnp.moveaxis(Bc, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
    )
    # remat the chunk body: the (B,Q,Q,H) intra-chunk decay/prob tensors are
    # recomputed in the backward sweep instead of being stored once per chunk
    # (nc x 134 MB/device for jamba — the dominant train-memory term before)
    s_final, y_chunks = lax.scan(jax.checkpoint(body), s0, xs)  # y: (nc,B,Q,H,P)
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(Bsz, L, H, P)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y, s_final


def mamba_forward(
    params: Dict[str, Array],
    u: Array,                       # (B, L, D)
    cfg,
    *,
    ctx=None,
    chunk: int = 128,
    init_state: Optional[Array] = None,
    return_cache: bool = False,
) -> Tuple[Array, Any]:
    """Full Mamba2 block (train/prefill).

    Returns (out (B,L,D), final_state) — or, with ``return_cache``,
    (out, (final_state, conv_window)) where conv_window is the raw last
    W-1 pre-conv inputs needed to continue decoding."""
    Bsz, L, _ = u.shape
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    W = cfg.ssm_conv_width

    proj = u @ params["in_proj"]                     # (B,L, 2din+2N+H)
    z, xBC_raw, dt_raw = jnp.split(proj, [din, 2 * din + 2 * N], axis=-1)
    if ctx is not None:
        # mamba mixes over time, not channels: shard d_inner (heads) across
        # 'model' and keep seq whole — the dual of attention's layout
        z = ctx.constrain(z, "batch", "seq", "d_inner")
        xBC_raw = ctx.constrain(xBC_raw, "batch", "seq", "d_inner")
    xBC = jax.nn.silu(_causal_conv(xBC_raw, params["conv_w"], params["conv_b"]))
    x, Bm, Cm = jnp.split(xBC, [din, din + N], axis=-1)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq", "d_inner")
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                # (B,L,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, s_final = ssd_chunked(
        x.reshape(Bsz, L, H, P), dt, A, Bm, Cm, params["D"],
        chunk=chunk, init_state=init_state,
    )
    y = y.reshape(Bsz, L, din).astype(u.dtype)
    y = gated_rms_norm(y, z, params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_cache:
        # pad on the left if the prompt is shorter than the conv window
        tail = xBC_raw[:, -(W - 1):, :]
        if L < W - 1:
            tail = jnp.pad(tail, ((0, 0), (W - 1 - L, 0), (0, 0)))
        return out, (s_final, tail)
    return out, s_final


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32) -> Dict[str, Array]:
    din, N, H, W = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
    P = cfg.ssm_head_dim
    return dict(
        state=jnp.zeros((batch, H, P, N), jnp.float32),
        conv=jnp.zeros((batch, W - 1, din + 2 * N), dtype),
    )


def mamba_decode(
    params: Dict[str, Array],
    u: Array,                       # (B, 1, D)
    cache: Dict[str, Array],
    cfg,
) -> Tuple[Array, Dict[str, Array]]:
    """O(1) single-token step: conv window update + state recurrence."""
    Bsz = u.shape[0]
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim

    proj = (u[:, 0] @ params["in_proj"])             # (B, 2din+2N+H)
    z, xBC, dt_raw = jnp.split(proj, [din, 2 * din + 2 * N], axis=-1)
    # conv over cached window + current input
    win = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # (B,W,C)
    w = params["conv_w"].astype(jnp.float32)         # (W,C)
    conv_out = (win.astype(jnp.float32) * w[None]).sum(axis=1) + params[
        "conv_b"
    ].astype(jnp.float32)
    xBC_t = jax.nn.silu(conv_out).astype(u.dtype)
    x, Bm, Cm = jnp.split(xBC_t, [din, din + N], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                              # (B,H)
    xh = x.reshape(Bsz, H, P).astype(jnp.float32) * dt[..., None]
    dstate = jnp.einsum("bhp,bn->bhpn", xh, Bm.astype(jnp.float32))
    state = cache["state"] * a[:, :, None, None] + dstate
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + x.reshape(Bsz, H, P).astype(jnp.float32) * params["D"].astype(
        jnp.float32
    )[None, :, None]
    y = y.reshape(Bsz, din).astype(u.dtype)
    y = gated_rms_norm(y[:, None, :], z[:, None, :], params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    new_cache = dict(state=state, conv=win[:, 1:, :])
    return out, new_cache
