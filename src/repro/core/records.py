"""Record container + image payload format ("the files" of the paper).

The paper's workloads read one JPEG per file and decode+resize it inside the
mapped function.  We have no JPEG codec in this environment, so we define:

* ``RRF1`` — a TFRecord-like container: for each record
  ``[u64 length][u32 crc32(length)][payload][u32 crc32(payload)]``.
  Corrupt records raise :class:`RecordError` (exercised by
  ``Dataset.ignore_errors()``, paper §III-A).
* ``IMG1`` — an image payload: 16-byte header
  ``magic(4s) | h(u32) | w(u32) | c(u16) | dtype(u16)`` followed by raw
  ``h*w*c`` samples.  ``decode_image`` is the ``tf.image.decode_jpeg``
  analogue: it parses, validates and materializes the array — a real
  CPU-side decode step with a real cost, which is what the paper measures.

Preprocessing mirrors the paper's mapped function: decode → convert dtype to
float in [0,1] → resize to the network's input size (224x224x3 for AlexNet).
"""
from __future__ import annotations

import struct
import zlib
from typing import Iterator, List, Sequence, Tuple

import numpy as np

RECORD_HDR = struct.Struct("<QI")   # length, crc(length)
RECORD_FTR = struct.Struct("<I")    # crc(payload)
IMG_HDR = struct.Struct("<4sIIHH")  # magic, h, w, c, dtype-code
IMG_MAGIC = b"IMG1"

_DTYPES = {0: np.uint8, 1: np.uint16, 2: np.float32}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


class RecordError(ValueError):
    """Raised on CRC mismatch / truncated record / bad image header."""


# ---------------------------------------------------------------------------
# RRF1 container
# ---------------------------------------------------------------------------
def encode_record(payload: bytes) -> bytes:
    hdr = RECORD_HDR.pack(len(payload), zlib.crc32(struct.pack("<Q", len(payload))))
    ftr = RECORD_FTR.pack(zlib.crc32(payload))
    return hdr + payload + ftr


def decode_records(blob: bytes) -> Iterator[bytes]:
    """Yield payloads from a byte-string of concatenated RRF1 records."""
    off, n = 0, len(blob)
    while off < n:
        if off + RECORD_HDR.size > n:
            raise RecordError("truncated record header")
        length, hcrc = RECORD_HDR.unpack_from(blob, off)
        if zlib.crc32(struct.pack("<Q", length)) != hcrc:
            raise RecordError("record header crc mismatch")
        off += RECORD_HDR.size
        if off + length + RECORD_FTR.size > n:
            raise RecordError("truncated record payload")
        payload = blob[off : off + length]
        off += length
        (pcrc,) = RECORD_FTR.unpack_from(blob, off)
        off += RECORD_FTR.size
        if zlib.crc32(payload) != pcrc:
            raise RecordError("record payload crc mismatch")
        yield payload


def decode_single_record(blob: bytes) -> bytes:
    payloads = list(decode_records(blob))
    if len(payloads) != 1:
        raise RecordError(f"expected 1 record, found {len(payloads)}")
    return payloads[0]


# ---------------------------------------------------------------------------
# IMG1 payload
# ---------------------------------------------------------------------------
def encode_image(arr: np.ndarray) -> bytes:
    arr = np.ascontiguousarray(arr)
    if arr.ndim == 2:
        arr = arr[:, :, None]
    if arr.ndim != 3:
        raise ValueError(f"image must be HxWxC, got shape {arr.shape}")
    code = _DTYPE_CODES.get(arr.dtype)
    if code is None:
        raise ValueError(f"unsupported image dtype {arr.dtype}")
    h, w, c = arr.shape
    return IMG_HDR.pack(IMG_MAGIC, h, w, c, code) + arr.tobytes()


def decode_image(payload: bytes) -> np.ndarray:
    """``tf.image.decode_jpeg`` analogue (parse + validate + materialize)."""
    if len(payload) < IMG_HDR.size:
        raise RecordError("image payload too short")
    magic, h, w, c, code = IMG_HDR.unpack_from(payload, 0)
    if magic != IMG_MAGIC:
        raise RecordError(f"bad image magic {magic!r}")
    dtype = _DTYPES.get(code)
    if dtype is None:
        raise RecordError(f"bad image dtype code {code}")
    body = payload[IMG_HDR.size :]
    expected = h * w * c * np.dtype(dtype).itemsize
    if len(body) != expected:
        raise RecordError(f"image body {len(body)}B != expected {expected}B")
    return np.frombuffer(body, dtype=dtype).reshape(h, w, c).copy()


# ---------------------------------------------------------------------------
# Preprocessing (the paper's mapped function, post-decode)
# ---------------------------------------------------------------------------
def convert_image_dtype(img: np.ndarray) -> np.ndarray:
    """uint{8,16} -> float32 in [0,1] (tf.image.convert_image_dtype)."""
    if img.dtype == np.uint8:
        return img.astype(np.float32) / 255.0
    if img.dtype == np.uint16:
        return img.astype(np.float32) / 65535.0
    return img.astype(np.float32)


def resize_image(img: np.ndarray, out_h: int, out_w: int) -> np.ndarray:
    """Bilinear resize (tf.image.resize_images analogue), pure numpy."""
    h, w, c = img.shape
    if (h, w) == (out_h, out_w):
        return img
    ys = np.linspace(0, h - 1, out_h, dtype=np.float32)
    xs = np.linspace(0, w - 1, out_w, dtype=np.float32)
    y0 = np.floor(ys).astype(np.int32)
    x0 = np.floor(xs).astype(np.int32)
    y1 = np.minimum(y0 + 1, h - 1)
    x1 = np.minimum(x0 + 1, w - 1)
    wy = (ys - y0.astype(np.float32))[:, None, None]
    wx = (xs - x0.astype(np.float32))[None, :, None]
    img = img.astype(np.float32)
    top = img[y0][:, x0] * (1 - wx) + img[y0][:, x1] * wx
    bot = img[y1][:, x0] * (1 - wx) + img[y1][:, x1] * wx
    return top * (1 - wy) + bot * wy


def preprocess_image(payload: bytes, out_h: int = 224, out_w: int = 224) -> np.ndarray:
    """decode -> convert dtype -> resize: the full mapped function."""
    img = decode_image(payload)
    img = convert_image_dtype(img)
    return resize_image(img, out_h, out_w)


# ---------------------------------------------------------------------------
# Dataset writers (one image per file, like ImageNet/Caltech-101 on disk)
# ---------------------------------------------------------------------------
def write_image_dataset(
    storage,
    n_images: int,
    *,
    mean_hw: Tuple[int, int] = (64, 64),
    channels: int = 3,
    n_classes: int = 101,
    seed: int = 0,
    prefix: str = "img",
) -> Tuple[List[str], List[int]]:
    """Write ``n_images`` single-image RRF1 files into ``storage``.

    Image sizes are jittered around ``mean_hw`` to mimic a real photo corpus
    (the paper's ImageNet subset has median 112 KB; Caltech-101 median 12 KB —
    choose ``mean_hw`` accordingly).  Returns (paths, labels).
    """
    rng = np.random.default_rng(seed)
    paths, labels = [], []
    for i in range(n_images):
        h = max(8, int(rng.normal(mean_hw[0], mean_hw[0] * 0.2)))
        w = max(8, int(rng.normal(mean_hw[1], mean_hw[1] * 0.2)))
        img = rng.integers(0, 256, size=(h, w, channels), dtype=np.uint8)
        blob = encode_record(encode_image(img))
        path = f"{prefix}_{i:06d}.rrf"
        storage.write_file(path, blob)
        paths.append(path)
        labels.append(int(rng.integers(0, n_classes)))
    return paths, labels


def write_token_dataset(
    storage,
    n_shards: int,
    docs_per_shard: int,
    seq_len: int,
    vocab_size: int,
    *,
    seed: int = 0,
    prefix: str = "tokens",
) -> List[str]:
    """Write shards of token sequences (LM training corpus analogue).

    Each shard file is a sequence of RRF1 records, one record per document,
    payload = int32 token ids.
    """
    rng = np.random.default_rng(seed)
    paths = []
    for s in range(n_shards):
        parts = []
        for _ in range(docs_per_shard):
            toks = rng.integers(0, vocab_size, size=(seq_len,), dtype=np.int32)
            parts.append(encode_record(toks.tobytes()))
        path = f"{prefix}_{s:05d}.rrf"
        storage.write_file(path, b"".join(parts))
        paths.append(path)
    return paths


def decode_token_shard(blob: bytes, seq_len: int) -> np.ndarray:
    docs = [np.frombuffer(p, dtype=np.int32) for p in decode_records(blob)]
    return np.stack([d[:seq_len] for d in docs])
