"""Transient-fault retry for storage I/O (paper §III-C: user-level retry).

The TensorFlow system paper's fault-tolerance story is user-level
checkpointing *plus retry* — transient storage errors (a flaky NFS mount, a
Lustre OST failing over, an object store returning 5xx) must be absorbed at
the I/O layer, not surfaced to kill a multi-day run.  This module is that
layer:

* :class:`RetryPolicy` — bounded exponential backoff with **full jitter**
  (delay drawn uniformly from ``[0, min(max_delay, base * 2**attempt)]``,
  the AWS-style variant that avoids retry synchronization across threads),
  a per-op wall-clock ``deadline_s``, and a retryable-error classifier.
  Defaults: 5 attempts, 10 ms base, 1 s cap, 30 s deadline.
* :class:`RetryingStorage` — a transparent :class:`Storage` wrapper that
  applies the policy to every data op (reads, writes, fsync).  Because
  every pipeline stage and checkpointer talks to plain ``Storage``,
  wrapping once makes ``Dataset``/``ReaderPool``/``interleave`` reads and
  checkpoint stage/drain writes retry transparently — no call-site changes.

Classification: an error is retried iff the classifier says so.  The
default retries :class:`OSError`/:class:`TimeoutError` (which covers
:class:`repro.core.faults.FaultInjected`) but never the *semantic* OSErrors
— ``FileNotFoundError``, ``PermissionError``, ``IsADirectoryError``,
``NotADirectoryError`` — retrying those just burns the deadline.

Give-up semantics: when the budget (attempts or deadline) is exhausted the
**original** exception is re-raised, so downstream semantics are unchanged
— ``ignore_errors`` still sees the same error type and drops the element,
and ``interleave`` quarantines the shard (``pipeline.quarantined_shards``)
only at that point.  Observability: every retry increments
``storage.retries`` and every exhausted budget ``storage.gave_up`` (live
metrics, plus plain ``.retries``/``.gave_up`` attribute counters).

Idempotency note: faults modelled by :class:`FaultyStorage` fire *before*
bytes move, so retrying any op is safe.  On real storage, ``write_file`` /
``write_range`` / reads are idempotent by construction; ``append_file`` is
only safe to retry when the failed attempt did not land bytes — backends
where a failed append may have partially applied should disable write
retries (``retry_writes=False``).
"""
from __future__ import annotations

import random
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional

from .. import metrics
from .storage import Storage

# `RetryingStorage.give_up_log` keeps only this many most-recent entries
# (the exact total lives in the `gave_up` counter / `storage.gave_up` metric).
GIVE_UP_LOG_LIMIT = 100

#: OSError subclasses that signal a semantic problem, not a flaky device.
_NON_RETRYABLE = (FileNotFoundError, PermissionError, IsADirectoryError,
                  NotADirectoryError)


def default_classifier(exc: BaseException) -> bool:
    """Retry I/O-flavoured errors; never semantic or programming errors."""
    if isinstance(exc, _NON_RETRYABLE):
        return False
    return isinstance(exc, (OSError, TimeoutError))


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff + full jitter + per-op deadline.

    ``max_attempts`` counts *total* tries (1 = no retry).  ``deadline_s``
    caps the wall clock spent on one logical op including backoff sleeps;
    ``None`` disables it.  ``retryable`` classifies which exceptions are
    worth another try.  ``sleep`` performs the backoff wait — inject
    :meth:`SimulatedStorage.paced_sleep` to put retry pacing on the same
    scaled clock as the simulated device (fig13 reproduces the faulty-path
    latency tax exactly at any ``time_scale``), or a recording stub in
    tests.
    """

    max_attempts: int = 5
    base_delay_s: float = 0.01
    max_delay_s: float = 1.0
    deadline_s: Optional[float] = 30.0
    retryable: Callable[[BaseException], bool] = field(
        default=default_classifier)
    sleep: Callable[[float], None] = field(default=time.sleep)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")

    def backoff_s(self, retry_index: int, rng: random.Random) -> float:
        """Full-jitter delay before retry ``retry_index`` (0-based)."""
        cap = min(self.max_delay_s,
                  self.base_delay_s * (2.0 ** retry_index))
        return rng.uniform(0.0, max(0.0, cap))


def retry_call(policy: RetryPolicy, fn: Callable, *args,
               op: str = "op", rng: Optional[random.Random] = None,
               on_retry: Optional[Callable[[int, BaseException], None]] = None,
               on_give_up: Optional[Callable[[BaseException], None]] = None,
               **kwargs):
    """Run ``fn(*args, **kwargs)`` under ``policy``.

    Re-raises the *original* exception on a non-retryable error or an
    exhausted budget (attempts or deadline) — callers never see a wrapper
    type, so existing error handling keeps working.
    """
    rng = rng if rng is not None else random
    deadline = (None if policy.deadline_s is None
                else time.monotonic() + policy.deadline_s)
    attempt = 0
    while True:
        try:
            return fn(*args, **kwargs)
        except BaseException as e:
            if not policy.retryable(e):
                raise
            attempt += 1
            exhausted = attempt >= policy.max_attempts or (
                deadline is not None and time.monotonic() >= deadline)
            if exhausted:
                if on_give_up is not None:
                    on_give_up(e)
                metrics.inc("storage.gave_up", 1, op=op)
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            metrics.inc("storage.retries", 1, op=op)
            delay = policy.backoff_s(attempt - 1, rng)
            if deadline is not None:
                delay = min(delay, max(0.0, deadline - time.monotonic()))
            if delay > 0:
                policy.sleep(delay)


class RetryingStorage(Storage):
    """Transparent :class:`Storage` wrapper applying a :class:`RetryPolicy`.

    Data ops (``read_file``/``read_range``/``write_file``/``append_file``/
    ``write_range``/``fsync_dir``) are retried; namespace ops (``listdir``,
    ``exists``, ``rename``, ...) pass straight through — they are metadata,
    and the commit protocol's rename must stay single-shot atomic.
    """

    def __init__(self, inner: Storage, policy: Optional[RetryPolicy] = None,
                 *, retry_writes: bool = True, seed: Optional[int] = None):
        self.inner = inner
        self.policy = policy if policy is not None else RetryPolicy()
        self.retry_writes = retry_writes
        self.name = f"retry({getattr(inner, 'name', '?')})"
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self.retries = 0    # attribute mirrors of the live counters, for
        self.gave_up = 0    # tests/benchmarks with metrics disabled
        # ring of the most recent give-ups: long soak runs against a flaky
        # tier must not grow memory unboundedly; ``gave_up`` stays exact
        self.give_up_log: Deque[tuple] = deque(
            maxlen=GIVE_UP_LOG_LIMIT)  # (op, repr(exc)) per give-up

    def _call(self, op: str, fn: Callable, *args, **kwargs):
        def _note_retry(_attempt: int, _exc: BaseException) -> None:
            with self._lock:
                self.retries += 1

        def _note_give_up(exc: BaseException) -> None:
            with self._lock:
                self.gave_up += 1
                self.give_up_log.append((op, repr(exc)))

        return retry_call(self.policy, fn, *args, op=op, rng=self._rng,
                          on_retry=_note_retry, on_give_up=_note_give_up,
                          **kwargs)

    # -- retried data ops ------------------------------------------------------
    def read_file(self, path: str) -> bytes:
        return self._call("read_file", self.inner.read_file, path)

    def read_range(self, path: str, offset: int, length: int) -> bytes:
        return self._call("read_range", self.inner.read_range,
                          path, offset, length)

    def write_file(self, path: str, data: bytes, sync: bool = False) -> None:
        if not self.retry_writes:
            return self.inner.write_file(path, data, sync=sync)
        return self._call("write_file", self.inner.write_file,
                          path, data, sync=sync)

    def append_file(self, path: str, data: bytes, sync: bool = False) -> None:
        if not self.retry_writes:
            return self.inner.append_file(path, data, sync=sync)
        return self._call("append_file", self.inner.append_file,
                          path, data, sync=sync)

    def write_range(self, path: str, offset: int, data: bytes,
                    sync: bool = False) -> None:
        if not self.retry_writes:
            return self.inner.write_range(path, offset, data, sync=sync)
        return self._call("write_range", self.inner.write_range,
                          path, offset, data, sync=sync)

    def fsync_dir(self, path: str) -> None:
        return self._call("fsync_dir", self.inner.fsync_dir, path)

    # -- passthrough namespace -------------------------------------------------
    def listdir(self, path: str) -> List[str]:
        return self.inner.listdir(path)

    def exists(self, path: str) -> bool:
        return self.inner.exists(path)

    def makedirs(self, path: str) -> None:
        self.inner.makedirs(path)

    def remove(self, path: str) -> None:
        self.inner.remove(path)

    def rename(self, src: str, dst: str) -> None:
        self.inner.rename(src, dst)

    def size(self, path: str) -> int:
        return self.inner.size(path)

    def drop_caches(self) -> None:
        self.inner.drop_caches()
