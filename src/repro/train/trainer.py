"""Training loop: input pipeline + checkpointing + fault tolerance.

Integrates the paper's pieces end-to-end:

* data comes through the :mod:`repro.core.dataset` pipeline (parallel map +
  prefetch) and optionally :func:`prefetch_to_device`;
* checkpoints go through a Direct-, BurstBuffer-, Async- or
  AsyncBurstBuffer-checkpointer every ``ckpt_every`` steps (the paper's
  protocol: §IV-C).  With an async engine
  (:class:`repro.core.async_checkpoint.AsyncCheckpointer` or
  :class:`repro.core.async_burst_buffer.AsyncBurstBufferCheckpointer`),
  ``save()`` returns a future-like handle and the step loop never blocks
  past the host snapshot; the trainer tracks in-flight handles, re-raises
  background write failures at the next step boundary and at ``run()``
  exit, and blocks on the final preemption save so the checkpoint is
  durable (fast-tier committed, for the async burst buffer) before
  stopping.  A save still in flight when ``run()`` returns stays pending —
  call :meth:`Trainer.wait_for_checkpoints` to drain it and surface any
  error (the same contract as ``BurstBufferCheckpointer.wait``);
* **restart**: on construction the trainer restores the newest checkpoint
  if one exists (crash/preemption recovery);
* **preemption**: SIGTERM (or :meth:`Trainer.preempt`) triggers
  checkpoint-and-stop at the next step boundary; with a
  ``preempt_deadline_s`` budget and an engine that supports
  ``preempt()``, older queued snapshots are abandoned and the final save
  is promoted to its durability tier within the deadline — the outcome
  lands in :attr:`Trainer.preemption_report`;
* **straggler monitor**: per-step data-wait vs compute-time is recorded
  (paper Fig. 6: when prefetch works, data-wait ≈ 0); a sustained data-wait
  fraction above ``straggler_threshold`` is surfaced in ``report()``.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax
import numpy as np

from .. import metrics as live_metrics
from .. import trace
from ..core.stats import StepTimer


class Trainer:
    def __init__(
        self,
        train_step: Callable,                  # (state, batch) -> (state, metrics)
        state: Dict[str, Any],
        data_iter: Iterable,
        *,
        checkpointer=None,                     # Direct/BurstBuffer checkpointer
        ckpt_every: int = 0,
        resume: bool = True,
        preempt_deadline_s: Optional[float] = None,
        straggler_threshold: float = 0.2,
        install_sigterm: bool = False,
        on_step: Optional[Callable[[int, Dict], None]] = None,
        stall_detector=None,                   # repro.metrics.StallDetector
    ):
        self.train_step = train_step
        self.state = state
        self.data_iter = iter(data_iter)
        self.checkpointer = checkpointer
        self.ckpt_every = ckpt_every
        self.timer = StepTimer()
        self.straggler_threshold = straggler_threshold
        self.on_step = on_step
        self.stall_detector = stall_detector
        self.history: List[Dict] = []
        self._stop_requested = False
        self._preempt_deadline_s = preempt_deadline_s
        self._pending_saves: List[Any] = []  # AsyncSaveHandle-like objects
        self.recovered_step: Optional[int] = None
        self.preemption_report = None        # PreemptionReport after a stop
        self.preempt_s: Optional[float] = None  # stop-path wall time
        if install_sigterm:
            signal.signal(signal.SIGTERM, self._handle_sigterm)
        if resume and checkpointer is not None:
            if hasattr(checkpointer, "resume"):
                # CheckpointManager path: params AND input-pipeline position
                # (walks back past corrupt checkpoints; repositions a
                # ResumableIterator so no sample is skipped or replayed)
                res = checkpointer.resume(self.state, data_iter=self.data_iter)
                self.state = res.state
                self.recovered_step = res.step
            else:
                latest = checkpointer.latest_step()
                if latest is not None:
                    self.state = checkpointer.restore_pytree(self.state)
                    self.recovered_step = latest
                    # step counter lives in the state itself

    def _handle_sigterm(self, signum, frame):  # pragma: no cover
        self._stop_requested = True

    def request_stop(self) -> None:
        """Graceful-preemption hook (same path as SIGTERM)."""
        self._stop_requested = True

    def preempt(self, deadline_s: Optional[float] = None) -> None:
        """Graceful preemption with a shutdown budget: stop at the next
        step boundary, issue the final save, and give the checkpointer
        ``deadline_s`` seconds (overriding the constructor default) to
        promote the newest in-flight save to its durability tier —
        abandoning older ones.  The outcome lands in
        :attr:`preemption_report`."""
        if deadline_s is not None:
            self._preempt_deadline_s = deadline_s
        self._stop_requested = True

    @property
    def step(self) -> int:
        return int(jax.device_get(self.state["step"]))

    def run(self, n_steps: int) -> List[Dict]:
        for _ in range(n_steps):
            t0 = time.monotonic()
            with trace.span(trace.STAGE_DATA_WAIT, "next_batch"):
                try:
                    batch = next(self.data_iter)
                except StopIteration:
                    break
            t1 = time.monotonic()
            with trace.span(trace.STAGE_COMPUTE, "train_step"):
                self.state, metrics = self.train_step(self.state, batch)
                metrics = {k: float(jax.device_get(v)) for k, v in metrics.items()}
            t2 = time.monotonic()
            self.timer.data_wait_s.append(t1 - t0)
            self.timer.compute_s.append(t2 - t1)
            step = self.step
            metrics["step"] = step
            self.history.append(metrics)
            # live heartbeat: the paper's Fig. 6 observable, per step
            if live_metrics.enabled():
                live_metrics.inc("trainer.steps")
                live_metrics.observe("trainer.data_wait_s", t1 - t0)
                live_metrics.observe("trainer.compute_s", t2 - t1)
                live_metrics.set_gauge("trainer.step_s", t2 - t0)
                live_metrics.set_gauge("trainer.last_step", step)
            if self.stall_detector is not None:
                self.stall_detector.observe(step, t2 - t0)
            if self.on_step:
                self.on_step(step, metrics)

            if self.checkpointer is not None and self.ckpt_every and (
                step % self.ckpt_every == 0
            ):
                self._save_checkpoint(step)

            if self._stop_requested:
                if self.checkpointer is not None:
                    t_pre = time.monotonic()
                    handle = self._save_checkpoint(step)
                    preempt = getattr(self.checkpointer, "preempt", None)
                    if callable(preempt):
                        # graceful-shutdown budget: promote the newest
                        # in-flight save (this one) within the deadline,
                        # abandon older queued snapshots
                        self.preemption_report = preempt(
                            self._preempt_deadline_s)
                    elif handle is not None:
                        # preemption save must be durable before we stop
                        handle.result()
                    self.preempt_s = time.monotonic() - t_pre
                break
        # surface any background write failure that settled during the run
        # (in-flight saves stay pending: wait_for_checkpoints() drains them)
        self._reap_saves()
        return self.history

    # -- checkpointing --------------------------------------------------------
    def _save_checkpoint(self, step: int):
        """Save; returns the async handle if the checkpointer is async.

        Only the blocking portion (full save for a synchronous
        checkpointer, host snapshot for an async one) lands in
        ``timer.checkpoint_s`` — the trainer's view of training-thread
        blocked time."""
        self._reap_saves()
        t3 = time.monotonic()
        extra = None
        state_fn = getattr(self.data_iter, "state", None)
        if callable(state_fn):
            # iterator checkpoint rides along in the meta (tf.data-style),
            # captured on the training thread so it is consistent with the
            # params being saved even under an async engine
            extra = {"pipeline": state_fn()}
        result = self.checkpointer.save(step, self.state, extra_meta=extra)
        self.timer.checkpoint_s.append(time.monotonic() - t3)
        if hasattr(result, "done") and hasattr(result, "exception"):
            self._pending_saves.append(result)
            return result
        return None

    def _reap_saves(self) -> None:
        """Drop completed async saves; re-raise the first background error
        (a checkpoint that can never land must not fail silently)."""
        still = []
        error = None
        for h in self._pending_saves:
            if h.done():
                if getattr(h, "cancelled", lambda: False)():
                    continue  # abandoned by preempt(): no error to report
                e = h.exception()
                if e is not None and error is None:
                    error = e
            else:
                still.append(h)
        self._pending_saves = still
        if error is not None:
            raise error

    def wait_for_checkpoints(self) -> None:
        """Drain all outstanding checkpoint work (async writes, burst-buffer
        drains); surfaces any background error."""
        if self.checkpointer is not None and hasattr(self.checkpointer, "wait"):
            self.checkpointer.wait()
        self._pending_saves = []

    def close(self) -> None:
        """Release the input pipeline: closes the data iterator end-to-end
        (prefetcher threads, in-flight reader-pool work) when it supports it.
        Training that abandons a ``repeat()`` pipeline mid-epoch must call
        this (or rely on GC) to stop the background producer promptly."""
        close = getattr(self.data_iter, "close", None)
        if close is not None:
            close()

    # -- diagnostics ---------------------------------------------------------
    def report(self) -> Dict[str, Any]:
        s = self.timer.summary()
        compute = max(s["compute"]["total"], 1e-9)
        data_frac = s["data_wait"]["total"] / (s["data_wait"]["total"] + compute)
        return dict(
            steps=len(self.timer.compute_s),
            recovered_step=self.recovered_step,
            data_wait_frac=data_frac,
            straggler_suspect=data_frac > self.straggler_threshold,
            timer=s,
            blocked_ckpt_s=(
                list(self.checkpointer.blocked_s)
                if self.checkpointer is not None and
                hasattr(self.checkpointer, "blocked_s") else []
            ),
            pending_async_saves=sum(
                1 for h in self._pending_saves if not h.done()
            ),
            preemption=(
                dict(
                    committed_step=self.preemption_report.committed_step,
                    abandoned_steps=list(
                        self.preemption_report.abandoned_steps),
                    deadline_s=self.preemption_report.deadline_s,
                    elapsed_s=self.preemption_report.elapsed_s,
                    deadline_met=self.preemption_report.deadline_met,
                    preempt_s=self.preempt_s,
                ) if self.preemption_report is not None else None
            ),
            stalls=(self.stall_detector.summary()
                    if self.stall_detector is not None else None),
        )
