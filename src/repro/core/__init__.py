"""repro.core — the paper's contribution: DL I/O as a first-class subsystem.

* :mod:`repro.core.dataset` — tf.data-like input pipeline (shuffle / shard /
  parallel map / interleave / fused map_and_batch / batch / prefetch /
  cache / ignore_errors), with closeable iterators end-to-end.
* :mod:`repro.core.readerpool` — the shared, lazily-sized reader thread
  pool every parallel pipeline stage schedules onto (grown once, reused
  across epochs and stages).
* :mod:`repro.core.prefetcher` — background-thread prefetcher + device
  double-buffering.
* :mod:`repro.core.records` — record container + image payloads + decode.
* :mod:`repro.core.storage` — storage tiers (native + Table-I-calibrated
  simulator: hdd / ssd / optane / lustre).
* :mod:`repro.core.checkpoint` — sharded TF-Saver-like checkpointing with
  parallel shard I/O (``io_threads``).
* :mod:`repro.core.async_checkpoint` — async snapshot checkpointing:
  training blocks for the host snapshot only; a background writer does the
  sharded save; ``save()`` returns a future-like handle.
* :mod:`repro.core.burst_buffer` — fast-tier staging + multi-stream async
  drain (the 2.6x), with intra-file parallel range drains
  (``Storage.write_range``).
* :mod:`repro.core.async_burst_buffer` — the fused engine: snapshot-only
  blocking, background fast-tier stage, then the multi-stream drain —
  training never blocks past the host snapshot.
* :mod:`repro.core.faults` — :class:`FaultyStorage` fault injection
  (sticky failures, torn writes, reordered fsync + crash, and non-sticky
  transients), the crash-consistency proof harness for all of the above.
* :mod:`repro.core.retry` — :class:`RetryPolicy` (exponential backoff +
  full jitter + deadline, injectable ``sleep``) and the transparent
  :class:`RetryingStorage` wrapper that absorbs transient storage faults
  below every pipeline and checkpoint path.
* :mod:`repro.core.cache` — tiered block read-cache: :class:`BlockCache`
  (byte-budget LRU + single-flight dedup + optional fast-tier spill),
  the transparent :class:`CachingStorage` wrapper, and the
  :class:`ReadaheadScheduler` that prefetches upcoming shards' blocks
  ahead of the interleave cursor.
* :mod:`repro.core.recovery` — :class:`CheckpointManager`: retention
  (keep-last-k + keep-every-n), corruption-aware ``latest_valid()``
  restore, crash-safe GC, and TrainState-level ``resume()`` that also
  re-positions a :class:`~repro.core.dataset.ResumableIterator`.
* :mod:`repro.core.microbench` — STREAM-like ingestion benchmark.
* :mod:`repro.core.stats` — dstat-like I/O timeline view, an adapter over
  the :mod:`repro.trace` collector.

Telemetry: every I/O layer here (storage reads/writes, per-element
map/decode, prefetch fetches, checkpoint save/restore, burst-buffer
drains) emits stage-attributed spans through :mod:`repro.trace` — the
tf-Darshan-style subsystem.  Tracing is off by default; call
``repro.trace.start()`` to collect, then export with
``repro.trace.dump_chrome_trace`` (Perfetto) or summarize with
``repro.trace.to_markdown``.
"""
from .cache import BlockCache, CachingStorage, ReadaheadScheduler
from .dataset import (Dataset, ResumableIterator, ShardQuarantine,
                      image_pipeline, interleave_order,
                      sharded_image_pipeline, sharded_record_dataset)
from .prefetcher import PrefetchIterator, prefetch_to_device
from .readerpool import ReaderPool, reader_pool
from .storage import Storage, NativeStorage, SimulatedStorage, TIERS, make_storage
from .checkpoint import CheckpointSaver, PreemptionReport
from .async_checkpoint import AsyncCheckpointer, AsyncSaveHandle
from .async_burst_buffer import AsyncBurstBufferCheckpointer
from .burst_buffer import (BurstBufferCheckpointer, DirectCheckpointer,
                           DrainStallError)
from .faults import FaultInjected, FaultyStorage, TransientFault
from .retry import RetryPolicy, RetryingStorage
from .recovery import CheckpointManager, ResumeResult, latest_valid_step, \
    validate_step
from .stats import IOTracer, StepTimer

__all__ = [
    "Dataset", "ResumableIterator", "ShardQuarantine", "image_pipeline",
    "interleave_order", "sharded_image_pipeline", "sharded_record_dataset",
    "BlockCache", "CachingStorage", "ReadaheadScheduler",
    "PrefetchIterator", "prefetch_to_device", "ReaderPool", "reader_pool",
    "Storage", "NativeStorage", "SimulatedStorage", "TIERS", "make_storage",
    "CheckpointSaver", "PreemptionReport", "AsyncCheckpointer",
    "AsyncSaveHandle", "AsyncBurstBufferCheckpointer",
    "BurstBufferCheckpointer", "DirectCheckpointer", "DrainStallError",
    "FaultInjected", "FaultyStorage", "TransientFault",
    "RetryPolicy", "RetryingStorage",
    "CheckpointManager", "ResumeResult", "latest_valid_step", "validate_step",
    "IOTracer", "StepTimer",
]
