"""Config system: model configs, input shapes, and the 40-cell matrix."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """One architecture.  All sizes are the exact public configs; the only
    framework-added field is ``padded_vocab`` (vocab rounded up to 256 so the
    embedding table shards evenly — standard practice, noted in DESIGN.md)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # attention variants
    rope_theta: float = 1e4
    qk_norm: bool = False
    window: Optional[int] = None       # sliding-window size (SWA)
    local_global_period: int = 0       # gemma3: every k-th layer is global
    mrope: bool = False                # qwen2-vl M-RoPE (3-section rotary)
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_chunk: int = 256
    moe_period: int = 1                # MoE every k-th layer (jamba: 2), dense MLP otherwise
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    attn_period: int = 0               # jamba: 1 attn per ``attn_period`` layers
    # enc-dec
    enc_layers: int = 0
    # modality stub: inputs are precomputed frame/patch embeddings
    modality_stub: bool = False
    modality_seq: int = 0              # stub frontend output length (encoder side)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    # -- head padding for clean 16-way TP --------------------------------
    # Archs with 28/40/56/8 q-heads can't shard heads over a 16-wide model
    # axis; replicated attention probabilities cost GiBs per device in the
    # backward pass.  Standard practice: pad head counts to the next clean
    # multiple (zero-init extra heads).  The *public* n_heads/n_kv_heads
    # stay authoritative for MODEL_FLOPS; padded_* are the tensor shapes.
    @property
    def padded_heads(self) -> int:
        H, Hkv = self.n_heads, self.n_kv_heads
        if H == 0:
            return 0
        if H % 16 == 0 and H % Hkv == 0:
            return H
        Hp = ((H + 15) // 16) * 16
        while Hp % self.padded_kv_heads != 0:
            Hp += 16
        return Hp

    @property
    def padded_kv_heads(self) -> int:
        H, Hkv = self.n_heads, self.n_kv_heads
        if H == 0 or (H % 16 == 0 and H % Hkv == 0):
            return Hkv
        Hp = ((H + 15) // 16) * 16
        # smallest kv-head count >= Hkv that divides the padded q heads
        for cand in range(Hkv, Hp + 1):
            if Hp % cand == 0:
                return cand
        return Hp

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token long-context decode shape?
        True for SSM/hybrid and windowed-attention archs (per assignment)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.window is not None
            or self.local_global_period > 0
        )

    @property
    def has_decoder(self) -> bool:
        return True  # all 10 assigned archs decode (enc-dec included)

    def param_count(self) -> int:
        """Total parameter count N (analytic)."""
        V, D = self.padded_vocab, self.d_model
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += V * D
        def attn_params() -> int:
            H, Hkv, hd = self.n_heads, self.n_kv_heads, self.head_dim
            return D * H * hd + 2 * D * Hkv * hd + H * hd * D
        def mlp_params() -> int:
            return 3 * D * self.d_ff  # SwiGLU: gate, up, down
        def moe_params() -> int:
            return D * self.n_experts + self.n_experts * 3 * D * self.d_ff

        def ffn_params_for_layer(i: int) -> int:
            if self.is_moe and (i % self.moe_period == self.moe_period - 1):
                return moe_params()
            return mlp_params()
        def mamba_params() -> int:
            din, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            in_p = D * (2 * din + 2 * N + Hs)
            conv = self.ssm_conv_width * (din + 2 * N)
            out_p = din * D + din  # out proj + gated norm
            return in_p + conv + out_p + 3 * Hs
        if self.family == "ssm":
            n += self.n_layers * (mamba_params() + 2 * D)
        elif self.family == "hybrid":
            n_attn = self.n_layers // self.attn_period
            n_mamba = self.n_layers - n_attn
            n += n_attn * (attn_params() + 2 * D)
            n += n_mamba * (mamba_params() + 2 * D)
            n += sum(ffn_params_for_layer(i) for i in range(self.n_layers))
        elif self.family == "encdec":
            # encoder self-attn+mlp, decoder self+cross+mlp
            n += self.enc_layers * (attn_params() + mlp_params() + 2 * D)
            n += self.n_layers * (2 * attn_params() + mlp_params() + 3 * D)
        else:
            n += self.n_layers * (attn_params() + 2 * D)
            n += sum(ffn_params_for_layer(i) for i in range(self.n_layers))
        return n

    def active_param_count(self) -> int:
        """N_active: params touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        D = self.d_model
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if i % self.moe_period == self.moe_period - 1
        )
        dense_moe = n_moe_layers * self.n_experts * 3 * D * self.d_ff
        active_moe = n_moe_layers * self.experts_per_token * 3 * D * self.d_ff
        return self.param_count() - dense_moe + active_moe

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw: Dict = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
        )
        if self.n_heads:
            kw.update(n_heads=4, n_kv_heads=2, head_dim=16)
        if self.is_moe:
            # capacity_factor = E/k makes the tiny smoke configs drop-free,
            # so prefill+decode match the teacher-forced forward exactly
            kw.update(n_experts=4, experts_per_token=2, moe_chunk=16,
                      capacity_factor=2.0)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=16)
        if self.family == "hybrid":
            kw.update(n_layers=max(2, 2 * self.attn_period) if self.attn_period else 2,
                      attn_period=self.attn_period or 2)
        if self.enc_layers:
            kw.update(enc_layers=2)
        if self.local_global_period:
            kw.update(local_global_period=self.local_global_period,
                      window=min(self.window or 16, 16))
        elif self.window is not None:
            kw.update(window=16)
        if self.modality_stub:
            kw.update(modality_seq=min(self.modality_seq or 16, 16))
        return replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def runnable_cells(cfg: ModelConfig) -> List[str]:
    """Which of the 4 assigned shapes run for this arch (skip rules per
    DESIGN.md §4)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells
