"""tf.data-like pipeline semantics (paper §II-A)."""
import threading
import time

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.dataset import Dataset


class TestBasics:
    def test_from_tensor_slices_order(self):
        assert list(Dataset.from_tensor_slices([3, 1, 2])) == [3, 1, 2]

    def test_take_repeat(self):
        assert list(Dataset.range(3).repeat(2)) == [0, 1, 2, 0, 1, 2]
        assert list(Dataset.range(10).take(4)) == [0, 1, 2, 3]

    def test_batch_shapes(self):
        batches = list(Dataset.range(10).batch(3))
        assert [b.shape for b in batches] == [(3,), (3,), (3,)]  # drop remainder
        batches = list(Dataset.range(10).batch(3, drop_remainder=False))
        assert batches[-1].shape == (1,)

    def test_batch_pytree(self):
        ds = Dataset.from_tensor_slices(
            [(np.ones(2) * i, np.int32(i)) for i in range(4)]
        ).batch(2)
        imgs, labels = next(iter(ds))
        assert imgs.shape == (2, 2) and labels.shape == (2,)


class TestShuffle:
    @given(st.integers(0, 2**31 - 1), st.integers(1, 64))
    @settings(max_examples=20, deadline=None)
    def test_shuffle_is_permutation(self, seed, buf):
        items = list(range(100))
        out = list(Dataset.from_tensor_slices(items).shuffle(buf, seed=seed))
        assert sorted(out) == items

    def test_shuffle_deterministic_by_seed(self):
        a = list(Dataset.range(50).shuffle(16, seed=7))
        b = list(Dataset.range(50).shuffle(16, seed=7))
        c = list(Dataset.range(50).shuffle(16, seed=8))
        assert a == b
        assert a != c  # astronomically unlikely to collide

    def test_shuffle_actually_shuffles(self):
        out = list(Dataset.range(100).shuffle(100, seed=0))
        assert out != list(range(100))


class TestMap:
    def test_map_serial(self):
        assert list(Dataset.range(4).map(lambda x: x * 2)) == [0, 2, 4, 6]

    @pytest.mark.parametrize("threads", [2, 4])
    def test_map_parallel_deterministic_order(self, threads):
        out = list(Dataset.range(20).map(
            lambda x: x * 10, num_parallel_calls=threads))
        assert out == [x * 10 for x in range(20)]

    def test_map_parallel_completion_order_is_complete(self):
        def slow_even(x):
            time.sleep(0.02 if x % 2 == 0 else 0.0)
            return x

        out = list(Dataset.range(16).map(
            slow_even, num_parallel_calls=4, deterministic=False))
        assert sorted(out) == list(range(16))

    def test_map_parallel_uses_threads(self):
        """8 sleeps of 50ms on 8 threads must take far less than 400ms."""
        def slow(x):
            time.sleep(0.05)
            return x

        t0 = time.monotonic()
        out = list(Dataset.range(8).map(slow, num_parallel_calls=8))
        elapsed = time.monotonic() - t0
        assert sorted(out) == list(range(8))
        assert elapsed < 0.25, f"no thread overlap: {elapsed:.3f}s"


class TestErrorHandling:
    def test_ignore_errors_drops_bad(self):
        def maybe_fail(x):
            if x % 3 == 0:
                raise ValueError("boom")
            return x

        out = list(Dataset.range(10).map(maybe_fail).ignore_errors())
        assert out == [x for x in range(10) if x % 3 != 0]

    def test_error_propagates_without_ignore(self):
        def fail(x):
            raise RuntimeError("boom")

        with pytest.raises(RuntimeError):
            list(Dataset.range(3).map(fail))


class TestCachePrefetch:
    def test_cache_second_epoch_no_recompute(self):
        calls = []

        def f(x):
            calls.append(x)
            return x

        ds = Dataset.range(5).map(f).cache()
        assert list(ds) == list(range(5))
        assert list(ds) == list(range(5))
        assert len(calls) == 5  # second epoch served from memory

    def test_prefetch_preserves_stream(self):
        out = list(Dataset.range(100).prefetch(4))
        assert out == list(range(100))

    def test_prefetch_error_propagates(self):
        def fail(x):
            if x == 5:
                raise RuntimeError("boom")
            return x

        with pytest.raises(RuntimeError):
            list(Dataset.range(10).map(fail).prefetch(2))
