"""AsyncBurstBufferCheckpointer: snapshot-only blocking, both tiers land.

Acceptance criteria covered here:

* ``save()`` blocks for the host snapshot only — on the simulated
  optane/hdd pair the training-thread blocked seconds are ≤ 0.5x the plain
  burst buffer's (which pays the full fast-tier write);
* both tiers end up with every checkpoint, bit-identical, and the handle
  settles exactly when the *fast* tier has committed (the step is then
  restorable — the preemption-save contract);
* drain bookkeeping (``_pending``/``_drained``) stays bounded over long
  runs; error reporting is exactly-once across ``wait()``/``close()``;
* trainer integration: the step loop never blocks past the snapshot, and a
  preemption save is fast-tier durable before ``run()`` returns.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.async_burst_buffer import AsyncBurstBufferCheckpointer
from repro.core.async_checkpoint import AsyncSaveHandle
from repro.core.burst_buffer import BurstBufferCheckpointer
from repro.core.checkpoint import CheckpointSaver
from repro.core.faults import FaultInjected, FaultyStorage


def big_tree(mb=2, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(mb * 1024 * 256,)).astype(np.float32)}


class TestAsyncBurstBuffer:
    def test_roundtrip_both_tiers(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        abb = AsyncBurstBufferCheckpointer(fast, slow, "ckpt/m", n_shards=2,
                                           drain_streams=4,
                                           drain_chunk=256 << 10)
        t = big_tree(1)
        h = abb.save(7, t)
        assert isinstance(h, AsyncSaveHandle) and h.step == 7
        r = h.result()   # fast tier committed
        assert r.step == 7 and r.n_bytes > 0
        assert abb.fast_saver.latest_step() == 7  # restorable already
        abb.wait()       # slow tier drained
        for saver in (CheckpointSaver(fast, "ckpt/m"),
                      CheckpointSaver(slow, "ckpt/m")):
            out = saver.restore_pytree(t)
            np.testing.assert_array_equal(out["w"], t["w"])
        out = abb.restore_pytree(t)
        np.testing.assert_array_equal(out["w"], t["w"])
        abb.close()

    def test_blocked_half_of_plain_burst_buffer(self, fast_slow_storage):
        """The tentpole number: bb pays the fast-tier write; asyncbb pays
        the snapshot only."""
        fast, slow = fast_slow_storage
        t = big_tree(8)
        bb = BurstBufferCheckpointer(fast, slow, "bb/m")
        bb.save(1, t)
        bb_blocked = bb.blocked_s[0]
        bb.wait()
        bb.close()

        abb = AsyncBurstBufferCheckpointer(fast, slow, "abb/m")
        h = abb.save(1, t)
        abb_blocked = abb.blocked_s[0]
        h.result()
        abb.wait()
        abb.close()
        assert abb_blocked < bb_blocked * 0.5, (
            f"asyncbb blocked {abb_blocked:.3f}s !< "
            f"bb blocked {bb_blocked:.3f}s * 0.5")

    def test_saves_commit_in_order_on_both_tiers(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        abb = AsyncBurstBufferCheckpointer(fast, slow, "ckpt/m",
                                           max_pending=2)
        t = big_tree(1)
        for s in (10, 20, 30):
            abb.save(s, t)
        abb.wait()
        assert CheckpointSaver(fast, "ckpt/m").latest_step() == 30
        assert CheckpointSaver(slow, "ckpt/m").all_steps() == [10, 20, 30]
        abb.close()

    def test_fast_tier_cleanup_and_bounded_bookkeeping(self,
                                                       fast_slow_storage):
        """Satellite regression: ``_pending``/``_drained`` must not grow
        with the number of saves, and old staged steps are evicted."""
        fast, slow = fast_slow_storage
        abb = AsyncBurstBufferCheckpointer(fast, slow, "ckpt/m", keep=8)
        t = big_tree(1)
        for s in range(1, 7):
            abb.save(s, t)
        abb.wait()
        with abb._pending_lock:
            assert abb._pending == [] and abb._drained == set()
        files = fast.listdir("ckpt")
        assert not any(f.startswith("m-1.data") for f in files)
        assert any(f.startswith("m-6.data") for f in files)
        abb.close()

    def test_backpressure_bounds_inflight_snapshots(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        abb = AsyncBurstBufferCheckpointer(fast, slow, "ckpt/m",
                                           max_pending=1)
        t = big_tree(4)
        abb.save(1, t)          # occupies the single slot while staging
        t0 = time.monotonic()
        abb.save(2, t)          # must wait for save 1 to finish staging
        second_blocked = time.monotonic() - t0
        # the second save's blocked time includes (most of) save 1's stage
        assert second_blocked > abb.blocked_s[0] * 2
        abb.wait()
        abb.close()

    def test_stage_error_reported_once(self, tmp_storage):
        import tempfile

        faulty_fast = FaultyStorage(tmp_storage)
        with tempfile.TemporaryDirectory() as d2:
            from repro.core.storage import NativeStorage

            slow = NativeStorage(d2)
            abb = AsyncBurstBufferCheckpointer(faulty_fast, slow, "ckpt/m")
            t = big_tree(1)
            abb.save(1, t)
            abb.wait()
            faulty_fast.fail_after(0)
            h = abb.save(2, t)
            assert isinstance(h.exception(), FaultInjected)
            with pytest.raises(FaultInjected):
                abb.wait()   # observed via the handle, but wait still owes it
            faulty_fast.heal()
            abb.save(3, t)
            abb.wait()       # stale step-2 error must not resurface
            assert CheckpointSaver(slow, "ckpt/m").latest_step() == 3
            abb.close()      # already-delivered error: close stays quiet

    def test_drain_error_surfaces_through_wait(self, tmp_storage):
        import tempfile

        with tempfile.TemporaryDirectory() as d2:
            from repro.core.storage import NativeStorage

            faulty_slow = FaultyStorage(NativeStorage(d2))
            abb = AsyncBurstBufferCheckpointer(tmp_storage, faulty_slow,
                                               "ckpt/m")
            t = big_tree(1)
            abb.save(1, t)
            abb.wait()
            faulty_slow.fail_after(0)
            h = abb.save(2, t)
            assert h.result().step == 2      # fast tier is fine
            with pytest.raises(FaultInjected):
                abb.wait()                   # the drain died
            faulty_slow.heal()
            # fast tier kept the step even though the slow tier lost it
            assert abb.fast_saver.latest_step() == 2
            assert CheckpointSaver(faulty_slow, "ckpt/m").latest_step() == 1
            abb.close()

    def test_save_after_close_raises(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        abb = AsyncBurstBufferCheckpointer(fast, slow, "ckpt/m")
        abb.close()
        with pytest.raises(RuntimeError):
            abb.save(1, big_tree(1))


class TestTrainerIntegration:
    def _trainer(self, checkpointer):
        from repro.train.trainer import Trainer

        def train_step(st, batch):
            return {**st, "step": st["step"] + 1}, {"loss": 0.0}

        data = iter([np.zeros(2, np.float32)] * 64)
        return Trainer(
            train_step, {"w": np.ones(1024, np.float32), "step": np.int32(0)},
            data, checkpointer=checkpointer, ckpt_every=2, resume=False,
        )

    def test_step_loop_never_blocks_past_snapshot(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        abb = AsyncBurstBufferCheckpointer(fast, slow, "ckpt/m")
        tr = self._trainer(abb)
        tr.run(5)
        assert len(abb.blocked_s) == 2      # saves at steps 2 and 4
        assert all(b < 0.05 for b in tr.timer.checkpoint_s), (
            tr.timer.checkpoint_s)
        tr.wait_for_checkpoints()
        assert tr.report()["pending_async_saves"] == 0
        assert CheckpointSaver(slow, "ckpt/m").latest_step() == 4
        abb.close()

    def test_preemption_save_fast_tier_durable(self, fast_slow_storage):
        fast, slow = fast_slow_storage
        abb = AsyncBurstBufferCheckpointer(fast, slow, "ckpt/m")
        tr = self._trainer(abb)
        tr.run(2)
        tr.request_stop()
        tr.run(3)   # stops at the boundary, blocking on the final save
        # handle.result() settles on fast-tier commit: restorable now
        assert abb.fast_saver.latest_step() == tr.step
        tr.wait_for_checkpoints()
        assert CheckpointSaver(slow, "ckpt/m").latest_step() == tr.step
        abb.close()
