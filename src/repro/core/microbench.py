"""STREAM-like TensorFlow-I/O micro-benchmark (paper §III-A, Fig. 4/5).

Measures raw ingestion bandwidth of the input pipeline: read files from a
storage tier, optionally decode+resize, batch, and pull batches through the
iterator as fast as possible (no compute phase).  Reports images/s and MB/s
as the paper does, under a strong-scaling sweep of reader threads.

Two pipelines are measurable:

* ``run_microbench`` — the per-file pipeline (one single-image ``.rrf`` per
  element), in ``legacy`` (per-element map -> stack) or ``vectorized``
  (fused ``map_and_batch`` + zero-copy decode) form;
* ``run_sharded_microbench`` — the interleaved shard-streaming engine over
  multi-record shards (fig11's fast path).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from . import records
from .dataset import Dataset, image_pipeline, sharded_image_pipeline


@dataclass
class MicrobenchResult:
    storage: str
    threads: int
    preprocess: bool
    n_images: int
    total_bytes: int
    seconds: float

    @property
    def images_per_s(self) -> float:
        return self.n_images / self.seconds

    @property
    def mb_per_s(self) -> float:
        return self.total_bytes / 1e6 / self.seconds

    def row(self) -> str:
        return (
            f"{self.storage},{self.threads},{int(self.preprocess)},"
            f"{self.n_images},{self.images_per_s:.2f},{self.mb_per_s:.2f}"
        )


def _consume(ds, n_batches: Optional[int] = None):
    """Pull batches through the iterator; returns (n_images, seconds)."""
    n_images = 0
    t0 = time.monotonic()
    it = iter(ds)
    try:
        consumed_batches = 0
        for batch in it:
            first = batch[0] if isinstance(batch, tuple) else batch
            n_images += len(first)
            consumed_batches += 1
            if n_batches is not None and consumed_batches >= n_batches:
                break
    finally:
        it.close()
    return n_images, time.monotonic() - t0


def run_microbench(
    storage,
    paths: Sequence[str],
    *,
    threads: int = 1,
    batch_size: int = 64,
    preprocess: bool = True,
    out_hw: tuple = (64, 64),
    seed: int = 0,
    n_batches: Optional[int] = None,
    pipeline: str = "legacy",
) -> MicrobenchResult:
    """One micro-benchmark run: consume the corpus through the per-file
    pipeline.  ``pipeline="vectorized"`` uses the fused map_and_batch path
    (zero-copy decode + LUT resize into the batch buffer)."""
    if pipeline not in ("legacy", "vectorized"):
        raise ValueError(f"unknown pipeline {pipeline!r}")
    sizes = {}

    if pipeline == "vectorized" and preprocess:
        def load_into(path, out):
            blob = storage.read_file(path)  # tf.read_file()
            sizes[path] = len(blob)
            payload = records.decode_single_record(blob, copy=False)
            records.preprocess_image_into(payload, out)
            return None

        ds = (
            Dataset.from_tensor_slices(list(paths))
            .shuffle(len(paths), seed=seed)
            .map_and_batch(load_into, batch_size, num_parallel_calls=threads,
                           out_shape=(*out_hw, 3), ignore_errors=True,
                           drop_remainder=True)
        )
    else:
        def load(path):
            blob = storage.read_file(path)  # tf.read_file()
            sizes[path] = len(blob)
            if not preprocess:
                return np.int64(len(blob))  # read-only pipeline (paper Fig. 5)
            payload = records.decode_single_record(blob)
            return records.preprocess_image(payload, *out_hw)

        ds = (
            Dataset.from_tensor_slices(list(paths))
            .shuffle(len(paths), seed=seed)
            .map(load, num_parallel_calls=threads)
            .ignore_errors()
            .batch(batch_size, drop_remainder=True)
        )

    n_images, seconds = _consume(ds, n_batches)

    return MicrobenchResult(
        storage=getattr(storage, "name", "?"),
        threads=threads,
        preprocess=preprocess,
        n_images=n_images,
        total_bytes=sum(sizes.values()),
        seconds=seconds,
    )


def run_sharded_microbench(
    storage,
    shard_paths: Sequence[str],
    *,
    threads: int = 1,
    batch_size: int = 64,
    preprocess: bool = True,
    out_hw: tuple = (64, 64),
    seed: int = 0,
    block_length: int = 8,
    n_batches: Optional[int] = None,
    cache=None,
    readahead=None,
) -> MicrobenchResult:
    """Ingestion bandwidth of the interleaved shard-streaming engine:
    ``threads`` shards in flight (cycle_length = num_parallel_calls =
    threads), records decoded zero-copy into the fused batch buffer.

    ``cache``/``readahead`` pass through to :func:`sharded_image_pipeline`:
    a :class:`~repro.core.cache.BlockCache` serves repeat epochs warm, and
    readahead prefetches upcoming shards' blocks onto the reader pool."""
    total_bytes = sum(storage.size(p) for p in shard_paths)
    ds = sharded_image_pipeline(
        storage, list(shard_paths), batch_size=batch_size,
        cycle_length=max(threads, 1), block_length=block_length,
        num_parallel_calls=threads, prefetch=0, out_hw=out_hw, seed=seed,
        preprocess=preprocess, cache=cache, readahead=readahead)

    n_images, seconds = _consume(ds, n_batches)

    return MicrobenchResult(
        storage=getattr(storage, "name", "?"),
        threads=threads,
        preprocess=preprocess,
        n_images=n_images,
        total_bytes=total_bytes,
        seconds=seconds,
    )


def thread_scaling_sweep(
    storage,
    paths: Sequence[str],
    *,
    thread_counts: Sequence[int] = (1, 2, 4, 8),
    repeats: int = 3,
    warmup: bool = True,
    bench=None,
    **kw,
) -> List[MicrobenchResult]:
    """Paper's strong-scaling protocol: warm-up run discarded, median kept.

    ``bench`` selects the benchmark body (default :func:`run_microbench`;
    pass :func:`run_sharded_microbench` for the interleaved engine)."""
    fn = bench if bench is not None else run_microbench
    out: List[MicrobenchResult] = []
    for t in thread_counts:
        runs = []
        n = repeats + (1 if warmup else 0)
        for i in range(n):
            r = fn(storage, paths, threads=t, **kw)
            if warmup and i == 0:
                continue
            runs.append(r)
        runs.sort(key=lambda r: r.seconds)
        out.append(runs[len(runs) // 2])
    return out
