"""Fig. 5 analogue: pipeline with ONLY tf.read() (no decode/resize) —
isolates preprocessing cost from raw I/O.  The read-only loader is shared
by both pipeline generations (the vectorized engine only changes decode/
batch), so one sweep covers both.

Writes machine-readable ``BENCH_read_only.json`` (same schema as
``BENCH_threads.json``) for the perf-regression gate.

    PYTHONPATH=src python -m benchmarks.fig5_read_only [--smoke]
"""
from __future__ import annotations

import sys

from . import fig4_threads


def run(**overrides) -> dict:
    kw = dict(preprocess=False, name="fig5_read_only",
              json_name="BENCH_read_only.json")
    kw.update(overrides)
    return fig4_threads.run(**kw)


def run_smoke(**overrides) -> dict:
    kw = dict(preprocess=False, name="fig5_read_only",
              json_name="BENCH_read_only.json")
    kw.update(overrides)
    return fig4_threads.run_smoke(**kw)


if __name__ == "__main__":
    run_smoke() if "--smoke" in sys.argv else run()
