"""Shared model layers (pure-functional JAX).

Conventions:
* params are plain dicts of jnp arrays; layer stacks carry a leading
  ``n_layers`` dim and are consumed by ``lax.scan``.
* activations are bf16 (cfg.dtype); norms/softmax/rope run in fp32.
* every function takes a :class:`repro.sharding.rules.ShardingCtx` (``ctx``)
  whose ``constrain`` is a no-op without a mesh (CPU smoke tests).
* attention is **chunked online-softmax** over KV blocks (lax.scan), so
  logits for 32k/500k sequences are never materialized — the jnp analogue
  of flash attention, and the baseline the Pallas kernel competes with.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

NEG_INF = -1e30


def cast(x: Array, dtype) -> Array:
    return x.astype(dtype) if x.dtype != dtype else x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dtype)


def gated_rms_norm(y: Array, z: Array, weight: Array, eps: float = 1e-6) -> Array:
    """Mamba2's norm: RMSNorm(y * silu(z))."""
    dtype = y.dtype
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    out = yf * lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and qwen2-vl's 3-section M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                       # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(hd: int) -> Tuple[int, int, int]:
    """qwen2-vl uses (16,24,24) on hd/2=64, i.e. (1/4, 3/8, 3/8)."""
    half = hd // 2
    s1 = half // 4
    s2 = (half * 3) // 8
    return (s1, s2, half - s1 - s2)


def apply_mrope(x: Array, positions_thw: Array, theta: float) -> Array:
    """qwen2-vl M-RoPE. positions_thw: (3, ..., S) — temporal/height/width
    position ids (text tokens have t=h=w=index; the vision stub supplies
    patch grids)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # build per-dim angles by section
    sec = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32)
        for i, s in enumerate(mrope_sections(hd))
    ])                                                   # (hd/2,) in {0,1,2}
    pos = jnp.take(positions_thw.astype(jnp.float32), sec, axis=0)  # (hd/2, ..., S)
    pos = jnp.moveaxis(pos, 0, -1)                      # (..., S, hd/2)
    angles = (pos * freqs)[..., None, :]                # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked attention (online softmax over KV blocks)
# ---------------------------------------------------------------------------
def _chunk_attn_masked(
    q: Array,              # (B, qc, H, hd) fp32-scaled
    k: Array,              # (B, kc, Hkv, hd)
    v: Array,              # (B, kc, Hkv, hd)
    q_pos: Array,          # (qc,) absolute positions
    kv_pos: Array,         # (kc,)
    carry,                 # (acc (B,qc,H,hd) f32, m (B,qc,H) f32, l (B,qc,H) f32)
    *,
    causal: bool,
    window: Optional[Array],   # scalar int32 or None: kv_pos > q_pos - window
    kv_valid: Optional[Array] = None,  # (kc,) bool extra mask (decode length)
):
    acc, m, l = carry
    B, qc, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, qc, Hkv, group, hd)
    # logits: (B, qc, Hkv, group, kc)
    logits = jnp.einsum(
        "bqhgd,bkhd->bqhgk", qg.astype(jnp.float32), k.astype(jnp.float32)
    )
    mask = jnp.ones((qc, k.shape[1]), dtype=bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= kv_pos[None, :] > (q_pos[:, None] - window)
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    m_new = jnp.maximum(m, logits.max(axis=-1).reshape(B, qc, H))
    # renormalize old accumulator
    scale_old = jnp.exp(m - m_new)
    p = jnp.exp(logits - m_new.reshape(B, qc, Hkv, group)[..., None])
    l_new = l * scale_old + p.sum(axis=-1).reshape(B, qc, H)
    pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    acc_new = acc * scale_old[..., None] + pv.reshape(B, qc, H, hd)
    return acc_new, m_new, l_new


def chunked_attention(
    q: Array,               # (B, Sq, H, hd)
    k: Array,               # (B, Skv, Hkv, hd)
    v: Array,
    *,
    causal: bool = True,
    q_offset: int | Array = 0,       # absolute position of q[0]
    window: Optional[Array] = None,  # scalar or None
    kv_valid: Optional[Array] = None,  # (Skv,) bool
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    ctx=None,
) -> Array:
    """Online-softmax attention; never materializes (Sq, Skv) logits.

    The default path (no ``kv_valid``/``q_offset``) uses the custom-VJP
    flash implementation: the backward pass recomputes probability blocks
    instead of storing per-chunk residuals (see models/flash.py) — this is
    what keeps the train-cell HBM footprint inside 16 GiB.
    """
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    if kv_valid is None and (isinstance(q_offset, int) and q_offset == 0):
        from .flash import flash_attention_train

        return flash_attention_train(
            q, k, v, causal=causal, window=window,
            q_chunk=q_chunk, kv_chunk=kv_chunk)
    out_dtype = q.dtype
    sm_scale = 1.0 / math.sqrt(hd)
    q = (q.astype(jnp.float32) * sm_scale).astype(q.dtype)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = max(1, Sq // q_chunk)
    nk = max(1, Skv // kv_chunk)
    # require even chunking (shapes here are powers of two)
    if Sq % q_chunk or Skv % kv_chunk:
        q_chunk, nq = Sq, 1
        kv_chunk, nk = Skv, 1

    kc = k.reshape(B, nk, kv_chunk, Hkv, hd)
    vc = v.reshape(B, nk, kv_chunk, Hkv, hd)
    kv_pos_all = jnp.arange(Skv).reshape(nk, kv_chunk)
    kv_valid_all = (
        kv_valid.reshape(nk, kv_chunk) if kv_valid is not None else None
    )

    def one_q_chunk(qi, q_blk):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        init = (
            jnp.zeros((B, q_chunk, H, hd), jnp.float32),
            jnp.full((B, q_chunk, H), NEG_INF, jnp.float32),
            jnp.zeros((B, q_chunk, H), jnp.float32),
        )

        def body(carry, xs):
            k_blk, v_blk, kv_pos, kv_ok = xs
            carry = _chunk_attn_masked(
                q_blk, k_blk, v_blk, q_pos, kv_pos, carry,
                causal=causal, window=window, kv_valid=kv_ok,
            )
            return carry, None

        xs = (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            kv_pos_all,
            kv_valid_all if kv_valid_all is not None
            else jnp.ones((nk, kv_chunk), bool),
        )
        (acc, _m, l), _ = lax.scan(body, init, xs)
        return (acc / jnp.maximum(l[..., None], 1e-30)).astype(out_dtype)

    if nq == 1:
        return one_q_chunk(0, q)
    qc = q.reshape(B, nq, q_chunk, H, hd)
    out = lax.map(
        lambda i: one_q_chunk(i, qc[:, i]), jnp.arange(nq)
    )  # (nq, B, q_chunk, H, hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)


def decode_attention(
    q: Array,          # (B, 1, H, hd)
    k_cache: Array,    # (B, Skv, Hkv, hd)
    v_cache: Array,
    cur_len: Array,    # scalar int32: number of valid cache entries
    *,
    window: Optional[Array] = None,
    ctx=None,
) -> Array:
    """Single-token attention against a (possibly seq-sharded) KV cache."""
    B, _, H, hd = q.shape
    Skv, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = H // Hkv
    sm_scale = 1.0 / math.sqrt(hd)
    qg = (q.astype(jnp.float32) * sm_scale).reshape(B, Hkv, group, hd)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    kv_pos = jnp.arange(Skv)
    mask = kv_pos < cur_len
    if window is not None:
        mask &= kv_pos > (cur_len - 1 - window)
    logits = jnp.where(mask[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# FFN: SwiGLU MLP and top-k MoE
# ---------------------------------------------------------------------------
def swiglu_mlp(x: Array, wi_gate: Array, wi_up: Array, wo: Array, ctx=None) -> Array:
    h = jax.nn.silu(x @ wi_gate) * (x @ wi_up)
    if ctx is not None:
        h = ctx.constrain(h, "batch", "seq", "d_ff")
    return h @ wo


def moe_block(
    x: Array,                # (B, S, D)
    router_w: Array,         # (D, E)
    wi_gate: Array,          # (E, D, F)
    wi_up: Array,            # (E, D, F)
    wo: Array,               # (E, F, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    chunk: int = 256,
    ctx=None,
) -> Tuple[Array, Array]:
    """Capacity-based top-k MoE (GShard-style dispatch/combine einsums),
    grouped over sequence chunks so dispatch tensors stay small.

    Returns (output, aux_loss) — aux is the load-balancing loss.
    """
    B, S, D = x.shape
    E = router_w.shape[-1]
    dtype = x.dtype
    T = B * S
    chunk = min(chunk, T)
    if T % chunk:
        chunk = T
    G = T // chunk
    cap = max(top_k, int(math.ceil(chunk * top_k / E * capacity_factor)))

    xt = x.reshape(G, chunk, D)
    if ctx is not None:
        # keep the group dim fully sharded: without this GSPMD replicates
        # the (G,chunk,E,cap) dispatch tensors (TB-scale for 16e MoEs)
        xt = ctx.constrain(xt, "moe_groups", None, "d_model")
    logits = (xt.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (G,c,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = lax.top_k(probs, top_k)                      # (G,c,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )  # renormalize over chosen experts (mixtral convention)

    sel = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32)               # (G,c,k,E)
    # position of each (token, slot) within its expert queue
    pos = jnp.cumsum(sel.reshape(G, chunk * top_k, E), axis=1).reshape(
        G, chunk, top_k, E
    ) - sel
    keep = (pos < cap) * sel                                           # (G,c,k,E)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = pos_oh.sum(axis=2)                                      # (G,c,E,cap)
    combine = (gate_vals[..., None] * keep)[..., None] * pos_oh
    combine = combine.sum(axis=2)                                      # (G,c,E,cap)

    # dispatch: (g, t, e, c) x tokens (g, t, d) -> expert inputs (g, e, c, d)
    # dispatch entries are {0,1} and combine weights are softmax outputs —
    # bf16 is exact/safe here and halves the dispatch-tensor bytes
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dtype), xt)
    if ctx is not None:
        xe = ctx.constrain(xe, "moe_groups", "experts", None, "d_model")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, wi_gate)) * jnp.einsum(
        "gecd,edf->gecf", xe, wi_up
    )
    if ctx is not None:
        h = ctx.constrain(h, "moe_groups", "experts", None, "d_ff")
    ye = jnp.einsum("gecf,efd->gecd", h, wo)                           # (G,E,cap,D)
    if ctx is not None:
        ye = ctx.constrain(ye, "moe_groups", "experts", None, "d_model")
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(dtype), ye)
    y = y.reshape(B, S, D)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=(0, 1))                       # mean router prob per expert
    ce = sel.sum(axis=2).mean(axis=(0, 1))             # fraction routed per expert
    aux = E * jnp.sum(me * ce)
    return y, aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed(tokens: Array, table: Array, ctx=None, scale: Optional[float] = None) -> Array:
    if ctx is not None and ctx.mesh is not None:
        # one-hot matmul instead of gather: with a (vocab x d_model)-sharded
        # table, gather (and its scatter-add transpose) force GSPMD into
        # full rematerialization; the matmul form shards cleanly and its
        # backward is a plain einsum (measured -9 GiB/device on 33B train)
        onehot = jax.nn.one_hot(tokens, table.shape[0], dtype=table.dtype)
        onehot = ctx.constrain(onehot, "batch", "res_seq", "vocab")
        x = onehot @ table
    else:
        x = jnp.take(table, tokens, axis=0)
    if scale is not None:
        x = (x.astype(jnp.float32) * scale).astype(x.dtype)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "res_seq", "d_model")
    return x


def unembed(x: Array, table: Array, ctx=None) -> Array:
    logits = x @ table.T.astype(x.dtype)
    if ctx is not None:
        # keep the LM head sequence-parallel: without res_seq here the head
        # (logits fp32, lse, label one-hots and their grads) runs with seq
        # gathered — several full (B,S,D)/(B,S,V) fp32 buffers per device
        logits = ctx.constrain(logits, "batch", "res_seq", "vocab")
    return logits
