"""Shared, lazily-sized reader thread pool for the input pipeline.

The seed pipeline created a fresh ``ThreadPoolExecutor`` inside every
``map(num_parallel_calls=k)`` iterator — one pool *per epoch per stage*,
paying thread spawn/teardown on every epoch boundary and preventing any
reuse across pipeline stages.  The paper's tf.data runtime instead owns one
long-lived inter-op pool that every stage schedules onto.

:class:`ReaderPool` is that pool: a process-wide set of daemon worker
threads that grows on demand (``ensure(n)``) and never shrinks.  Stages cap
their own in-flight work (a ``map`` keeps ``num_parallel_calls`` futures in
its window, an ``interleave`` keeps at most ``num_parallel_calls`` block
fetches outstanding), so a pool that grew to 8 workers for one sweep does
not inflate the concurrency of a later 1-thread run — pool size is a
capacity ceiling, not a parallelism setting.

Futures are standard :class:`concurrent.futures.Future` objects, so
``concurrent.futures.wait(..., FIRST_COMPLETED)`` works on them directly
(completion-order ``map`` and interleave block scheduling rely on this).
"""
from __future__ import annotations

import itertools
import queue
import threading
from concurrent.futures import Future
from typing import Any, Callable, List, Optional

from .. import metrics

_counter = itertools.count()


class ReaderPool:
    """Grow-only thread pool with ``Future``-based submission."""

    def __init__(self, name: str = "reader"):
        self._name = name
        self._id = next(_counter)
        self._work: "queue.SimpleQueue" = queue.SimpleQueue()
        self._threads: List[threading.Thread] = []
        self._lock = threading.Lock()
        self._shutdown = False
        self._inflight = 0
        # polled occupancy gauges: pool capacity, tasks queued behind busy
        # workers, tasks currently executing.  queue_depth > 0 while
        # inflight == size is the live "ReaderPool saturated" signal.
        pool = f"{name}-{self._id}"
        metrics.register_gauge("readerpool.size",
                               lambda: len(self._threads), pool=pool)
        metrics.register_gauge("readerpool.queue_depth",
                               self._work.qsize, pool=pool)
        metrics.register_gauge("readerpool.inflight",
                               lambda: self._inflight, pool=pool)

    # -- sizing ----------------------------------------------------------------
    @property
    def size(self) -> int:
        return len(self._threads)

    def ensure(self, n_workers: int) -> "ReaderPool":
        """Grow the pool to at least ``n_workers`` threads (never shrinks)."""
        if n_workers <= 0:
            return self
        with self._lock:
            if self._shutdown:
                raise RuntimeError("ReaderPool is shut down")
            while len(self._threads) < n_workers:
                t = threading.Thread(
                    target=self._worker,
                    name=f"{self._name}-{self._id}-{len(self._threads)}",
                    daemon=True,
                )
                t.start()
                self._threads.append(t)
        return self

    # -- execution -------------------------------------------------------------
    def _worker(self) -> None:
        while True:
            item = self._work.get()
            if item is None:  # shutdown sentinel
                return
            fut, fn, args, kwargs = item
            if not fut.set_running_or_notify_cancel():
                continue
            with self._lock:
                self._inflight += 1
            try:
                fut.set_result(fn(*args, **kwargs))
            except BaseException as e:
                fut.set_exception(e)
            finally:
                with self._lock:
                    self._inflight -= 1
                metrics.inc("readerpool.completed")

    def submit(self, fn: Callable, *args: Any, **kwargs: Any) -> Future:
        if not self._threads:
            self.ensure(1)
        fut: Future = Future()
        metrics.inc("readerpool.submitted")
        self._work.put((fut, fn, args, kwargs))
        return fut

    def shutdown(self) -> None:
        """Stop all workers (used by tests; the global pool lives forever)."""
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
            threads, self._threads = self._threads, []
        for _ in threads:
            self._work.put(None)
        for t in threads:
            t.join(timeout=5.0)
        pool = f"{self._name}-{self._id}"
        for g in ("readerpool.size", "readerpool.queue_depth",
                  "readerpool.inflight"):
            metrics.unregister_gauge(g, pool=pool)


_global_pool: Optional[ReaderPool] = None
_global_lock = threading.Lock()


def reader_pool(min_workers: int = 0) -> ReaderPool:
    """The process-wide shared pool, grown to at least ``min_workers``."""
    global _global_pool
    with _global_lock:
        if _global_pool is None:
            _global_pool = ReaderPool()
    if min_workers:
        _global_pool.ensure(min_workers)
    return _global_pool
