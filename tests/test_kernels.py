"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # bare env (see `test` extra in pyproject.toml)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels import preprocess as _kpre
from repro.kernels.quantize import BLOCK, dequantize_blocks, quantize_blocks


class TestQuantizeKernel:
    @pytest.mark.parametrize("n", [1, 7, 256, 300])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, n, dtype):
        x = (jax.random.normal(jax.random.PRNGKey(n), (n, BLOCK)) * 5).astype(dtype)
        q, s = quantize_blocks(x)
        qr, sr = ref.quantize_blocks_ref(x)
        # last-ulp division differences (compiled vs interpret) may flip a
        # value sitting exactly on a rounding boundary by 1 level
        dq = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
        assert dq.max() <= 1
        # rate bound, with an absolute floor so a single boundary flip in a
        # small array (1 block = 256 values) doesn't trip it
        assert (dq > 0).sum() <= max(1, dq.size // 1000)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
        back = dequantize_blocks(q, s)
        br = ref.dequantize_blocks_ref(qr, sr)
        np.testing.assert_allclose(np.asarray(back), np.asarray(br),
                                   rtol=1e-5, atol=float(np.asarray(s).max()))

    def test_zero_block_scale_is_one(self):
        x = jnp.zeros((4, BLOCK))
        q, s = quantize_blocks(x)
        assert (np.asarray(s) == 1.0).all()
        assert (np.asarray(q) == 0).all()

    @given(st.integers(0, 10_000), st.floats(0.01, 1e4))
    @settings(max_examples=20, deadline=None)
    def test_property_roundtrip_bound(self, seed, scale):
        x = jax.random.normal(jax.random.PRNGKey(seed), (31,)) * scale
        q, s = ops.quantize(x)
        back = ops.dequantize(q, s, x.shape)
        bound = np.abs(np.asarray(x)).max() / 127.0 * 0.5 + 1e-9
        assert np.abs(np.asarray(back) - np.asarray(x)).max() <= bound * 1.01

    def test_any_shape_wrapper(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 7))
        q, s = ops.quantize(x)
        back = ops.dequantize(q, s, x.shape)
        assert back.shape == x.shape


class TestPreprocessKernel:
    @pytest.mark.parametrize("hw", [(8, 8), (17, 23), (64, 48)])
    @pytest.mark.parametrize("c", [1, 3])
    def test_matches_ref(self, hw, c):
        h, w = hw
        img = jax.random.randint(jax.random.PRNGKey(0), (2, h, w, c), 0, 256,
                                 dtype=jnp.uint8)
        mean = jnp.linspace(0.3, 0.6, c)
        std = jnp.linspace(0.2, 0.3, c)
        out = ops.normalize_images_nhwc(img, mean, std)
        xc = jnp.transpose(img, (0, 3, 1, 2)).reshape(2, c, h * w)
        r = ref.normalize_images_ref(xc, mean, std)
        r = jnp.transpose(r.reshape(2, c, h, w), (0, 2, 3, 1))
        np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                                   rtol=1e-4, atol=1e-5)


class TestResizeConvertKernel:
    @pytest.mark.parametrize("in_hw,out_hw", [
        ((24, 20), (12, 16)), ((9, 13), (17, 8)), ((16, 16), (16, 16)),
    ])
    @pytest.mark.parametrize("c", [1, 3])
    def test_pallas_matches_numpy_fallback(self, in_hw, out_hw, c):
        rng = np.random.default_rng(sum(in_hw + out_hw))
        x = rng.integers(0, 256, (3, *in_hw, c), dtype=np.uint8)
        got = np.asarray(_kpre.resize_convert_images(
            jnp.asarray(x), *out_hw))
        want = _kpre.resize_convert_batch_np(x, *out_hw)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_pallas_matches_jnp_oracle(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(0, 256, (2, 14, 18, 3), dtype=np.uint8))
        got = _kpre.resize_convert_images(x, 7, 9)
        want = ref.resize_convert_ref(x, 7, 9)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_matches_per_image_host_path(self):
        from repro.core import records

        rng = np.random.default_rng(1)
        x = rng.integers(0, 256, (4, 20, 16, 3), dtype=np.uint8)
        got = np.asarray(_kpre.resize_convert_images(jnp.asarray(x), 10, 8))
        per_image = np.stack([
            records.preprocess_image(records.encode_image(x[i]), 10, 8)
            for i in range(4)
        ])
        np.testing.assert_allclose(got, per_image, rtol=1e-5, atol=1e-5)

    def test_float_and_uint16_inputs(self):
        rng = np.random.default_rng(2)
        xf = rng.random((2, 10, 12, 1)).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(_kpre.resize_convert_images(jnp.asarray(xf), 5, 6)),
            _kpre.resize_convert_batch_np(xf, 5, 6), rtol=1e-5, atol=1e-6)
        xu = rng.integers(0, 65536, (2, 10, 12, 1)).astype(np.uint16)
        got = np.asarray(_kpre.resize_convert_images(jnp.asarray(xu), 5, 6))
        assert got.min() >= 0.0 and got.max() <= 1.0

    def test_dispatcher_backends_agree(self):
        rng = np.random.default_rng(3)
        x = rng.integers(0, 256, (2, 12, 12, 3), dtype=np.uint8)
        a = np.asarray(_kpre.resize_convert(x, 6, 6, backend="numpy"))
        b = np.asarray(_kpre.resize_convert(x, 6, 6, backend="pallas"))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
        with pytest.raises(ValueError):
            _kpre.resize_convert(x, 6, 6, backend="tpu2000")

    def test_jit_wrapper(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.integers(0, 256, (2, 10, 10, 3), dtype=np.uint8))
        out = ops.resize_convert_nhwc(x, 5, 5)
        assert out.shape == (2, 5, 5, 3) and out.dtype == jnp.float32


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("sq,skv,bq,bk", [
        (128, 128, 64, 64), (256, 256, 128, 64), (64, 64, 64, 64),
    ])
    @pytest.mark.parametrize("hd", [32, 64])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, sq, skv, bq, bk, hd, causal):
        key = jax.random.PRNGKey(hd + sq)
        kq, kk, kv_ = jax.random.split(key, 3)
        q = jax.random.normal(kq, (2, sq, 4, hd), jnp.float32)
        k = jax.random.normal(kk, (2, skv, 2, hd), jnp.float32)
        v = jax.random.normal(kv_, (2, skv, 2, hd), jnp.float32)
        o = ops.flash_attention_bhsd(q, k, v, causal=causal, bq=bq, bk=bk)
        kb = jnp.repeat(k, 2, axis=2)
        vb = jnp.repeat(v, 2, axis=2)
        qf = q.transpose(0, 2, 1, 3).reshape(8, sq, hd)
        kf = kb.transpose(0, 2, 1, 3).reshape(8, skv, hd)
        vf = vb.transpose(0, 2, 1, 3).reshape(8, skv, hd)
        orf = ref.attention_ref(qf, kf, vf, causal=causal)
        orf = orf.reshape(2, 4, sq, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                                   atol=3e-5, rtol=1e-3)

    def test_bf16_io(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 2, 32), jnp.bfloat16)
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 32), jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 32), jnp.bfloat16)
        o = ops.flash_attention_bhsd(q, k, v, bq=64, bk=64)
        assert o.dtype == jnp.bfloat16
        assert np.isfinite(np.asarray(o, np.float32)).all()

    def test_agrees_with_model_chunked_attention(self):
        from repro.models.layers import chunked_attention

        q = jax.random.normal(jax.random.PRNGKey(3), (2, 128, 4, 32))
        k = jax.random.normal(jax.random.PRNGKey(4), (2, 128, 2, 32))
        v = jax.random.normal(jax.random.PRNGKey(5), (2, 128, 2, 32))
        o_kernel = ops.flash_attention_bhsd(q, k, v, bq=64, bk=64)
        o_model = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                                   atol=3e-5, rtol=1e-3)
