"""Background sampler: periodic registry snapshots -> time series.

Counters and sketches accumulate; gauges are instantaneous — to see a
*timeline* (buffer occupancy over the run, backlog draining, records/s)
something must snapshot the registry periodically.  :class:`Sampler` is
that something: a daemon thread that calls ``registry.collect()`` every
``interval_s``, keeps a bounded in-memory series, and optionally appends
each snapshot as a JSONL line (the CI perf artifact; see
:mod:`repro.metrics.export`).

The thread holds no locks while sleeping and tolerates slow ticks (it
never tries to "catch up" — a missed tick is a missed sample, matching
dstat semantics from the paper's §IV-B methodology).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, List, Optional

from . import registry as _registry
from .export import snapshot_to_json
from .registry import MetricsRegistry


class Sampler:
    """Periodic gauge/counter snapshotter with optional JSONL sink."""

    def __init__(
        self,
        registry: Optional[MetricsRegistry] = None,
        interval_s: float = 0.5,
        jsonl_path: Optional[str] = None,
        max_points: int = 10_000,
    ):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be positive, got {interval_s}")
        self._registry = registry
        self.interval_s = float(interval_s)
        self.jsonl_path = jsonl_path
        self._points: Deque[dict] = deque(maxlen=max_points)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._file = None
        self._lock = threading.Lock()

    def _reg(self) -> Optional[MetricsRegistry]:
        return self._registry or _registry.get_registry()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Sampler":
        if self._thread is not None:
            return self
        if self.jsonl_path:
            self._file = open(self.jsonl_path, "w")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="metrics-sampler", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Stop the thread; takes one final sample so short runs (shorter
        than ``interval_s``) still land at least one point."""
        t = self._thread
        if t is None:
            return
        self._stop.set()
        t.join(timeout)
        self._thread = None
        self.sample_now()
        if self._file is not None:
            self._file.close()
            self._file = None

    def __enter__(self) -> "Sampler":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    # -- sampling ------------------------------------------------------------
    def sample_now(self) -> Optional[dict]:
        """Take one snapshot immediately (also used by the tick loop)."""
        reg = self._reg()
        if reg is None:
            return None
        snap = reg.collect()
        with self._lock:
            self._points.append(snap)
            if self._file is not None:
                self._file.write(snapshot_to_json(snap) + "\n")
                self._file.flush()
        return snap

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.sample_now()

    def points(self) -> List[dict]:
        """Snapshot series collected so far (oldest first)."""
        with self._lock:
            return list(self._points)
