"""Span/event collector — the core of the tf-Darshan-style telemetry spine.

Design constraints (tf-Darshan, arXiv:2008.04395, §3: instrumentation must
not perturb the workload it observes):

* **Lock-cheap.** Each thread appends finished spans to its own buffer
  (created once per thread under a registry lock, then lock-free).  The
  only cross-thread synchronization on the hot path is the GIL-atomic
  ``list.append``.
* **Near-zero overhead when disabled.** The module-level :func:`span` /
  :func:`instant` / :func:`count` helpers check a single global and return a
  shared no-op singleton — no object allocation, no kwargs dict, nothing to
  garbage-collect.  Instrumented call sites therefore stay in hot paths
  permanently (storage reads, per-element decode) instead of being
  compiled out.
* **Thread-aware.** Every span records its OS thread id and thread name, so
  nesting is reconstructed per-thread (Chrome ``trace_event`` semantics:
  ``ph:"X"`` events nest by ts/dur containment within one tid).

Timestamps are seconds relative to the tracer's epoch (``time.monotonic``
at construction/reset), which keeps exported traces small and diff-able.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


# ---------------------------------------------------------------------------
# Stage taxonomy (the attribution axis of every span)
# ---------------------------------------------------------------------------
STAGE_STORAGE_READ = "storage_read"       # Storage.read_file (incl. device pacing)
STAGE_STORAGE_WRITE = "storage_write"     # Storage.write_file
STAGE_DECODE = "decode"                   # Dataset.map fn (read+decode+resize)
STAGE_PREFETCH = "prefetch"               # background prefetch-thread fetch
STAGE_CKPT_SNAPSHOT = "checkpoint_snapshot"  # pytree -> host memory (blocking)
STAGE_CKPT_WRITE = "checkpoint_write"     # CheckpointSaver.save (serialize+write)
STAGE_CKPT_RESTORE = "checkpoint_restore" # CheckpointSaver.restore
STAGE_DRAIN = "bb_drain"                  # burst-buffer background drain
STAGE_STAGE = "bb_stage"                  # async-bb fast-tier staging write
#                                           (off the training thread)
STAGE_DATA_WAIT = "data_wait"             # trainer blocked on next(batch)
STAGE_COMPUTE = "compute"                 # trainer forward/backward/update
STAGE_CACHE = "cache"                     # block-cache miss fill / spill I/O

#: Stages that make up the input pipeline (vs. STAGE_COMPUTE) — the two
#: interval sets whose overlap is the paper's Fig. 6 observable.
#: STAGE_STORAGE_READ is deliberately absent: pipeline reads are already
#: nested inside STAGE_DECODE/STAGE_PREFETCH spans, while *non*-pipeline
#: reads (checkpoint restore, burst-buffer drain) would otherwise count as
#: "input pipeline busy" and inflate the overlap ratio.  STAGE_CACHE is
#: excluded for the same reason: cache fills nest inside the read path.
INPUT_PIPELINE_STAGES = (STAGE_DECODE, STAGE_PREFETCH, STAGE_DATA_WAIT)


# ---------------------------------------------------------------------------
# Records
# ---------------------------------------------------------------------------
@dataclass
class SpanRecord:
    """One completed span: ``[t0, t0+dur)`` seconds since the tracer epoch."""

    stage: str
    name: str
    tid: int
    thread: str
    t0: float
    dur: float
    nbytes: int = 0
    args: Optional[dict] = None


@dataclass
class CounterRecord:
    """Point sample of a named gauge (e.g. prefetch buffer depth)."""

    name: str
    t: float
    value: float
    tid: int


# ---------------------------------------------------------------------------
# Span handles
# ---------------------------------------------------------------------------
class _NullSpan:
    """Shared do-nothing span returned on the disabled path.

    A single module-level instance serves every disabled call site, so a
    ``with span(...)`` costs two method calls and zero allocations.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set_bytes(self, nbytes: int) -> "_NullSpan":
        return self

    def set(self, **args) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """Live span handle; records itself into the tracer on ``__exit__``."""

    __slots__ = ("_tracer", "stage", "name", "_t0", "nbytes", "args")

    def __init__(self, tracer: "Tracer", stage: str, name: str, nbytes: int = 0):
        self._tracer = tracer
        self.stage = stage
        self.name = name
        self.nbytes = nbytes
        self.args = None

    def set_bytes(self, nbytes: int) -> "Span":
        self.nbytes = nbytes
        return self

    def set(self, **args) -> "Span":
        if self.args is None:
            self.args = args
        else:
            self.args.update(args)
        return self

    def __enter__(self) -> "Span":
        self._t0 = time.monotonic()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.monotonic()
        tr = self._tracer
        th = threading.current_thread()
        tr._append_span(
            SpanRecord(
                stage=self.stage,
                name=self.name,
                tid=th.ident or 0,
                thread=th.name,
                t0=self._t0 - tr._epoch,
                dur=t1 - self._t0,
                nbytes=self.nbytes,
                args=self.args,
            )
        )
        return False


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------
class _ThreadBuf:
    __slots__ = ("spans", "counters")

    def __init__(self):
        self.spans: List[SpanRecord] = []
        self.counters: List[CounterRecord] = []


class Tracer:
    """Thread-aware span/counter collector.

    Per-thread buffers are registered once (under ``_reg_lock``) and then
    appended to without any locking; snapshots (:meth:`spans`,
    :meth:`counters`) copy under the registry lock so concurrent recording
    stays safe.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._epoch = time.monotonic()
        self._local = threading.local()
        self._reg_lock = threading.Lock()
        self._bufs: List[_ThreadBuf] = []

    # -- recording ---------------------------------------------------------
    def _buf(self) -> _ThreadBuf:
        b = getattr(self._local, "buf", None)
        if b is None:
            b = _ThreadBuf()
            with self._reg_lock:
                self._bufs.append(b)
            self._local.buf = b
        return b

    def _append_span(self, rec: SpanRecord) -> None:
        self._buf().spans.append(rec)

    def span(self, stage: str, name: str = "", nbytes: int = 0):
        """Open a span; use as ``with tracer.span(stage, name) as sp:``."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, stage, name, nbytes)

    def instant(self, stage: str, name: str = "", nbytes: int = 0,
                t: Optional[float] = None) -> None:
        """Record a zero-duration event (e.g. a byte-counter sample)."""
        if not self.enabled:
            return
        th = threading.current_thread()
        if t is None:
            t = time.monotonic() - self._epoch
        self._append_span(
            SpanRecord(stage=stage, name=name, tid=th.ident or 0,
                       thread=th.name, t0=t, dur=0.0, nbytes=nbytes)
        )

    def count(self, name: str, value: float) -> None:
        """Sample a gauge (rendered as a counter track in Perfetto)."""
        if not self.enabled:
            return
        th = threading.current_thread()
        self._buf().counters.append(
            CounterRecord(name=name, t=time.monotonic() - self._epoch,
                          value=float(value), tid=th.ident or 0)
        )

    # -- snapshots ---------------------------------------------------------
    def spans(self) -> List[SpanRecord]:
        """Merged snapshot of all threads' spans, sorted by start time."""
        with self._reg_lock:
            out: List[SpanRecord] = []
            for b in self._bufs:
                out.extend(b.spans)
        out.sort(key=lambda r: (r.t0, -r.dur))
        return out

    def counters(self) -> List[CounterRecord]:
        with self._reg_lock:
            out: List[CounterRecord] = []
            for b in self._bufs:
                out.extend(b.counters)
        out.sort(key=lambda r: r.t)
        return out

    # -- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        with self._reg_lock:
            for b in self._bufs:
                b.spans.clear()
                b.counters.clear()
            self._epoch = time.monotonic()

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False


# ---------------------------------------------------------------------------
# Module-level API (what instrumented call sites use)
# ---------------------------------------------------------------------------
_active: Optional[Tracer] = None


def get_tracer() -> Optional[Tracer]:
    """The process-global tracer, or None when tracing is off."""
    return _active


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    global _active
    _active = tracer
    return tracer


def start(enabled: bool = True) -> Tracer:
    """Install (and return) a fresh global tracer."""
    return set_tracer(Tracer(enabled=enabled))


def stop() -> Optional[Tracer]:
    """Uninstall and return the global tracer (its records stay readable)."""
    global _active
    t, _active = _active, None
    return t


def enabled() -> bool:
    t = _active
    return t is not None and t.enabled


def span(stage: str, name: str = "", nbytes: int = 0):
    """Hot-path helper: a real span when tracing, the shared null span
    otherwise.  Call sites must pass positional args only so the disabled
    path allocates nothing."""
    t = _active
    if t is None or not t.enabled:
        return NULL_SPAN
    return Span(t, stage, name, nbytes)


def instant(stage: str, name: str = "", nbytes: int = 0) -> None:
    t = _active
    if t is not None and t.enabled:
        t.instant(stage, name, nbytes)


def count(name: str, value: float) -> None:
    t = _active
    if t is not None and t.enabled:
        t.count(name, value)
