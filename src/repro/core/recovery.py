"""Checkpoint retention + corruption-aware restore + train-state resume.

The paper's restart story (§III-C) is "restart quickly from a checkpoint";
PR 2/7 made the *save* path crash-consistent, this module makes recovery
actually work end-to-end:

* :class:`CheckpointManager` owns **retention** (keep-last-k plus
  keep-every-n milestones) on top of a :class:`~repro.core.checkpoint.
  CheckpointSaver`, with a GC whose invariant is *never delete the only
  valid restore target* and whose ordering is crash-safe: the marker is
  rewritten to the retained set **first**, files are deleted second — a
  crash in between leaves stray files (reclaimed by the next GC), never a
  marker pointing at deleted data.
* :func:`validate_step` / :func:`latest_valid_step` — structural
  validation (meta + index parse, every shard present and long enough for
  its tensor extents) that detects torn writes, rolled-back unsynced data
  and half-deleted steps *without* reading tensor bytes.  ``restore()``
  walks valid steps newest-first, past corrupt/torn/unsynced checkpoints —
  the marker-fallback generalization of the burst-buffer restore: step
  candidates come from the union of the marker and a directory listing, so
  a torn/missing marker alone never makes data unreachable.
* :meth:`CheckpointManager.resume` — TrainState-level restart: restores
  params into a skeleton **and** re-positions a
  :class:`~repro.core.dataset.ResumableIterator` from the pipeline state
  the trainer attached at save time (``extra_meta["pipeline"]``), so a
  resumed run neither skips nor replays samples.

PR 10 fuses the manager with every save engine (``engine=direct|async|
bb|asyncbb``): one lifecycle subsystem instead of "retention *or* the
async blocked-time win".  Each step moves through explicit states —
``SNAPSHOTTED`` (host copy taken) → ``STAGED`` (durable at the engine's
preemption tier) → ``COMMITTED`` (durable at the final tier) — with
retention/GC **deferred past drain commit** via engine hooks, so a step
staged on the fast tier but not yet drained is never collected and
``latest_valid()``/``restore()`` consult both tiers.  ``preempt(
deadline_s)`` forwards the graceful-shutdown budget to the engine
(promote the newest in-flight save, abandon the rest, record it).

The manager implements the checkpointer interface the
:class:`~repro.train.trainer.Trainer` expects (``save``/``latest_step``/
``restore_pytree``/``wait``/``close``/``preempt``/``blocked_s``), so it
can drop in wherever a :class:`~repro.core.burst_buffer.
DirectCheckpointer` does — optionally with a :class:`~repro.core.retry.
RetryingStorage` wrap for transient-fault absorption
(``retry_policy=...``).
"""
from __future__ import annotations

import json
import re
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from .. import metrics
from .async_burst_buffer import AsyncBurstBufferCheckpointer
from .async_checkpoint import AsyncCheckpointer
from .burst_buffer import BurstBufferCheckpointer, DirectCheckpointer
from .checkpoint import (CHECKPOINT_MARKER, CheckpointSaver,
                         PreemptionReport, SaveResult, unflatten_pytree,
                         write_marker)
from .retry import RetryingStorage, RetryPolicy

#: Effectively-infinite retention for the inner saver: the manager owns GC.
_NO_SAVER_GC = 1 << 30

#: Per-step lifecycle states of the fused manager (monotonic order).
SNAPSHOTTED = "SNAPSHOTTED"   # host snapshot taken; nothing on storage yet
STAGED = "STAGED"             # durable at the engine's preemption tier
COMMITTED = "COMMITTED"       # durable at the final (slow) tier; GC-eligible
ABANDONED = "ABANDONED"       # given up by preempt() to meet its deadline
_STATE_ORDER = {SNAPSHOTTED: 0, STAGED: 1, COMMITTED: 2}

ENGINES = ("direct", "async", "bb", "asyncbb")
#: How many COMMITTED entries the per-step state map keeps around (all
#: non-committed entries are always kept — they are live lifecycle state).
_STATE_HISTORY = 64


def _split_prefix(prefix: str) -> Tuple[str, str]:
    """``"ckpt/model"`` -> ``("ckpt", "model")``."""
    if "/" in prefix:
        d, name = prefix.rsplit("/", 1)
    else:
        d, name = ".", prefix
    return d, name


def list_steps(storage, prefix: str) -> List[int]:
    """Steps present on disk (by filename), sorted ascending.

    Deliberately *not* marker-based: after a torn marker write or a
    half-finished GC the marker under-reports what is restorable.
    """
    d, name = _split_prefix(prefix)
    pat = re.compile(re.escape(name) + r"-(\d+)\.(meta|index|data-\d+-of-\d+)$")
    steps: Set[int] = set()
    try:
        names = storage.listdir(d)
    except (FileNotFoundError, OSError):
        return []
    for n in names:
        m = pat.match(n)
        if m:
            steps.add(int(m.group(1)))
    return sorted(steps)


def marker_steps(storage, prefix: str) -> List[int]:
    """Steps the commit marker claims (``[]`` on a missing/corrupt marker)."""
    d, _ = _split_prefix(prefix)
    path = f"{d}/{CHECKPOINT_MARKER}"
    try:
        if not storage.exists(path):
            return []
        marker = json.loads(storage.read_file(path))
        steps = {int(s) for s in marker.get("all_steps", [])}
        if "latest" in marker and marker["latest"] is not None:
            steps.add(int(marker["latest"]))
        return sorted(steps)
    except (OSError, ValueError, KeyError, TypeError):
        return []


def validate_step(storage, prefix: str, step: int) -> bool:
    """Structural validity: can ``restore(step)`` possibly succeed?

    Checks the meta and index parse as JSON, and that every data shard
    exists with at least the bytes its tensor extents require — which
    catches torn shard writes (truncated content), unsynced writes rolled
    back by a crash (missing/short files), and half-deleted steps, without
    reading any tensor data.
    """
    base = f"{prefix}-{step}"
    try:
        meta = json.loads(storage.read_file(f"{base}.meta"))
        if int(meta["step"]) != step:
            return False
        index = json.loads(storage.read_file(f"{base}.index"))
        n_shards = int(index["n_shards"])
        need = [0] * n_shards
        for e in index["tensors"].values():
            s = int(e["shard"])
            need[s] = max(need[s], int(e["offset"]) + int(e["length"]))
        for s in range(n_shards):
            p = f"{base}.data-{s:05d}-of-{n_shards:05d}"
            if storage.size(p) < need[s]:
                return False
        return True
    except (OSError, ValueError, KeyError, TypeError):
        return False


def valid_steps(storage, prefix: str) -> List[int]:
    """All structurally-valid steps, sorted ascending.  Candidates are the
    union of the directory listing and the marker (marker-fallback: either
    source alone may be damaged)."""
    cands = set(list_steps(storage, prefix)) | set(marker_steps(storage, prefix))
    return [s for s in sorted(cands) if validate_step(storage, prefix, s)]


def latest_valid_step(storage, prefix: str) -> Optional[int]:
    vs = valid_steps(storage, prefix)
    return vs[-1] if vs else None


@dataclass
class ResumeResult:
    """What :meth:`CheckpointManager.resume` recovered.

    ``step is None`` means no restorable checkpoint existed — ``state`` is
    the untouched skeleton and training starts fresh.
    """

    step: Optional[int]
    state: Any
    meta: Dict[str, Any] = field(default_factory=dict)
    pipeline: Optional[Dict[str, Any]] = None
    restore_s: float = 0.0

    @property
    def fresh(self) -> bool:
        return self.step is None


class CheckpointManager:
    """Retention + corruption-aware restore, fused with any save engine.

    ``engine`` selects the save path (all four share one commit protocol):

    * ``"direct"`` (default) — synchronous sharded save to ``storage``;
    * ``"async"`` — :class:`~repro.core.async_checkpoint.AsyncCheckpointer`
      (snapshot-only blocking, background write);
    * ``"bb"`` — :class:`~repro.core.burst_buffer.BurstBufferCheckpointer`
      (stage to ``fast_storage``, background drain to ``storage``);
    * ``"asyncbb"`` — the fused engine (snapshot-only blocking, background
      stage *and* drain).

    The manager drives every step through explicit lifecycle states
    (:data:`SNAPSHOTTED` → :data:`STAGED` → :data:`COMMITTED`, readable via
    :meth:`step_states`), and owns retention: ``keep_last`` newest steps
    plus ``keep_every`` milestones, with the latest *valid* step always
    kept.  With a background engine, GC is **deferred past drain commit** —
    it runs from the engine's commit hook, on the engine's own thread, so a
    step staged on the fast tier but not yet drained is never deleted and
    stays restorable for a preemption restart.  :meth:`latest_valid` and
    :meth:`restore` consult **both tiers** (fast preferred: it holds the
    newest data and reads faster).

    ``retry_policy`` wraps both storages in :class:`~repro.core.retry.
    RetryingStorage` so transient device faults are absorbed below the
    checkpoint protocol.  :meth:`preempt` forwards the graceful-shutdown
    budget to the engine and records what was abandoned; :meth:`close` is
    idempotent and delivers a pending background error exactly once.
    """

    def __init__(
        self,
        storage,
        prefix: str = "ckpt/model",
        *,
        engine: str = "direct",
        fast_storage=None,
        keep_last: int = 5,
        keep_every: Optional[int] = None,
        retry_policy: Optional[RetryPolicy] = None,
        n_shards: int = 1,
        sync: bool = True,
        quantize: Optional[str] = None,
        io_threads: Optional[int] = None,
        max_pending: int = 2,
        cleanup_fast: bool = True,
        drain_streams: int = 4,
        drain_chunk: int = 8 << 20,
        drain_stall_timeout: Optional[float] = None,
        drain_requeue_limit: int = 3,
    ):
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        if keep_every is not None and keep_every < 1:
            raise ValueError(f"keep_every must be >= 1, got {keep_every}")
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
        if engine in ("bb", "asyncbb") and fast_storage is None:
            raise ValueError(f"engine={engine!r} requires fast_storage")
        if retry_policy is not None:
            storage = RetryingStorage(storage, retry_policy)
            if fast_storage is not None:
                fast_storage = RetryingStorage(fast_storage, retry_policy)
        self.storage = storage
        self.fast_storage = fast_storage
        self.prefix = prefix
        self.engine_kind = engine
        self.keep_last = keep_last
        self.keep_every = keep_every
        # the slow-tier saver never GCs (keep=inf) and is used for restore
        # and GC bookkeeping only: deletion policy lives in the manager,
        # where "valid" is a first-class concept
        saver_kw = dict(n_shards=n_shards, sync=sync, quantize=quantize,
                        io_threads=io_threads)
        self.saver = CheckpointSaver(storage, prefix, keep=_NO_SAVER_GC,
                                     **saver_kw)
        if engine == "direct":
            self.engine = DirectCheckpointer(
                storage, prefix, keep=_NO_SAVER_GC, **saver_kw)
        elif engine == "async":
            self.engine = AsyncCheckpointer(
                storage, prefix, keep=_NO_SAVER_GC,
                max_pending=max_pending, **saver_kw)
            self.engine.on_committed = self._on_committed
        elif engine == "bb":
            self.engine = BurstBufferCheckpointer(
                fast_storage, storage, prefix, keep=_NO_SAVER_GC,
                cleanup_fast=cleanup_fast, drain_streams=drain_streams,
                drain_chunk=drain_chunk,
                drain_stall_timeout=drain_stall_timeout,
                drain_requeue_limit=drain_requeue_limit, **saver_kw)
        else:  # asyncbb
            self.engine = AsyncBurstBufferCheckpointer(
                fast_storage, storage, prefix, keep=_NO_SAVER_GC,
                max_pending=max_pending, cleanup_fast=cleanup_fast,
                drain_streams=drain_streams, drain_chunk=drain_chunk,
                drain_stall_timeout=drain_stall_timeout,
                drain_requeue_limit=drain_requeue_limit, **saver_kw)
        if engine in ("bb", "asyncbb"):
            self.engine.on_staged = self._on_staged
            self.engine.on_drained = self._on_committed
        self.fast_saver = getattr(self.engine, "fast_saver", None)
        self._dir, _ = _split_prefix(prefix)
        self.gc_deleted: List[int] = []  # every step GC ever removed
        self.abandoned_steps: List[int] = []  # given up by preempt()
        self._sync = sync
        self._closed = False
        self._gc_lock = threading.Lock()
        self._state_lock = threading.Lock()
        self._step_states: "OrderedDict[int, str]" = OrderedDict()

    # -- lifecycle state machine ----------------------------------------------
    @property
    def blocked_s(self) -> List[float]:
        """Training-thread blocked time, straight from the engine."""
        return self.engine.blocked_s

    def _mark(self, step: int, state: str) -> None:
        """Advance ``step``'s lifecycle state (monotonic: hooks firing out
        of order can never move a step backwards).  Runs on the training
        thread and on engine background threads."""
        with self._state_lock:
            cur = self._step_states.get(step)
            if (state in _STATE_ORDER and cur in _STATE_ORDER
                    and _STATE_ORDER[state] < _STATE_ORDER[cur]):
                return
            self._step_states[step] = state
            self._step_states.move_to_end(step)
            committed = [s for s, st in self._step_states.items()
                         if st == COMMITTED]
            for s in committed[:-_STATE_HISTORY]:
                del self._step_states[s]
        if metrics.enabled():
            metrics.inc("ckpt.lifecycle_transitions", 1, state=state)

    def step_states(self) -> Dict[int, str]:
        """Snapshot of the per-step lifecycle map (newest last)."""
        with self._state_lock:
            return dict(self._step_states)

    def _on_staged(self, step: int) -> None:
        """Engine hook: the step committed at the preemption tier."""
        self._mark(step, STAGED)

    def _on_committed(self, step: int) -> None:
        """Engine hook: the step committed at the final tier.  Deferred
        retention runs *here* — never earlier, so an undrained step can't
        be collected out from under a preemption restart."""
        self._mark(step, COMMITTED)
        self.gc()

    # -- save + retention ------------------------------------------------------
    def save(self, step: int, tree: Any, extra_meta: Optional[dict] = None):
        """Save through the engine.  Returns its native result — a
        :class:`~repro.core.checkpoint.SaveResult` for the synchronous
        engines, an :class:`~repro.core.async_checkpoint.AsyncSaveHandle`
        for the async ones."""
        if self._closed:
            raise RuntimeError("save() on a closed CheckpointManager")
        r = self.engine.save(step, tree, extra_meta)
        self._mark(step, SNAPSHOTTED)
        if self.engine_kind == "direct":
            # synchronous single-tier commit: the save call was the whole
            # lifecycle, and GC stays inline (back-compat with PR 8)
            self._mark(step, STAGED)
            self._on_committed(step)
        elif self.engine_kind == "bb":
            # save() blocked through the fast-tier write: already staged
            self._mark(step, STAGED)
        return r

    def retained_steps(self) -> List[int]:
        """The set the current policy would keep, given what's on disk."""
        steps = list_steps(self.storage, self.prefix)
        if not steps:
            return []
        retained: Set[int] = set(steps[-self.keep_last:])
        if self.keep_every:
            retained |= {s for s in steps if s % self.keep_every == 0}
        lv = latest_valid_step(self.storage, self.prefix)
        if lv is not None:
            retained.add(lv)
        return sorted(retained)

    def gc(self) -> List[int]:
        """Apply retention on the final (slow) tier; return the steps
        deleted.

        Ordering is crash-safe: the marker is rewritten to the retained set
        *before* any file is deleted, so a crash mid-GC strands extra files
        (reclaimed by the next GC) but never publishes a marker whose steps
        are gone.  The latest valid step is always in the retained set —
        GC can never delete the only restore target.  With a background
        engine this runs on the engine's commit thread (serialized with its
        marker publishes); the lock only guards against a concurrent
        user-initiated call.  Steps staged on the fast tier but not yet
        drained are untouchable by construction: they have no slow-tier
        files, and the engine's own fast-tier cleanup never evicts the
        newest or still-pending steps.
        """
        with self._gc_lock:
            steps = list_steps(self.storage, self.prefix)
            if not steps:
                return []
            retained = set(self.retained_steps())
            doomed = [s for s in steps if s not in retained]
            lv = latest_valid_step(self.storage, self.prefix)
            latest = lv if lv is not None else max(retained)
            marker = json.dumps(
                dict(latest=latest, all_steps=sorted(retained))).encode()
            write_marker(self.storage, self.saver._marker_path(), marker,
                         sync=self.saver.sync)
            for s in doomed:
                self.saver._delete_step(s)
            self.gc_deleted.extend(doomed)
            return doomed

    # -- introspection ---------------------------------------------------------
    def all_steps(self) -> List[int]:
        """Steps on the final (slow) tier — the set retention governs."""
        return list_steps(self.storage, self.prefix)

    def fast_steps(self) -> List[int]:
        """Steps on the fast tier (``[]`` for single-tier engines)."""
        if self.fast_storage is None:
            return []
        return list_steps(self.fast_storage, self.prefix)

    def valid_steps(self) -> List[int]:
        """Structurally-valid steps across **both** tiers: a step staged on
        the fast tier but not yet drained is restorable (the
        preemption-restart contract)."""
        vs: Set[int] = set(valid_steps(self.storage, self.prefix))
        if self.fast_storage is not None:
            vs |= set(valid_steps(self.fast_storage, self.prefix))
        return sorted(vs)

    def latest_valid(self) -> Optional[int]:
        vs = self.valid_steps()
        return vs[-1] if vs else None

    def latest_step(self) -> Optional[int]:
        """Newest *restorable* step (the Trainer's resume entry point) —
        deliberately stricter than the marker's ``latest``."""
        return self.latest_valid()

    # -- restore ---------------------------------------------------------------
    def _tiers(self) -> List[Tuple[Any, CheckpointSaver]]:
        """(storage, saver) pairs in restore-preference order: fast tier
        first (it holds the newest data and reads faster), slow second."""
        out: List[Tuple[Any, CheckpointSaver]] = []
        if self.fast_saver is not None:
            out.append((self.fast_storage, self.fast_saver))
        out.append((self.storage, self.saver))
        return out

    def restore(self, step: Optional[int] = None
                ) -> Tuple[Dict[str, Any], dict, int]:
        """Restore ``step`` (or the newest restorable step), walking back
        past corrupt/torn/unsynced checkpoints across both tiers.  Returns
        ``(flat, meta, step_restored)``.
        """
        if step is not None:
            for storage, saver in self._tiers():
                if storage is not self.storage and \
                        not validate_step(storage, self.prefix, step):
                    continue
                try:
                    flat, meta = saver.restore(step)
                    return flat, meta, step
                except (OSError, ValueError, KeyError):
                    if storage is self.storage:
                        raise  # slow tier was the last resort: error parity
        for s in reversed(self.valid_steps()):
            for storage, saver in self._tiers():
                if not validate_step(storage, self.prefix, s):
                    continue
                try:
                    flat, meta = saver.restore(s)
                    return flat, meta, s
                except (OSError, ValueError, KeyError):
                    continue  # damage validate_step can't see (bad JSON field)
        raise FileNotFoundError(
            f"no restorable checkpoint under {self.prefix}")

    def restore_pytree(self, skeleton: Any, step: Optional[int] = None) -> Any:
        import jax

        flat, _meta, _s = self.restore(step)
        treedef = jax.tree_util.tree_structure(skeleton)
        return unflatten_pytree(flat, treedef)

    def resume(self, skeleton: Any, *, data_iter: Any = None,
               step: Optional[int] = None) -> ResumeResult:
        """TrainState-level restart: params + input-pipeline position.

        Restores the newest restorable checkpoint into ``skeleton``'s
        structure; if the checkpoint carries pipeline state (the trainer
        attaches ``extra_meta={"pipeline": it.state()}`` at save time) and
        ``data_iter`` supports ``restore_state``, the iterator is
        re-positioned so the resumed run neither skips nor replays samples.
        With no checkpoint at all, returns a fresh :class:`ResumeResult`
        (``step=None``, skeleton untouched).
        """
        import jax

        t0 = time.monotonic()
        try:
            flat, meta, s = self.restore(step)
        except FileNotFoundError:
            if step is not None:
                raise
            return ResumeResult(step=None, state=skeleton)
        treedef = jax.tree_util.tree_structure(skeleton)
        state = unflatten_pytree(flat, treedef)
        pipeline = (meta.get("extra") or {}).get("pipeline")
        if data_iter is not None and pipeline is not None \
                and hasattr(data_iter, "restore_state"):
            data_iter.restore_state(pipeline)
        return ResumeResult(step=s, state=state, meta=meta,
                            pipeline=pipeline,
                            restore_s=time.monotonic() - t0)

    # -- checkpointer-interface parity ----------------------------------------
    def wait(self) -> None:
        """Block until every issued save has committed at the final tier;
        surfaces the first background error (report-once, engine contract)."""
        self.engine.wait()

    def preempt(self, deadline_s: Optional[float] = None) -> PreemptionReport:
        """Graceful-shutdown budget, forwarded to the engine: stop issuing
        new saves, promote the newest in-flight save to its preemption-tier
        commit within ``deadline_s``, abandon the rest.  Abandoned steps
        are recorded in :attr:`abandoned_steps` and marked
        :data:`ABANDONED` in the lifecycle map."""
        report = self.engine.preempt(deadline_s)
        if report.committed_step is None:
            # the engine's view may lag (e.g. queued cleanups); fall back to
            # what is actually restorable across both tiers
            report.committed_step = self.latest_valid()
        self.abandoned_steps.extend(report.abandoned_steps)
        for s in report.abandoned_steps:
            self._mark(s, ABANDONED)
        return report

    def close(self) -> None:
        """Idempotent shutdown.  The first call closes the engine and lets
        its never-delivered background error (if any) surface; later calls
        are no-ops — the error is delivered exactly once, matching the
        :class:`~repro.core.burst_buffer.DirectCheckpointer` close()
        discipline even when the engine still has pending saves."""
        if self._closed:
            return
        self._closed = True
        self.engine.close()
