"""IOTracer adapter (dstat view over repro.trace) + kind validation."""
import time

import pytest

from repro.core.stats import IOTracer


class TestKindValidation:
    def test_unknown_kind_raises(self):
        tr = IOTracer()
        with pytest.raises(ValueError, match="unknown I/O kind"):
            tr.record("fsync", 10)
        # regression: before the fix, any unknown kind silently counted as a
        # write — totals must stay untouched after the failed record
        t = tr.totals()
        assert t["write_bytes"] == 0 and t["write_ops"] == 0

    @pytest.mark.parametrize("kind", ["read", "write"])
    def test_valid_kinds_accepted(self, kind):
        tr = IOTracer()
        tr.record(kind, 100, "f")
        t = tr.totals()
        assert t[f"{kind}_bytes"] == 100
        assert t[f"{kind}_ops"] == 1


class TestAdapter:
    def test_totals_and_timeline(self):
        tr = IOTracer(interval_s=0.05)
        tr.record("read", 1000, "a")
        tr.record("write", 500, "b")
        time.sleep(0.06)
        tr.record("read", 2000, "c")
        t = tr.totals()
        assert t == dict(read_bytes=3000, write_bytes=500,
                         read_ops=2, write_ops=1)
        rows = tr.timeline()
        assert len(rows) >= 2
        assert rows[0]["read_ops"] == 1 and rows[0]["write_ops"] == 1
        assert sum(r["read_ops"] for r in rows) == 2

    def test_csv_header_and_rows(self):
        tr = IOTracer()
        tr.record("read", 1_000_000)
        csv = tr.to_csv()
        lines = csv.splitlines()
        assert lines[0] == "t_s,read_mb_s,write_mb_s,read_ops,write_ops"
        assert lines[1].startswith("0.0,1.000,")

    def test_reset(self):
        tr = IOTracer()
        tr.record("read", 10)
        tr.reset()
        assert tr.timeline() == []
        assert tr.totals()["read_ops"] == 0

    def test_events_gated_by_keep_events(self):
        tr = IOTracer()
        tr.record("read", 10, "x")   # keep_events off: not logged
        assert tr.events == []
        tr.keep_events = True
        tr.record("write", 20, "y")
        kinds = [(k, n, tag) for _t, k, n, tag in tr.events]
        assert kinds == [("write", 20, "y")]
        # the bucketed view saw both ops regardless
        assert tr.totals()["read_ops"] == 1 and tr.totals()["write_ops"] == 1

    def test_collector_exposed_for_span_tooling(self):
        from repro import trace

        tr = IOTracer()
        tr.keep_events = True
        tr.record("read", 64, "f.bin")
        spans = tr.collector.spans()
        assert spans[0].stage == trace.STAGE_STORAGE_READ
        assert spans[0].nbytes == 64

    def test_bounded_memory_without_keep_events(self):
        # default mode folds into buckets: no per-op records retained
        tr = IOTracer()
        for _ in range(100):
            tr.record("read", 1)
        assert tr.collector.spans() == []
        assert tr.totals()["read_ops"] == 100
