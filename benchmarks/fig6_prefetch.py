"""Fig. 6 analogue: AlexNet mini-app runtime, prefetch on/off x threads x tier.

The paper's central claim: with prefetch(1), runtime becomes independent of
threads/tier (input pipeline fully hidden behind per-batch compute).

Emits the usual CSV rows plus machine-readable ``BENCH_prefetch.json``:
per tier x thread-count an ``overlap_gain`` leaf (no-prefetch runtime /
prefetch runtime — how much wall clock prefetch overlap wins back, gated
by the regression gate's ``overlap`` family) and the cross-config
``overlap_excess_hdd1`` (hdd single-thread no-prefetch excess, the paper's
headline worst case).

    PYTHONPATH=src python -m benchmarks.fig6_prefetch [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs.alexnet_mini import SMOKE as ACFG_SMOKE
from repro.configs.alexnet_mini import AlexNetConfig

# heavier FC stack: per-batch compute ~0.3 s, comfortably above per-batch
# I/O on the fast tiers but comparable to single-thread HDD (paper regime)
ACFG = AlexNetConfig(name="alexnet-fig6", in_hw=128,
                     filters=(64, 128, 192, 128, 128), fc=(1024, 1024))
from repro.core.dataset import image_pipeline
from repro.models import alexnet as A

from .common import RESULTS_DIR, BenchEnv, emit


def make_train_step(acfg):
    @jax.jit
    def step(params, imgs, labels):
        loss, g = jax.value_and_grad(
            lambda p: A.loss_fn(p, imgs, labels, acfg))(params)
        new_p = jax.tree.map(lambda p, gg: p - 1e-4 * gg, params, g)
        return new_p, loss

    return step


def run_epoch(st, paths, labels, *, threads, prefetch, step, params, acfg,
              batch=16, n_batches=6):
    ds = image_pipeline(
        st, paths, labels, batch_size=batch, num_parallel_calls=threads,
        prefetch=prefetch, out_hw=(acfg.in_hw, acfg.in_hw), seed=0,
        repeat=True)
    it = iter(ds)
    # warmup compile outside the timed region
    imgs, lbls = next(it)
    params, _ = step(params, jnp.asarray(imgs), jnp.asarray(lbls))
    t0 = time.monotonic()
    for _ in range(n_batches):
        imgs, lbls = next(it)
        params, loss = step(params, jnp.asarray(imgs), jnp.asarray(lbls))
        loss.block_until_ready()
    return time.monotonic() - t0


def run(tiers=("hdd", "ssd", "optane"), n_images=160, mean_hw=(64, 64),
        thread_counts=(1, 4), batch=16, n_batches=6, acfg=ACFG,
        name="fig6_prefetch", json_path=None) -> dict:
    # Caltech-101-like corpus: median ~12 KB images, unscaled tier model
    env = BenchEnv(tiers=tiers, n_images=n_images, mean_hw=mean_hw,
                   time_scale=1.0)
    step = make_train_step(acfg)
    params = A.init_params(jax.random.PRNGKey(0), acfg)
    rows = []
    times = {}
    result: dict = {}
    for tier in tiers:
        st = env.storages[tier]
        paths, labels = env.corpora[tier]
        result[tier] = {}
        for threads in thread_counts:
            per = {}
            for pf in (0, 1):
                t = run_epoch(st, paths, labels, threads=threads,
                              prefetch=pf, step=step, params=params,
                              acfg=acfg, batch=batch, n_batches=n_batches)
                times[(tier, threads, pf)] = t
                per[f"prefetch{pf}_s"] = round(t, 3)
                rows.append(f"{tier},threads={threads},prefetch={pf},"
                            f"runtime_s={t:.2f}")
            per["overlap_gain"] = round(
                per["prefetch0_s"] / max(per["prefetch1_s"], 1e-9), 3)
            result[tier][str(threads)] = per
    env.close()

    # prefetch-hides-io check: spread of prefetch=1 runtimes across configs
    pf1 = [v for k, v in times.items() if k[2] == 1]
    spread = (max(pf1) - min(pf1)) / max(min(pf1), 1e-9)
    t0 = thread_counts[0]
    excess = (times[(tiers[0], t0, 0)] / times[(tiers[0], t0, 1)])
    emit(name, rows,
         f"prefetch=1 runtime spread across tiers/threads={spread:.2%} "
         f"(paper: ~0 — I/O fully hidden); {tiers[0]} {t0}-thread "
         f"no-prefetch excess={excess:.2f}x")

    payload = {
        "benchmark": name,
        "config": {
            "tiers": list(tiers), "n_images": n_images,
            "mean_hw": list(mean_hw), "thread_counts": list(thread_counts),
            "batch": batch, "n_batches": n_batches,
            "model": {"name": acfg.name, "in_hw": acfg.in_hw,
                      "filters": list(acfg.filters), "fc": list(acfg.fc)},
        },
        "tiers": result,
        "overlap_excess_hdd1": round(excess, 3),
        "prefetch_spread": round(spread, 4),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_json = json_path or os.path.join(RESULTS_DIR, "BENCH_prefetch.json")
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_json}")
    return payload


def run_smoke() -> dict:
    """Tiny-scale CI variant: toy model, two tiers, seconds of runtime."""
    return run(tiers=("hdd", "ssd"), n_images=48, mean_hw=(48, 48),
               thread_counts=(1, 4), batch=8, n_batches=4, acfg=ACFG_SMOKE)


if __name__ == "__main__":
    payload = run_smoke() if "--smoke" in sys.argv else run()
    # the paper regime: hiding I/O behind compute must win on the slowest
    # tier's serial config; a gain below 1 means prefetch actively hurt
    ok = payload["overlap_excess_hdd1"] >= 1.0
    print(f"# overlap_excess_hdd1={payload['overlap_excess_hdd1']}x ok={ok}")
    if not ok:
        sys.exit(1)
