"""A tf.data-like input pipeline (paper §II-A / Fig. 2), in pure Python.

The pipeline is a chain of lazily-evaluated nodes::

    Dataset.list_files(storage).shard(n_workers, rank)
        .shuffle(buffer_size, seed)
        .interleave(stream_shard, cycle_length=8,      # parallel shard streaming
                    block_length=16, num_parallel_calls=8)
        .map_and_batch(decode_into, 64,                # fused decode-into-buffer
                       num_parallel_calls=8)
        .prefetch(1)                                   # background thread

Semantics follow the paper's description of the TF Dataset API:

* ``map(num_parallel_calls=k)`` keeps ``k`` elements in flight on the shared
  :class:`~repro.core.readerpool.ReaderPool`.  ``deterministic=True``
  (default) yields results in input order — like TF — by maintaining a
  window of futures; ``False`` yields in completion order via
  ``wait(FIRST_COMPLETED)`` (lower latency jitter, straggler mitigation).
* ``interleave`` is tf.data's ``parallel_interleave``: ``cycle_length``
  input elements are expanded to sub-streams consumed round-robin,
  ``block_length`` elements at a time; with ``num_parallel_calls`` the next
  block of each cycle slot is fetched on the reader pool while earlier
  slots' blocks are being consumed.  Output order is deterministic
  (independent of thread timing).
* ``map_and_batch`` is the fused tf.contrib path: elements decode directly
  into a preallocated ``(batch, *out_shape)`` buffer — no per-element
  ``np.asarray`` + ``np.stack`` — with error slots refilled from upstream
  when ``ignore_errors=True``.
* ``shard(n, i)`` keeps every n-th element (multi-worker data sharding).
* ``shuffle`` is TF's streaming buffer shuffle: fill a ``buffer_size``
  reservoir, emit a uniformly random element, refill.
* ``batch`` stacks ``n`` consecutive elements (pytree-aware) with one
  allocation per batch.
* ``prefetch`` inserts the background-thread prefetcher (see prefetcher.py).
* ``cache`` memoizes the upstream stream in host memory after epoch 1
  (paper §IV-B: "after the first epoch all samples ... cached in memory").
* ``ignore_errors`` drops elements whose map fn raised (tf.contrib.data.
  ignore_errors), so corrupt records don't kill a large run.

Iterators are closeable end-to-end: ``iter(ds)`` returns an iterator whose
``close()`` propagates through every node down to prefetcher background
threads and in-flight reader-pool futures, so an abandoned pipeline releases
its resources immediately instead of waiting for GC.
"""
from __future__ import annotations

import inspect
import itertools
import random
import threading
from collections import deque
from concurrent.futures import FIRST_COMPLETED, Future
from concurrent.futures import wait as futures_wait
from typing import Any, Callable, Iterable, Iterator, List, Optional, Sequence

import numpy as np

from .. import metrics, trace
from .prefetcher import PrefetchIterator
from .readerpool import reader_pool


class _ErrorMarker:
    """Carries an element-level failure downstream (TF semantics: the error
    surfaces at the iterator unless ``ignore_errors()`` drops it)."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


def _close_iter(it: Any) -> None:
    """Propagate close to any iterator that supports it (generators,
    PrefetchIterator, _RaisingIterator)."""
    close = getattr(it, "close", None)
    if close is not None:
        close()


class _RaisingIterator:
    """Terminal iterator: unwraps :class:`_ErrorMarker` into raises and
    forwards ``close()`` up the node chain."""

    __slots__ = ("_it",)

    def __init__(self, it: Iterator):
        self._it = it

    def __iter__(self) -> "_RaisingIterator":
        return self

    def __next__(self) -> Any:
        item = next(self._it)
        if isinstance(item, _ErrorMarker):
            raise item.exc
        return item

    def close(self) -> None:
        _close_iter(self._it)

    def __enter__(self) -> "_RaisingIterator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _raising(it: Iterator) -> Iterator:
    return _RaisingIterator(it)


def _take_future(window: List[Future], deterministic: bool) -> Future:
    """Next future to consume: input order, or first-completed order."""
    if deterministic or len(window) == 1:
        return window.pop(0)
    done, _ = futures_wait(window, return_when=FIRST_COMPLETED)
    for i, f in enumerate(window):
        if f in done:
            return window.pop(i)
    return window.pop(0)  # unreachable: wait() returned at least one


class _InterleaveSlot:
    """One cycle slot: an input element and its lazily-opened sub-iterator."""

    __slots__ = ("item", "it")

    def __init__(self, item: Any):
        self.item = item
        self.it: Optional[Iterator] = None


def _shard_key(item: Any) -> Any:
    """Stable identity for a pipeline input element: the shard path for
    ``(path, labels)`` tuples, the element itself otherwise."""
    if isinstance(item, tuple) and item and isinstance(item[0], str):
        return item[0]
    return item


class ShardQuarantine:
    """Cross-epoch registry of shards that failed mid-stream.

    ``interleave(quarantine=...)`` records every shard whose open or read
    failed (after any retry budget underneath is exhausted).  On the next
    epoch, instead of silently re-paying the failure, the engine
    *probe-reads* each quarantined shard as it comes up: one cheap record
    pull through the same ``fn``.  A shard that heals (the fault was
    transient at a longer horizon — an OST failover finished, a flaky mount
    recovered) is **re-admitted** and streams normally again, counted in
    ``pipeline.readmitted_shards``; one that is still bad is skipped for
    the rest of the epoch without burning its full retry budget.

    Thread-safe; share one instance across epochs (and pipelines) for the
    same corpus.  ``key`` maps an input element to its stable identity
    (default: the shard path).
    """

    def __init__(self, key: Callable[[Any], Any] = _shard_key):
        self._key = key
        self._lock = threading.Lock()
        self._bad: dict = {}            # key -> repr(last error)
        self.readmitted = 0             # attribute mirror of the live counter

    def quarantine(self, item: Any, exc: BaseException) -> None:
        with self._lock:
            self._bad[self._key(item)] = repr(exc)

    def is_quarantined(self, item: Any) -> bool:
        with self._lock:
            return self._key(item) in self._bad

    def readmit(self, item: Any) -> None:
        with self._lock:
            if self._bad.pop(self._key(item), None) is not None:
                self.readmitted += 1

    def quarantined(self) -> List[Any]:
        """Currently-quarantined keys (snapshot)."""
        with self._lock:
            return list(self._bad)

    def __len__(self) -> int:
        with self._lock:
            return len(self._bad)


class Dataset:
    """Lazily-evaluated pipeline node; iterate to pull elements through."""

    def __init__(self, gen_fn: Callable[[], Iterator]):
        self._gen_fn = gen_fn

    # -- sources ---------------------------------------------------------------
    @staticmethod
    def from_tensor_slices(items: Sequence) -> "Dataset":
        items = list(items)
        return Dataset(lambda: iter(items))

    @staticmethod
    def list_files(storage, dirpath: str = ".", suffix: str = ".rrf") -> "Dataset":
        # sorted: storage listdir order is backend-dependent (POSIX readdir,
        # object-store listing, ...) — a fixed seed must shuffle the same
        # file sequence on every backend for reproducible epochs.
        names = sorted(n for n in storage.listdir(dirpath) if n.endswith(suffix))
        if dirpath not in (".", ""):
            names = [f"{dirpath}/{n}" for n in names]
        return Dataset.from_tensor_slices(names)

    @staticmethod
    def range(n: int) -> "Dataset":
        return Dataset(lambda: iter(range(n)))

    # -- transformations -------------------------------------------------------
    def shard(self, num_shards: int, index: int) -> "Dataset":
        """Keep elements whose position ``% num_shards == index`` (tf.data
        ``Dataset.shard``): disjoint per-worker subsets that cover the input."""
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        if not 0 <= index < num_shards:
            raise ValueError(f"index {index} out of range [0, {num_shards})")
        upstream = self._gen_fn

        def gen():
            it = upstream()
            try:
                for i, item in enumerate(it):
                    if i % num_shards == index:
                        yield item
            finally:
                _close_iter(it)

        return Dataset(gen)

    def shuffle(self, buffer_size: int, seed: Optional[int] = None) -> "Dataset":
        upstream = self._gen_fn

        def gen():
            rng = random.Random(seed)
            buf: List[Any] = []
            it = upstream()
            try:
                for item in it:
                    buf.append(item)
                    if len(buf) >= buffer_size:
                        idx = rng.randrange(len(buf))
                        buf[idx], buf[-1] = buf[-1], buf[idx]
                        yield buf.pop()
                while buf:
                    idx = rng.randrange(len(buf))
                    buf[idx], buf[-1] = buf[-1], buf[idx]
                    yield buf.pop()
            finally:
                _close_iter(it)

        return Dataset(gen)

    def map(
        self,
        fn: Callable[[Any], Any],
        num_parallel_calls: int = 1,
        deterministic: bool = True,
    ) -> "Dataset":
        upstream = self._gen_fn
        fn_label = getattr(fn, "__name__", "map_fn")

        def safe_fn(item):
            # one decode-stage span per element; nested storage_read spans
            # (from fn's read_file call) attribute the I/O share of this time
            with trace.span(trace.STAGE_DECODE, fn_label), \
                    metrics.timer("pipeline.decode_s"):
                try:
                    out = fn(item)
                except Exception as e:  # surfaced at the iterator (TF semantics)
                    return _ErrorMarker(e)
                metrics.inc("pipeline.records")
                return out

        if num_parallel_calls <= 1:
            def gen_serial():
                it = upstream()
                try:
                    for item in it:
                        yield safe_fn(item)
                finally:
                    _close_iter(it)
            return Dataset(gen_serial)

        def gen_parallel():
            # shared pool, sized once; the window caps this stage's in-flight
            # work at num_parallel_calls even when the pool is larger
            pool = reader_pool(num_parallel_calls)
            src = upstream()
            window: List[Future] = []
            try:
                # prime the window
                for item in src:
                    window.append(pool.submit(safe_fn, item))
                    if len(window) >= num_parallel_calls:
                        break
                for item in src:
                    fut = _take_future(window, deterministic)
                    window.append(pool.submit(safe_fn, item))
                    yield fut.result()
                while window:
                    yield _take_future(window, deterministic).result()
            finally:
                for f in window:
                    f.cancel()
                _close_iter(src)

        return Dataset(gen_parallel)

    def interleave(
        self,
        fn: Callable[[Any], Iterable],
        cycle_length: int = 4,
        block_length: int = 1,
        num_parallel_calls: int = 0,
        quarantine: Optional[ShardQuarantine] = None,
    ) -> "Dataset":
        """Expand each input element to a sub-stream via ``fn`` and interleave
        ``cycle_length`` of them round-robin, ``block_length`` elements at a
        time (tf.data ``parallel_interleave``).

        With ``num_parallel_calls > 1`` the next block of up to
        ``min(cycle_length, num_parallel_calls)`` slots is fetched on the
        shared reader pool while earlier blocks are consumed — so e.g. eight
        ``.rrf`` shards stream concurrently record-by-record instead of one
        whole file per element.  Each slot has at most one outstanding fetch,
        which serializes its sub-iterator without locks.  Output order is
        deterministic regardless of thread timing.

        Errors (``fn`` raising, or a sub-iterator raising mid-stream) become
        element-level markers: the failing slot is retired and the rest of
        the cycle keeps streaming, so one corrupt shard doesn't kill the
        epoch when ``ignore_errors()`` is downstream.

        With a :class:`ShardQuarantine`, failed elements are additionally
        recorded by identity; on later epochs quarantined elements are
        probe-read before re-entering the cycle — healed shards re-admit
        (``pipeline.readmitted_shards``), still-bad ones are skipped for
        the epoch.
        """
        if cycle_length < 1:
            raise ValueError(f"cycle_length must be >= 1, got {cycle_length}")
        if block_length < 1:
            raise ValueError(f"block_length must be >= 1, got {block_length}")
        upstream = self._gen_fn
        fn_label = getattr(fn, "__name__", "interleave_fn")

        def _fetch_block(slot: _InterleaveSlot):
            """Pull up to block_length elements from one slot (pool task).

            Returns ``(values, exhausted)``; per-element failures append a
            marker and retire the slot."""
            with trace.span(trace.STAGE_DECODE, fn_label), \
                    metrics.timer("pipeline.interleave_block_s"):
                out: List[Any] = []
                if slot.it is None:
                    try:
                        slot.it = iter(fn(slot.item))
                    except Exception as e:
                        # a shard we could not even open is dropped from the
                        # cycle — with a RetryingStorage underneath, the
                        # error arriving here means the retry budget is
                        # already exhausted
                        metrics.inc("pipeline.quarantined_shards")
                        if quarantine is not None:
                            quarantine.quarantine(slot.item, e)
                        return [_ErrorMarker(e)], True
                for _ in range(block_length):
                    try:
                        out.append(next(slot.it))
                    except StopIteration:
                        return out, True
                    except Exception as e:
                        metrics.inc("pipeline.quarantined_shards")
                        if quarantine is not None:
                            quarantine.quarantine(slot.item, e)
                        out.append(_ErrorMarker(e))
                        return out, True
                return out, False

        def _probe_readmit(item) -> bool:
            """One cheap open + single-record pull of a quarantined shard.
            True ⇒ healed (caller re-admits); False ⇒ still bad, skip."""
            it = None
            try:
                it = iter(fn(item))
                next(it, None)
                return True
            except Exception:
                return False
            finally:
                _close_iter(it)

        parallel = num_parallel_calls > 1
        window = min(cycle_length, num_parallel_calls) if parallel else 0

        def gen():
            pool = reader_pool(num_parallel_calls) if parallel else None
            src = upstream()
            cycle: deque = deque()      # slots in round-robin order
            futs: dict = {}             # slot -> in-flight block fetch
            src_done = False
            try:
                while True:
                    while len(cycle) < cycle_length and not src_done:
                        try:
                            nxt = next(src)
                        except StopIteration:
                            src_done = True
                            break
                        if isinstance(nxt, _ErrorMarker):
                            yield nxt
                            continue
                        if quarantine is not None and \
                                quarantine.is_quarantined(nxt):
                            if _probe_readmit(nxt):
                                quarantine.readmit(nxt)
                                metrics.inc("pipeline.readmitted_shards")
                            else:
                                continue    # still bad: skip this epoch
                        cycle.append(_InterleaveSlot(nxt))
                    if not cycle:
                        return
                    if pool is not None:
                        for s in itertools.islice(cycle, 0, window):
                            if s not in futs:
                                futs[s] = pool.submit(_fetch_block, s)
                    slot = cycle.popleft()
                    if pool is not None:
                        fut = futs.pop(slot, None)
                        if fut is None:
                            fut = pool.submit(_fetch_block, slot)
                        vals, exhausted = fut.result()
                    else:
                        vals, exhausted = _fetch_block(slot)
                    if not exhausted:
                        cycle.append(slot)
                    yield from vals
            finally:
                for f in futs.values():
                    f.cancel()
                # cancel() cannot stop RUNNING fetches — wait them out so no
                # pool worker is still inside next(slot.it) when we close the
                # sub-iterators (generator.close() from another thread would
                # raise "generator already executing" and abort the teardown)
                if futs:
                    futures_wait(list(futs.values()))
                for s in cycle:
                    _close_iter(s.it)
                _close_iter(src)

        return Dataset(gen)

    def ignore_errors(self) -> "Dataset":
        upstream = self._gen_fn

        def gen():
            it = upstream()
            try:
                for item in it:
                    if isinstance(item, _ErrorMarker):
                        # live drop-rate signal (a corpus going bad shows up
                        # here long before accuracy does)
                        metrics.inc("pipeline.dropped")
                        continue
                    yield item
            finally:
                _close_iter(it)

        return Dataset(gen)

    def batch(self, batch_size: int, drop_remainder: bool = True) -> "Dataset":
        upstream = self._gen_fn

        def _stack(elems: List[Any]):
            first = elems[0]
            if isinstance(first, tuple):
                return tuple(
                    _stack([e[i] for e in elems]) for i in range(len(first))
                )
            if isinstance(first, dict):
                return {k: _stack([e[k] for e in elems]) for k in first}
            if isinstance(first, np.ndarray):
                # one allocation + per-element copy into it (no asarray churn)
                out = np.empty((len(elems),) + first.shape, first.dtype)
                for i, e in enumerate(elems):
                    out[i] = e
                return out
            return np.asarray(elems)

        def gen():
            buf: List[Any] = []
            it = _raising(upstream())
            try:
                for item in it:
                    buf.append(item)
                    if len(buf) == batch_size:
                        yield _stack(buf)
                        buf = []
                if buf and not drop_remainder:
                    yield _stack(buf)
            finally:
                _close_iter(it)

        return Dataset(gen)

    def map_and_batch(
        self,
        fn: Callable[[Any, np.ndarray], Any],
        batch_size: int,
        *,
        num_parallel_calls: int = 1,
        drop_remainder: bool = True,
        out_shape: Sequence[int] = (),
        out_dtype: Any = np.float32,
        ignore_errors: bool = False,
    ) -> "Dataset":
        """Fused map+batch (tf.contrib.data ``map_and_batch``): ``fn(item,
        out)`` decodes each element *directly into its row of a preallocated*
        ``(batch_size, *out_shape)`` buffer and returns an optional auxiliary
        scalar (e.g. the label).

        Batches are the buffer alone, or ``(buffer, np.asarray(auxes))`` when
        ``fn`` returns non-None — no per-element ``np.asarray``/``np.stack``
        ever runs.  With ``num_parallel_calls > 1``, up to that many rows
        fill concurrently on the shared reader pool.  ``ignore_errors=True``
        gives the fused equivalent of ``map().ignore_errors().batch()``: a
        failed row is refilled from the next upstream element (same element
        multiset as the legacy chain; row order within the batch may differ
        after a failure).
        """
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        upstream = self._gen_fn
        fn_label = getattr(fn, "__name__", "map_and_batch_fn")
        out_shape = tuple(out_shape)

        class _Exhausted(Exception):
            pass

        def _next_item(src):
            while True:
                try:
                    item = next(src)
                except StopIteration:
                    raise _Exhausted from None
                if isinstance(item, _ErrorMarker):
                    if ignore_errors:
                        metrics.inc("pipeline.dropped")
                        continue
                    raise item.exc
                return item

        def _row(buf, i):
            # 0-d rows need an explicit view: buf[i] on a 1-D buffer is a
            # scalar copy, so fn's writes would be lost
            return buf[i] if out_shape else buf[i:i + 1].reshape(())

        def _run(item, row):
            with trace.span(trace.STAGE_DECODE, fn_label), \
                    metrics.timer("pipeline.decode_s"):
                out = fn(item, row)
            metrics.inc("pipeline.records")
            return out

        def _assemble(buf, aux, rows):
            """Finalize one batch from the filled row indices."""
            if len(rows) < buf.shape[0]:
                rows = sorted(rows)
                buf = buf[rows]
                aux = [aux[i] for i in rows]
            if all(a is None for a in aux):
                return buf
            return buf, np.asarray(aux)

        def gen_serial():
            src = upstream()
            try:
                while True:
                    buf = np.empty((batch_size,) + out_shape, out_dtype)
                    aux: List[Any] = [None] * batch_size
                    filled: List[int] = []
                    try:
                        for i in range(batch_size):
                            while True:
                                item = _next_item(src)
                                try:
                                    aux[i] = _run(item, _row(buf, i))
                                except Exception as e:
                                    if ignore_errors:
                                        metrics.inc("pipeline.dropped")
                                        continue
                                    yield _ErrorMarker(e)
                                    return
                                filled.append(i)
                                break
                    except _Exhausted:
                        if filled and not drop_remainder:
                            yield _assemble(buf, aux, filled)
                        return
                    yield _assemble(buf, aux, filled)
            finally:
                _close_iter(src)

        if num_parallel_calls <= 1:
            return Dataset(gen_serial)

        def gen_parallel():
            pool = reader_pool(num_parallel_calls)
            src = upstream()
            try:
                exhausted = False
                while not exhausted:
                    buf = np.empty((batch_size,) + out_shape, out_dtype)
                    aux: List[Any] = [None] * batch_size
                    filled: List[int] = []
                    to_fill: deque = deque(range(batch_size))
                    inflight: dict = {}  # future -> row index
                    error: Optional[BaseException] = None
                    while (to_fill or inflight) and error is None:
                        while (to_fill and not exhausted
                               and len(inflight) < num_parallel_calls):
                            row = to_fill.popleft()
                            try:
                                item = _next_item(src)
                            except _Exhausted:
                                exhausted = True
                                break
                            inflight[pool.submit(_run, item, _row(buf, row))] = row
                        if not inflight:
                            break
                        done, _ = futures_wait(
                            inflight, return_when=FIRST_COMPLETED)
                        for f in done:
                            row = inflight.pop(f)
                            exc = f.exception()
                            if exc is None:
                                aux[row] = f.result()
                                filled.append(row)
                            elif ignore_errors:
                                metrics.inc("pipeline.dropped")
                                to_fill.append(row)  # refill from upstream
                            elif error is None:
                                error = exc
                    if error is not None:
                        for f in inflight:
                            f.cancel()
                        futures_wait(list(inflight))  # rows may still be writing
                        yield _ErrorMarker(error)
                        return
                    if len(filled) == batch_size or (filled and not drop_remainder):
                        yield _assemble(buf, aux, filled)
            finally:
                _close_iter(src)

        return Dataset(gen_parallel)

    def repeat(self, count: Optional[int] = None) -> "Dataset":
        upstream = self._gen_fn

        def gen():
            i = 0
            while count is None or i < count:
                it = upstream()
                try:
                    yield from it
                finally:
                    _close_iter(it)
                i += 1

        return Dataset(gen)

    def take(self, n: int) -> "Dataset":
        upstream = self._gen_fn

        def gen():
            it = upstream()
            try:
                for _ in range(n):
                    try:
                        yield next(it)
                    except StopIteration:
                        return
            finally:
                _close_iter(it)

        return Dataset(gen)

    def cache(self) -> "Dataset":
        upstream = self._gen_fn
        memo: dict = {"items": None, "lock": threading.Lock()}

        def gen():
            with memo["lock"]:
                cached = memo["items"]
            if cached is not None:
                yield from cached
                return
            # epoch 1 (possibly concurrent with another epoch-1 iterator:
            # each computes independently; a partial iteration never
            # publishes, so the memo only ever holds a complete stream)
            items = []
            it = upstream()
            try:
                for item in it:
                    items.append(item)
                    yield item
            finally:
                _close_iter(it)
            with memo["lock"]:
                if memo["items"] is None:
                    memo["items"] = items

        return Dataset(gen)

    def prefetch(self, buffer_size: int = 1) -> "Dataset":
        if buffer_size <= 0:
            return self
        upstream = self._gen_fn
        return Dataset(lambda: PrefetchIterator(upstream(), buffer_size))

    # -- sinks -------------------------------------------------------------------
    def __iter__(self) -> Iterator:
        """Closeable iterator: ``it.close()`` (or ``with iter(ds) as it:``)
        tears down prefetch threads and in-flight reader-pool work."""
        return _raising(self._gen_fn())

    def as_numpy(self) -> List[Any]:
        return list(self)


def _accepts_start(factory: Callable) -> bool:
    """True if ``factory`` can be called as ``factory(epoch, start)`` —
    the seekable-pipeline contract of :class:`ResumableIterator`."""
    try:
        sig = inspect.signature(factory)
    except (TypeError, ValueError):
        return False
    params = list(sig.parameters.values())
    if any(p.kind is p.VAR_POSITIONAL for p in params):
        return True
    positional = [p for p in params
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    if len(positional) >= 2:
        return True
    return any(p.name == "start" and p.kind is p.KEYWORD_ONLY
               for p in params)


def interleave_order(counts: Sequence[int], cycle_length: int = 4,
                     block_length: int = 1) -> List[tuple]:
    """Arithmetic replica of :meth:`Dataset.interleave` delivery order.

    Given per-source element ``counts`` (sources in upstream order),
    returns the exact global delivery order as ``(source_index,
    element_index)`` pairs — the order the real interleave produces when
    every sub-stream is error-free.  Zero I/O: this is how a seekable
    pipeline (:func:`sharded_record_dataset`) converts a flat resume
    offset into per-shard read positions.

    Faithful to one subtlety of the real operator: exhaustion is only
    observed on ``StopIteration``, so a source whose remaining count is an
    exact ``block_length`` multiple is re-appended after its last full
    block and occupies one extra (empty) cycle turn before retiring.
    """
    if cycle_length < 1:
        raise ValueError(f"cycle_length must be >= 1, got {cycle_length}")
    if block_length < 1:
        raise ValueError(f"block_length must be >= 1, got {block_length}")
    order: List[tuple] = []
    remaining = [int(c) for c in counts]
    pos = [0] * len(remaining)
    cycle: deque = deque()
    nxt = 0
    while True:
        while len(cycle) < cycle_length and nxt < len(remaining):
            cycle.append(nxt)
            nxt += 1
        if not cycle:
            return order
        s = cycle.popleft()
        take = min(block_length, remaining[s])
        for _ in range(take):
            order.append((s, pos[s]))
            pos[s] += 1
        remaining[s] -= take
        if take == block_length:
            # a full block: StopIteration not yet observed — the slot stays
            # in the cycle even if it is now empty (one extra empty turn)
            cycle.append(s)


def sharded_record_dataset(storage, paths: Sequence[str], rec_bytes: int, *,
                           cycle_length: int = 4, block_length: int = 4,
                           num_parallel_calls: int = 0, seed: int = 0,
                           start: int = 0) -> Dataset:
    """Interleaved fixed-size-record shard streaming with O(1) seek.

    The fig13 read-engine shape: shard paths are buffer-shuffled by
    ``seed``, then ``cycle_length`` shards stream concurrently
    record-by-record (``rec_bytes`` per ``read_range``, short final
    record allowed), ``block_length`` records per cycle turn.

    ``start`` positions the stream *arithmetically*: the shuffled shard
    order is replayed over the path list (pure Python, zero I/O), record
    counts come from ``storage.size`` (unpaced metadata), and
    :func:`interleave_order` maps the flat offset to per-shard positions —
    so resuming deep into an epoch costs a handful of ``size`` calls, not
    a replay of every skipped record.  Use as a seekable
    :class:`ResumableIterator` factory::

        it = ResumableIterator(
            lambda ep, start=0: sharded_record_dataset(
                storage, paths, rec_bytes, seed=ep, start=start))

    The two paths deliver byte-identical element sequences: the ``start``
    path reads exactly the records the ``start=0`` interleave would have
    delivered from that offset on, in the same order.
    """
    shard_order = list(
        Dataset.from_tensor_slices(list(paths))
        .shuffle(max(len(paths), 1), seed=seed))

    if start <= 0:
        def stream_shard(path):
            def gen():
                size = storage.size(path)
                for off in range(0, size, rec_bytes):
                    yield storage.read_range(path, off,
                                             min(rec_bytes, size - off))
            return gen()

        return (Dataset.from_tensor_slices(list(paths))
                .shuffle(max(len(paths), 1), seed=seed)
                .interleave(stream_shard, cycle_length=cycle_length,
                            block_length=block_length,
                            num_parallel_calls=num_parallel_calls))

    # seek path: rebuild the delivery order arithmetically, skip `start`
    # entries by slicing (no data I/O), and read only the tail
    sizes = [storage.size(p) for p in shard_order]
    counts = [(sz + rec_bytes - 1) // rec_bytes for sz in sizes]
    order = interleave_order(counts, cycle_length, block_length)

    def gen_spans():
        for s, i in itertools.islice(iter(order), start, None):
            off = i * rec_bytes
            yield (shard_order[s], off, min(rec_bytes, sizes[s] - off))

    spans = Dataset(gen_spans)
    reader = lambda t: storage.read_range(*t)  # noqa: E731
    reader.__name__ = "read_record"
    return spans.map(reader,
                     num_parallel_calls=max(num_parallel_calls, 1))


class ResumableIterator:
    """Epoch-aware iterator with a lightweight save/restore position.

    The tf.data-style iterator checkpoint: position is ``{"epoch": e,
    "offset": k}`` — *k elements of epoch e already delivered to the
    consumer*.  :meth:`state` is cheap enough to attach to every checkpoint
    (the trainer stores it in ``extra_meta["pipeline"]``);
    :meth:`restore_state` re-opens epoch ``e`` and deterministically skips
    ``k`` elements, so a resumed run neither skips nor replays samples.

    ``source`` is either a :class:`Dataset` (re-iterated per epoch — same
    element order every epoch) or a factory ``epoch -> Dataset`` for
    per-epoch seeding (``lambda ep: pipeline(seed=base_seed + ep)``); with
    a factory, skip-based restore still lands on the exact element because
    the factory rebuilds epoch ``e``'s order from its seed.  The offset
    counts elements *delivered through this iterator*: keep it downstream
    of ``prefetch`` (wrap the whole pipeline) so buffered-but-unconsumed
    elements are not counted as seen.

    **O(1) seek**: a factory that also accepts a start offset —
    ``(epoch, start) -> Dataset`` yielding epoch ``e``'s stream *from
    element* ``start`` (e.g. built on :func:`sharded_record_dataset`,
    which positions arithmetically instead of reading) — upgrades
    :meth:`restore_state` from O(offset) replay to a direct seek: the
    factory is opened at the checkpointed offset and no skipped element
    is ever produced, so resume cost is independent of how deep into the
    epoch the checkpoint was.  Seekability is detected from the factory's
    signature; :meth:`state` then carries ``"seek": True`` so a restore
    on a non-seekable pipeline of the same corpus still works (it falls
    back to replay).

    Determinism caveat: skip-restore replays the pipeline's element order,
    which is deterministic for ``deterministic=True`` stages (the default);
    under ``ignore_errors`` the offset counts *surviving* elements, so a
    fault that is present in one run and absent in the replay shifts the
    alignment — exactly tf.data's contract.
    """

    def __init__(self, source, *, epochs: Optional[int] = None):
        if isinstance(source, Dataset):
            self._factory = lambda epoch: source
            self._seekable = False
        elif callable(source):
            self._factory = source
            self._seekable = _accepts_start(source)
        else:
            raise TypeError(
                f"source must be a Dataset or epoch->Dataset factory, "
                f"got {type(source).__name__}")
        self.epochs = epochs
        self._epoch = 0
        self._offset = 0
        self._it: Optional[Iterator] = None
        self._done = False

    # -- position ----------------------------------------------------------------
    def state(self) -> dict:
        """Snapshot the position (JSON-serializable, O(1))."""
        s = {"epoch": self._epoch, "offset": self._offset, "version": 1}
        if self._seekable:
            s["seek"] = True
        return s

    def _open_epoch(self, epoch: int, start: int = 0) -> Iterator:
        if start > 0 and self._seekable:
            return iter(self._factory(epoch, start))
        return iter(self._factory(epoch))

    def restore_state(self, state: dict) -> None:
        """Re-open at ``state``: a direct seek when the factory supports a
        start offset, else by skipping already-delivered elements."""
        self.close()
        self._epoch = int(state["epoch"])
        self._offset = 0
        self._done = False
        target = int(state["offset"])
        if target > 0 and self._seekable:
            # O(1) reposition: the factory opens epoch `epoch` already
            # advanced past the first `target` elements (no replay I/O).
            # A target beyond the epoch end yields an empty tail; the
            # nonzero offset makes __next__ roll the epoch naturally.
            self._it = self._open_epoch(self._epoch, target)
            self._offset = target
            metrics.inc("pipeline.resume_seeks")
            return
        self._it = self._open_epoch(self._epoch)
        with trace.span(trace.STAGE_DATA_WAIT,
                        f"resume_skip:{target}@epoch{self._epoch}"):
            for _ in range(target):
                try:
                    next(self._it)
                except StopIteration:
                    # position beyond epoch end (e.g. the corpus shrank):
                    # roll into the next epoch rather than fail the resume
                    break
                self._offset += 1
        metrics.inc("pipeline.resume_skipped", self._offset)

    # -- iteration ---------------------------------------------------------------
    def __iter__(self) -> "ResumableIterator":
        return self

    def __next__(self) -> Any:
        if self._done:
            raise StopIteration
        if self._it is None:
            self._it = self._open_epoch(self._epoch)
        while True:
            try:
                item = next(self._it)
            except StopIteration:
                _close_iter(self._it)
                self._it = None
                empty_epoch = self._offset == 0
                self._epoch += 1
                self._offset = 0
                if (self.epochs is not None and self._epoch >= self.epochs) \
                        or empty_epoch:
                    # empty epoch: the source is exhausted/empty — stop
                    # instead of spinning on zero-element epochs forever
                    self._done = True
                    raise
                self._it = self._open_epoch(self._epoch)
                continue
            self._offset += 1
            return item

    def close(self) -> None:
        if self._it is not None:
            _close_iter(self._it)
            self._it = None

    def __enter__(self) -> "ResumableIterator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def image_pipeline(
    storage,
    paths: Sequence[str],
    labels: Optional[Sequence[int]] = None,
    *,
    batch_size: int = 64,
    num_parallel_calls: int = 4,
    prefetch: int = 1,
    shuffle_buffer: int = 1024,
    out_hw: tuple = (224, 224),
    seed: int = 0,
    preprocess: bool = True,
    repeat: bool = False,
    channels: int = 3,
    vectorized: bool = True,
) -> Dataset:
    """The paper's full input pipeline (Fig. 2) over an image-file corpus.

    ``vectorized=True`` (default) runs the fused ``map_and_batch`` path:
    zero-copy record decode, LUT-gather resize with the dtype conversion
    folded in, rows written straight into the batch buffer.
    ``vectorized=False`` keeps the seed per-element ``map -> ignore_errors ->
    batch`` chain (the fig11 baseline).
    """
    from . import records

    if labels is not None:
        src = Dataset.from_tensor_slices(list(zip(paths, labels)))
    else:
        src = Dataset.from_tensor_slices(list(paths))

    ds = src.shuffle(shuffle_buffer, seed=seed)
    if repeat:
        ds = ds.repeat()

    if preprocess and vectorized:
        if labels is not None:
            def load_into(item, out):
                path, label = item
                blob = storage.read_file(path)                   # tf.read_file
                payload = records.decode_single_record(blob, copy=False)
                records.preprocess_image_into(payload, out)
                return np.int32(label)
        else:
            def load_into(path, out):
                blob = storage.read_file(path)
                payload = records.decode_single_record(blob, copy=False)
                records.preprocess_image_into(payload, out)
                return None

        ds = ds.map_and_batch(
            load_into, batch_size, num_parallel_calls=num_parallel_calls,
            out_shape=(*out_hw, channels), out_dtype=np.float32,
            ignore_errors=True, drop_remainder=True)
    else:
        if labels is not None:
            def load(item):
                path, label = item
                blob = storage.read_file(path)                   # tf.read_file
                payload = records.decode_single_record(blob)
                if preprocess:
                    img = records.preprocess_image(payload, *out_hw)
                else:
                    img = np.frombuffer(payload, dtype=np.uint8)  # read-only
                return img, np.int32(label)
        else:
            def load(path):
                blob = storage.read_file(path)
                payload = records.decode_single_record(blob)
                if preprocess:
                    return records.preprocess_image(payload, *out_hw)
                return np.frombuffer(payload, dtype=np.uint8)

        ds = ds.map(load, num_parallel_calls=num_parallel_calls)
        ds = ds.ignore_errors()
        ds = ds.batch(batch_size, drop_remainder=True)

    if prefetch:
        ds = ds.prefetch(prefetch)
    return ds


def sharded_image_pipeline(
    storage,
    shard_paths: Sequence[str],
    labels_per_shard: Optional[Sequence[Sequence[int]]] = None,
    *,
    batch_size: int = 64,
    cycle_length: int = 4,
    block_length: int = 8,
    num_parallel_calls: int = 4,
    prefetch: int = 1,
    out_hw: tuple = (224, 224),
    seed: int = 0,
    preprocess: bool = True,
    repeat: bool = False,
    channels: int = 3,
    num_shards: int = 1,
    shard_index: int = 0,
    batched_preprocess: Optional[str] = None,
    cache=None,
    readahead=None,
    quarantine: Optional[ShardQuarantine] = None,
) -> Dataset:
    """High-throughput ingestion over multi-record ``.rrf`` shards.

    The vectorized read engine: shards are shuffled, ``cycle_length`` of
    them stream concurrently record-by-record through ``interleave`` (one
    sequential storage read per shard instead of one seek per image), and
    records decode zero-copy straight into the fused ``map_and_batch``
    buffer.  ``num_shards``/``shard_index`` apply ``Dataset.shard`` for
    multi-worker disjoint coverage.

    ``batched_preprocess`` switches resize+convert from per-record-on-host
    to whole-batch: ``"numpy"`` uses the batched LUT gather, ``"pallas"``
    the fused device kernel (:func:`repro.kernels.preprocess.
    resize_convert_images`).  Both require a uniform-size corpus
    (``write_sharded_image_dataset(hw_jitter=0)``).

    ``cache`` serves shard reads through a block cache: pass a
    :class:`~repro.core.cache.BlockCache` (wrapped here) or a ready-made
    :class:`~repro.core.cache.CachingStorage` — warm epochs then stream
    from DRAM (and the spill tier, if configured) instead of re-reading
    the device.  ``readahead`` prefetches upcoming shards' blocks ahead
    of the interleave cursor: a :class:`~repro.core.cache.
    ReadaheadScheduler`, or ``True``/an int window to build one over the
    cache (requires ``cache``).  ``quarantine`` enables cross-epoch shard
    quarantine with probe-read re-admission (see :class:`ShardQuarantine`).
    """
    from . import records

    if cache is not None:
        from .cache import BlockCache, CachingStorage
        if isinstance(cache, CachingStorage):
            storage = cache
        elif isinstance(cache, BlockCache):
            storage = CachingStorage(storage, cache)
        else:
            raise TypeError(
                f"cache= expects BlockCache or CachingStorage, got "
                f"{type(cache).__name__}")

    scheduler = None
    if readahead is not None and readahead is not False:
        from .cache import CachingStorage, ReadaheadScheduler
        if isinstance(readahead, ReadaheadScheduler):
            scheduler = readahead
        else:
            if not isinstance(storage, CachingStorage):
                raise TypeError("readahead= requires cache= (prefetch "
                                "needs a CachingStorage to land blocks in)")
            window = 8 if readahead is True else int(readahead)
            scheduler = ReadaheadScheduler(storage, window=window)

    if labels_per_shard is not None:
        items: List[Any] = [
            (p, list(ls)) for p, ls in zip(shard_paths, labels_per_shard)
        ]
    else:
        items = list(shard_paths)

    src = Dataset.from_tensor_slices(items)
    if num_shards > 1:
        src = src.shard(num_shards, shard_index)
    src = src.shuffle(max(len(items), 1), seed=seed)
    if repeat:
        src = src.repeat()

    if scheduler is not None:
        # lookahead node: announce each shard to the readahead scheduler
        # `lookahead_shards` positions before the interleave cursor reaches
        # it, so its blocks are (being) cached by the time it streams
        upstream = src._gen_fn
        lookahead = scheduler.lookahead_shards

        def gen_readahead():
            it = upstream()
            buf: deque = deque()
            try:
                for item in it:
                    if not isinstance(item, _ErrorMarker):
                        scheduler.schedule(_shard_key(item))
                    buf.append(item)
                    if len(buf) > lookahead:
                        yield buf.popleft()
                while buf:
                    yield buf.popleft()
            finally:
                scheduler.clear()   # don't prefetch past an abandoned epoch
                _close_iter(it)

        src = Dataset(gen_readahead)

    if labels_per_shard is not None:
        def stream_shard(item):
            path, labels = item
            blob = storage.read_file(path)          # one sequential shard read
            return zip(records.iter_record_views(blob), labels)
    else:
        def stream_shard(path):
            blob = storage.read_file(path)
            return records.iter_record_views(blob)

    ds = src.interleave(
        stream_shard, cycle_length=cycle_length, block_length=block_length,
        num_parallel_calls=num_parallel_calls, quarantine=quarantine)

    if not preprocess:
        # read-only mode (fig5): element = record byte length
        def record_len(item):
            view = item[0] if labels_per_shard is not None else item
            return np.int64(len(view))

        ds = ds.map(record_len).ignore_errors()
        ds = ds.batch(batch_size, drop_remainder=True)
    elif batched_preprocess:
        # decode raw uint8 on host, resize+convert whole batches at once
        from ..kernels import preprocess as kpre

        if labels_per_shard is not None:
            def decode_raw(item):
                view, label = item
                return records.decode_image(view, copy=False), np.int32(label)
        else:
            def decode_raw(view):
                return records.decode_image(view, copy=False)

        ds = ds.map(decode_raw, num_parallel_calls=num_parallel_calls)
        ds = ds.ignore_errors()
        ds = ds.batch(batch_size, drop_remainder=True)

        def batch_resize(batch):
            if labels_per_shard is not None:
                imgs, labels = batch
                return kpre.resize_convert(
                    imgs, *out_hw, backend=batched_preprocess), labels
            return kpre.resize_convert(batch, *out_hw,
                                       backend=batched_preprocess)

        ds = ds.map(batch_resize)
    else:
        if labels_per_shard is not None:
            def decode_into(item, out):
                view, label = item
                records.preprocess_image_into(view, out)
                return np.int32(label)
        else:
            def decode_into(view, out):
                records.preprocess_image_into(view, out)
                return None

        ds = ds.map_and_batch(
            decode_into, batch_size, num_parallel_calls=num_parallel_calls,
            out_shape=(*out_hw, channels), out_dtype=np.float32,
            ignore_errors=True, drop_remainder=True)

    if prefetch:
        ds = ds.prefetch(prefetch)
    return ds
