"""Fig. 13 (ours): recovery cost — goodput under transient faults, and
time-to-recover from a kill, per storage tier.

The resilience layer's two promises, measured:

* **Goodput under faults**: the interleaved shard pipeline reads the same
  corpus clean and under a transient read-fault rate (default 1%, the
  flaky-device model) absorbed by :class:`~repro.core.retry.
  RetryingStorage`.  ``goodput_frac = faulty samples/s / clean samples/s``
  — retries must absorb the faults *without quarantining shards* (every
  record still arrives; ``gave_up == 0``), at a throughput tax bounded by
  the re-read cost.
* **Time-to-recover**: a training run is killed mid-epoch; recovery is
  :meth:`~repro.core.recovery.CheckpointManager.resume` — restore params
  from the newest valid checkpoint *plus* re-position the
  :class:`~repro.core.dataset.ResumableIterator`.  With the seekable
  shard factory (:func:`~repro.core.dataset.sharded_record_dataset`) the
  reposition is an O(1) arithmetic seek, so ``recover_s`` is dominated by
  the state read and stays near-constant in checkpoint depth;
  ``recover_replay_s`` times the same resume through a replay-only
  factory (the pre-seek baseline, O(offset) in tier read throughput).

Retention is exercised along the way: the training run saves more steps
than ``keep_last`` and the payload records checkpoint files on disk, which
the manager's GC must hold bounded.

Machine-readable ``BENCH_recovery.json``; the CI regression gate covers
the ``samples_per_s`` and ``goodput_frac`` leaves (``recover_s`` is
reported but not gated — lower is better, the gate assumes higher-better).

Acceptance: on the hdd model at a 1% fault rate, goodput >= 0.9x clean
and no shard was quarantined.

    PYTHONPATH=src python -m benchmarks.fig13_recovery [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro import metrics
from repro.core import make_storage
from repro.core.dataset import (Dataset, ResumableIterator,
                                sharded_record_dataset)
from repro.core.faults import FaultyStorage
from repro.core.recovery import CheckpointManager
from repro.core.retry import RetryPolicy, RetryingStorage

from .common import RESULTS_DIR, SCRATCH, emit

TIERS = ("hdd", "ssd", "optane", "lustre")
FAULT_RATE = float(os.environ.get("REPRO_FAULT_RATE", "0.01"))
#: Realistic flaky-device backoff (1 ms base, 10 ms cap) — affordable here
#: because the sleep runs on the simulator's paced clock, not wall time.
RETRY_ATTEMPTS = 5
RETRY_BASE_S = 1e-3
RETRY_MAX_S = 1e-2


def make_policy(sim) -> RetryPolicy:
    """Retry policy whose backoff runs on ``sim``'s scaled clock.

    ``sleep=sim.paced_sleep`` puts the jittered backoff on the same
    ``time_scale`` as the modelled device, so the faulty-path latency tax
    (re-read + backoff) reproduces exactly at any simulation speed instead
    of the backoff staying real-time while the device accelerates."""
    return RetryPolicy(max_attempts=RETRY_ATTEMPTS, base_delay_s=RETRY_BASE_S,
                       max_delay_s=RETRY_MAX_S, sleep=sim.paced_sleep)


def write_corpus(storage, n_shards: int, recs_per_shard: int,
                 rec_bytes: int):
    rng = np.random.default_rng(0)
    paths = []
    for i in range(n_shards):
        blob = rng.integers(0, 256, size=recs_per_shard * rec_bytes,
                            dtype=np.uint8).tobytes()
        p = f"data/shard-{i:04d}.rrf"
        storage.write_file(p, blob)
        paths.append(p)
    return paths


def shard_pipeline(storage, paths, rec_bytes: int, seed: int = 0,
                   start: int = 0) -> Dataset:
    """The vectorized engine shape: interleaved shard streaming.

    Records are fetched one ``read_range`` each so the injected
    per-*read-op* fault rate maps onto a per-*record* fault rate — the
    flaky-device model the retry layer is sized for.  ``start`` (in
    *records*) seeks arithmetically via
    :func:`~repro.core.dataset.sharded_record_dataset` — positioning
    costs ``size`` calls only, no replay reads."""
    return (sharded_record_dataset(storage, paths, rec_bytes,
                                   cycle_length=4, block_length=4,
                                   num_parallel_calls=4, seed=seed,
                                   start=start)
            .map(lambda r: np.int64(len(r)))
            .ignore_errors()
            .batch(8, drop_remainder=False))


def read_all(storage, paths, rec_bytes: int, n_passes: int = 2) -> float:
    """Stream the whole corpus ``n_passes`` times; return samples/s."""
    n = 0
    t0 = time.monotonic()
    for p in range(n_passes):
        for batch in shard_pipeline(storage, paths, rec_bytes, seed=p):
            n += len(batch)
    dt = time.monotonic() - t0
    return n / max(dt, 1e-9)


def make_state(mb: float):
    rng = np.random.default_rng(1)
    n = int(mb * 1024 * 256)
    return {"w": rng.normal(size=(n,)).astype(np.float32),
            "step": np.int64(0)}


def measure_recovery(storage, paths, rec_bytes: int, state_mb: float,
                     keep_last: int, n_saves: int):
    """Kill a run mid-epoch and time CheckpointManager.resume().

    Resume is timed twice from the same checkpoint: through the seekable
    factory (O(1) arithmetic reposition — ``recover_s``) and through a
    replay-only factory of the same corpus (O(offset) skip —
    ``recover_replay_s``), so the seek win is measured, not assumed.

    Returns (recover_s, recover_replay_s, recovered_step,
    ckpt_files_on_disk)."""
    # batch offset -> record offset: the iterator counts delivered batches
    # (8 records each), the seek contract of shard_pipeline is records
    seek_factory = lambda ep, start=0: shard_pipeline(  # noqa: E731
        storage, paths, rec_bytes, seed=ep, start=start * 8)
    n_batches = sum(1 for _ in shard_pipeline(storage, paths, rec_bytes,
                                              seed=0))
    state = make_state(state_mb)
    mgr = CheckpointManager(storage, "ckpt/m", keep_last=keep_last)
    it = ResumableIterator(seek_factory)
    # consume half the epoch (in batches), checkpointing n_saves times on
    # the way — more saves than keep_last, so GC retention is exercised
    half = max(1, n_batches // 2)
    consumed = 0
    save_at = {max(1, half * (k + 1) // n_saves) for k in range(n_saves)}
    for batch in it:
        consumed += 1
        if consumed in save_at:
            state["step"] = np.int64(consumed)
            mgr.save(consumed, state,
                     extra_meta={"pipeline": it.state()})
        if consumed >= half:
            break
    it.close()   # the kill: this process's iterator state is gone
    ckpt_files = len([n for n in storage.listdir("ckpt")
                      if n != "checkpoint"])

    # restart: fresh manager, fresh *seekable* iterator, one timed resume()
    mgr2 = CheckpointManager(storage, "ckpt/m", keep_last=keep_last)
    it2 = ResumableIterator(seek_factory)
    skeleton = make_state(state_mb)
    t0 = time.monotonic()
    res = mgr2.resume(skeleton, data_iter=it2)
    recover_s = time.monotonic() - t0
    it2.close()
    assert res.step is not None and res.step <= half
    assert len(mgr2.all_steps()) <= keep_last + 1

    # the same resume through a replay-only factory: the pre-seek baseline
    mgr3 = CheckpointManager(storage, "ckpt/m", keep_last=keep_last)
    it3 = ResumableIterator(
        lambda ep: shard_pipeline(storage, paths, rec_bytes, seed=ep))
    t0 = time.monotonic()
    res3 = mgr3.resume(make_state(state_mb), data_iter=it3)
    recover_replay_s = time.monotonic() - t0
    it3.close()
    assert res3.step == res.step
    return recover_s, recover_replay_s, res.step, ckpt_files


def run(n_shards=16, recs_per_shard=32, rec_bytes=64 * 1024,
        state_mb=4.0, keep_last=3, n_saves=5, fault_rate=FAULT_RATE,
        n_passes=2, time_scale=1.0, smoke=False, name="fig13_recovery",
        json_path=None) -> dict:
    rows = []
    tiers_out = {}
    with tempfile.TemporaryDirectory(dir=SCRATCH) as root:
        for tier in TIERS:
            sim = make_storage(tier, os.path.join(root, tier),
                               time_scale=time_scale)
            paths = write_corpus(sim, n_shards, recs_per_shard, rec_bytes)

            faulty = FaultyStorage(sim).transient(
                rate=fault_rate, ops=("read",), seed=32)
            rs = RetryingStorage(faulty, make_policy(sim))
            reg = metrics.start()
            try:
                # metrics stay on for both passes so the comparison is
                # apples-to-apples; one untimed pass warms the reader pool
                read_all(sim, paths, rec_bytes, n_passes=1)
                clean_sps = read_all(sim, paths, rec_bytes, n_passes=n_passes)
                faulty_sps = read_all(rs, paths, rec_bytes, n_passes=n_passes)
                counters = reg.collect()["counters"]
                quarantined = int(sum(
                    v for k, v in counters.items()
                    if k.startswith("pipeline.quarantined_shards")))
            finally:
                metrics.stop()
            goodput = faulty_sps / max(clean_sps, 1e-9)

            recover_s, recover_replay_s, rec_step, ckpt_files = \
                measure_recovery(sim, paths, rec_bytes, state_mb,
                                 keep_last, n_saves)

            tiers_out[tier] = {
                "clean": {"samples_per_s": round(clean_sps, 2)},
                "faulty": {"samples_per_s": round(faulty_sps, 2)},
                "goodput_frac": round(goodput, 4),
                "retries": rs.retries,
                "gave_up": rs.gave_up,
                "quarantined_shards": quarantined,
                "recover_s": round(recover_s, 4),
                "recover_replay_s": round(recover_replay_s, 4),
                "recovered_step": rec_step,
                "ckpt_files_on_disk": ckpt_files,
            }
            rows.append(
                f"tier={tier},clean_samples_per_s={clean_sps:.1f},"
                f"faulty_samples_per_s={faulty_sps:.1f},"
                f"goodput_frac={goodput:.3f},retries={rs.retries},"
                f"gave_up={rs.gave_up},quarantined={quarantined},"
                f"recover_s={recover_s:.3f},"
                f"recover_replay_s={recover_replay_s:.3f}")

    hdd = tiers_out["hdd"]
    ok_goodput = hdd["goodput_frac"] >= 0.9
    ok_quarantine = all(t["quarantined_shards"] == 0 and t["gave_up"] == 0
                        for t in tiers_out.values())
    derived = (
        f"hdd goodput under {fault_rate:.0%} transient read faults = "
        f"{hdd['goodput_frac']:.3f} (acceptance: >=0.9, no quarantine); "
        f"recover_s (seek vs replay): " + ", ".join(
            f"{t}={tiers_out[t]['recover_s']:.3f}/"
            f"{tiers_out[t]['recover_replay_s']:.3f}" for t in TIERS))
    emit(name, rows, derived)

    payload = {
        "benchmark": name,
        "config": {
            "n_shards": n_shards, "recs_per_shard": recs_per_shard,
            "rec_bytes": rec_bytes, "state_mb": state_mb,
            "keep_last": keep_last, "n_saves": n_saves,
            "fault_rate": fault_rate, "n_passes": n_passes,
            "time_scale": time_scale,
            "retry": {"max_attempts": RETRY_ATTEMPTS,
                      "base_delay_s": RETRY_BASE_S,
                      "max_delay_s": RETRY_MAX_S,
                      "paced_sleep": True},
            "tiers": list(TIERS),
        },
        "tiers": tiers_out,
        "acceptance": {"hdd_goodput_ok": ok_goodput,
                       "no_quarantine": ok_quarantine},
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_json = json_path or os.path.join(RESULTS_DIR, "BENCH_recovery.json")
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_json}")
    return payload


def run_smoke() -> dict:
    """Tiny-scale CI variant: same output shape, seconds of runtime."""
    return run(n_shards=6, recs_per_shard=8, rec_bytes=16 * 1024,
               state_mb=0.5, n_saves=4, smoke=True)


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    payload = run_smoke() if smoke else run()
    acc = payload["acceptance"]
    ok = acc["hdd_goodput_ok"] and acc["no_quarantine"]
    print(f"# hdd goodput ok={acc['hdd_goodput_ok']} "
          f"no_quarantine={acc['no_quarantine']}")
    if not ok:
        sys.exit(1)
