"""Step functions: train / prefill / decode, sharding-aware.

``make_*_step`` return pure functions suitable for ``jax.jit`` with explicit
in/out shardings (built by :func:`state_shardings` / :func:`batch_shardings`).
The same functions drive the real trainer (CPU smoke scale) and the
multi-pod dry-run (lower+compile only).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..models.registry import model_fns
from ..sharding.rules import ShardingCtx
from .optimizer import OptConfig, adam_update, init_opt_state

Array = jax.Array


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def softmax_xent(logits: Array, labels: Array, z_loss: float = 1e-4) -> Array:
    """Mean token cross entropy (fp32) + small z-loss for stability.

    The label log-prob is picked with a one-hot einsum, NOT take_along_axis:
    gathering along a vocab-sharded logits dim makes GSPMD replicate the
    full (B,S,V) fp32 logits per device (8+ GiB at 4k x 32k-vocab)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.einsum("...v,...v->...", logits, onehot)
    loss = (lse - ll).mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------
def init_train_state(rng, cfg, opt_cfg: OptConfig) -> Dict[str, Any]:
    fns = model_fns(cfg)
    params = fns.init_params(rng, cfg)
    return dict(
        params=params,
        opt=init_opt_state(params, opt_cfg),
        step=jnp.int32(0),
    )


def make_train_step(cfg, opt_cfg: OptConfig, ctx: Optional[ShardingCtx] = None,
                    *, remat: bool = True, q_chunk: int = 1024,
                    kv_chunk: int = 1024, aux_weight: float = 0.01,
                    microbatch: int = 1) -> Callable:
    """Build the jit-able train step.

    ``microbatch > 1`` enables gradient accumulation: the global batch is
    split into ``microbatch`` slices processed by a ``lax.scan``; activation
    memory scales down by the same factor (fp32 grad accumulator costs one
    param-sized buffer).  This is how the largest train cells fit HBM.
    """
    fns = model_fns(cfg)

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        if fns.is_encdec:
            logits, aux = fns.forward(params, batch["frames"], inputs, cfg, ctx,
                                      remat=remat, q_chunk=q_chunk,
                                      kv_chunk=kv_chunk)
        else:
            logits, aux = fns.forward(params, inputs, cfg, ctx, remat=remat,
                                      q_chunk=q_chunk, kv_chunk=kv_chunk)
        loss = softmax_xent(logits, labels)
        return loss + aux_weight * aux, (loss, aux)

    def grads_of(params, batch):
        if microbatch <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        mb_batch = jax.tree.map(
            lambda x: x.reshape(microbatch, x.shape[0] // microbatch,
                                *x.shape[1:]),
            batch)
        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            (total, (loss, aux)), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32),
                               acc, g)
            return acc, (total, loss, aux)

        acc, (totals, losses, auxes) = lax.scan(body, acc0, mb_batch)
        grads = jax.tree.map(lambda a: (a / microbatch), acc)
        return (totals.mean(), (losses.mean(), auxes.mean())), grads

    def train_step(state, batch):
        (total, (loss, aux)), grads = grads_of(state["params"], batch)
        new_params, new_opt = adam_update(
            grads, state["opt"], state["params"], state["step"], opt_cfg)
        new_state = dict(params=new_params, opt=new_opt, step=state["step"] + 1)
        metrics = dict(loss=loss, aux=aux, total=total)
        return new_state, metrics

    return train_step


def make_prefill_step(cfg, ctx: Optional[ShardingCtx] = None,
                      *, q_chunk: int = 1024, kv_chunk: int = 1024) -> Callable:
    fns = model_fns(cfg)

    if fns.is_encdec:
        def prefill_step(params, batch, cache):
            return fns.prefill(params, batch["frames"], batch["tokens"],
                               cache, cfg, ctx, q_chunk=q_chunk, kv_chunk=kv_chunk)
    else:
        def prefill_step(params, batch, cache):
            return fns.prefill(params, batch["tokens"], cache, cfg, ctx,
                               q_chunk=q_chunk, kv_chunk=kv_chunk)
    return prefill_step


def make_decode_step(cfg, ctx: Optional[ShardingCtx] = None) -> Callable:
    fns = model_fns(cfg)

    def decode(params, token, cache):
        return fns.decode_step(params, token, cache, cfg, ctx)

    return decode


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------
def _leaf_is_logical(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def params_shardings(cfg, ctx: ShardingCtx, params_shapes) -> Any:
    """NamedSharding tree for params given their eval_shape tree."""
    fns = model_fns(cfg)
    logical = fns.param_logical(cfg)
    return jax.tree.map(
        lambda log, shp: ctx.sharding(log, shp.shape),
        logical, params_shapes, is_leaf=_leaf_is_logical,
    )


def opt_shardings(params_shapes, param_sh, opt_shapes, ctx: ShardingCtx) -> Any:
    """Optimizer-state shardings.

    m/v leaves that mirror the param shape reuse the param sharding; the
    int8-quantized layout ({q: (nblocks, 256), s: (nblocks, 1)}) is sharded
    on its block dim over the FSDP ('data') axis when even.
    """
    if ctx.mesh is None:
        return jax.tree.map(lambda _: None, opt_shapes)
    treedef = jax.tree_util.tree_structure(params_shapes)
    flat_pshape = jax.tree_util.tree_leaves(params_shapes)
    flat_psh = treedef.flatten_up_to(param_sh)
    flat_opt = treedef.flatten_up_to(opt_shapes)

    def axis_size(axes):
        if axes is None:
            return 1
        flat = (axes,) if isinstance(axes, str) else axes
        n = 1
        for a in flat:
            n *= ctx.mesh.shape.get(a, 1)
        return n

    def per_param(pshape, psh, osub):
        pspec = (tuple(psh.spec) + (None,) * len(pshape.shape)
                 )[: len(pshape.shape)] if psh is not None else None

        def leaf(x):
            if psh is not None and x.shape == pshape.shape:
                return psh
            if (pspec is not None and x.ndim == len(pshape.shape) + 1
                    and x.shape[: x.ndim - 2] == pshape.shape[:-1]):
                # int8 blockwise state (..., nb, QBLOCK|1): keep the leading
                # dims' partitioning; re-check the block dim's divisibility
                # against the last param axis assignment
                last = pspec[-1]
                if last is not None and x.shape[-2] % axis_size(last) != 0:
                    last = None
                spec = jax.sharding.PartitionSpec(*pspec[:-1], last, None)
                return jax.sharding.NamedSharding(ctx.mesh, spec)
            spec = ctx.spec(("d_model_w",) + (None,) * (len(x.shape) - 1), x.shape)
            return jax.sharding.NamedSharding(ctx.mesh, spec)

        return jax.tree.map(leaf, osub)

    out = [per_param(p, s, o) for p, s, o in zip(flat_pshape, flat_psh, flat_opt)]
    return jax.tree_util.tree_unflatten(treedef, out)


def state_shardings(cfg, ctx: ShardingCtx, state_shapes) -> Any:
    p_sh = params_shardings(cfg, ctx, state_shapes["params"])
    o_sh = opt_shardings(state_shapes["params"], p_sh, state_shapes["opt"], ctx)
    step_sh = (jax.sharding.NamedSharding(ctx.mesh, jax.sharding.PartitionSpec())
               if ctx.mesh is not None else None)
    return dict(params=p_sh, opt=o_sh, step=step_sh)


def batch_shardings(cfg, ctx: ShardingCtx, batch_shapes) -> Any:
    def leaf(shp):
        nd = len(shp.shape)
        if nd >= 3:  # frames (B, T, D) or mrope positions
            logical = ("batch",) + (None,) * (nd - 1)
        else:
            logical = ("batch",) + (None,) * (nd - 1)
        return ctx.sharding(logical, shp.shape)

    return jax.tree.map(leaf, batch_shapes)


def cache_shardings(cfg, ctx: ShardingCtx, cache_shapes) -> Any:
    def leaf(path_shp):
        return None

    def build(name, shp):
        nd = len(shp.shape)
        if name in ("k", "v"):
            logical = ("stack", "batch", "kv_seq", "kv_heads", "head_dim")
        elif name in ("xk", "xv"):
            logical = ("stack", "batch", "enc_seq", "kv_heads", "head_dim")
        elif name == "state":
            logical = ("stack",) * (nd - 4) + ("batch", "ssm_heads", None, None)
        elif name == "conv":
            logical = ("stack",) * (nd - 3) + ("batch", None, "d_inner")
        else:  # pos
            logical = ()
        return ctx.sharding(logical[:nd], shp.shape)

    return {k: build(k, v) if hasattr(v, "shape") else
            (jax.sharding.NamedSharding(ctx.mesh, jax.sharding.PartitionSpec())
             if ctx.mesh is not None else None)
            for k, v in cache_shapes.items()}
